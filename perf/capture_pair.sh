#!/usr/bin/env sh
# Capture a before/after pair of telemetry snapshots with the binary's
# own exporter, so perf PRs can commit real evidence instead of claims.
#
#   perf/capture_pair.sh <before-rev> [<after-rev>] [<tag>]
#   PROFILE=serve perf/capture_pair.sh <before-rev> [<after-rev>] [<tag>]
#
# For each rev this clones the repo into a temp dir at exactly that
# commit (detached, so the binary's pure-fs git_rev reader records the
# raw hash), builds the release binary, runs the selected workload with
# --metrics-out, and validates the snapshot with the same binary.
# Output lands at perf/<tag>-{before,after}-<profile>.metrics.json
# (+ .prom).
#
# Profiles (PROFILE env var):
#   tier1-smoke (default)  `run --preset small --lines 4` — the fit
#                          kernel workload; compare span.fit.ns.
#   serve                  build a small store, then drive the socket
#                          serving front in closed loop
#                          (`serve --listen 127.0.0.1:0 --clients 8`) —
#                          compare serve.<class>.latency_ns, the
#                          serve.*.cache_hit family and the
#                          store.read_path.{mmap,cached} split.
#
# after-rev defaults to HEAD; tag defaults to "pair". Example for the
# PR 8 SIMD evidence:
#
#   perf/capture_pair.sh 0d34285f HEAD pr8
#
# Revisions that already stamp provenance.report_fingerprint (PR 8
# fix-up onward) let you check "same results, less time" straight from
# the two JSON files for the tier1-smoke profile. When the before rev
# predates the field, compare the `report fingerprint` stdout line of
# the after binary run with PDFFLOW_SIMD=off vs auto instead — same
# code path the pair is claiming didn't change. The serve profile does
# not stamp a fingerprint (results identity is pinned by
# tests/serve_net.rs bit-equality instead).
set -eu

BEFORE=${1:?usage: [PROFILE=serve] perf/capture_pair.sh <before-rev> [<after-rev>] [<tag>]}
AFTER=${2:-HEAD}
TAG=${3:-pair}
PROFILE=${PROFILE:-tier1-smoke}
REPO=$(cd "$(dirname "$0")/.." && pwd)
OUT=$REPO/perf
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT INT TERM

capture() { # $1 = rev-ish, $2 = snapshot path
    rev=$(git -C "$REPO" rev-parse "$1")
    clone=$WORK/$rev
    git clone -q --no-checkout "$REPO" "$clone"
    git -C "$clone" checkout -q --detach "$rev"
    echo "== building $rev"
    (cd "$clone" && cargo build -q --release)
    bin=$clone/target/release/pdfflow
    echo "== capturing $2 ($PROFILE)"
    case "$PROFILE" in
    serve)
        store=$clone/tmp-serve-store
        (cd "$clone" && "$bin" store --preset small --lines 8 --store-dir "$store")
        # Server + closed-loop driver in one process: the socket front
        # listens on an ephemeral loopback port, 8 client connections
        # drive the mixed request classes, and the snapshot lands on
        # exit with the serve/net/read-path counter families.
        (cd "$clone" && "$bin" serve --store-dir "$store" --listen 127.0.0.1:0 \
            --max-in-flight 4 --queue-depth 8 --clients 8 --queries 4000 \
            --metrics-out "$2")
        ;;
    tier1-smoke)
        (cd "$clone" && "$bin" run --preset small --lines 4 --metrics-out "$2")
        ;;
    *)
        echo "unknown PROFILE '$PROFILE' (tier1-smoke | serve)" >&2
        exit 2
        ;;
    esac
    (cd "$clone" && "$bin" telemetry validate "$2")
}

capture "$BEFORE" "$OUT/$TAG-before-$PROFILE.metrics.json"
capture "$AFTER" "$OUT/$TAG-after-$PROFILE.metrics.json"

if command -v python3 >/dev/null 2>&1; then
    PROFILE="$PROFILE" python3 - "$OUT/$TAG-before-$PROFILE.metrics.json" \
              "$OUT/$TAG-after-$PROFILE.metrics.json" <<'EOF'
import json, os, sys
profile = os.environ.get("PROFILE", "tier1-smoke")
pair = [json.load(open(p)) for p in sys.argv[1:3]]
for label, snap in zip(("before", "after"), pair):
    prov = snap["provenance"]
    m = snap["metrics"]
    if profile == "serve":
        lat = m.get("serve.point.latency_ns", {})
        hits = sum(m.get(f"serve.{c}.cache_hit", {}).get("value", 0)
                   for c in ("point", "region", "analytic", "box", "radius", "knn", "diff"))
        print(f"{label}: git_rev {prov['git_rev'][:12]} "
              f"serve.point.latency_ns p50 {lat.get('p50', '-')} "
              f"count {lat.get('count', '-')} cache_hits {hits:.0f} "
              f"reads mmap/cached "
              f"{m.get('store.read_path.mmap', {}).get('value', 0):.0f}/"
              f"{m.get('store.read_path.cached', {}).get('value', 0):.0f}")
    else:
        fit = m.get("span.fit.ns", {})
        print(f"{label}: git_rev {prov['git_rev'][:12]} "
              f"fingerprint {prov.get('report_fingerprint', '-')} "
              f"span.fit.ns p50 {fit.get('p50', '-')} count {fit.get('count', '-')}")
fps = [p["provenance"].get("report_fingerprint") for p in pair]
if all(fps):
    print("report fingerprints match" if fps[0] == fps[1]
          else "WARNING: report fingerprints DIFFER — results changed")
EOF
fi
