#!/usr/bin/env sh
# Capture a before/after pair of tier1-smoke telemetry snapshots with
# the binary's own exporter, so perf PRs can commit real evidence
# instead of claims.
#
#   perf/capture_pair.sh <before-rev> [<after-rev>] [<tag>]
#
# For each rev this clones the repo into a temp dir at exactly that
# commit (detached, so the binary's pure-fs git_rev reader records the
# raw hash), builds the release binary, runs the tier1-smoke workload
# (`run --preset small --lines 4`) with --metrics-out, and validates
# the snapshot with the same binary. Output lands at
# perf/<tag>-{before,after}-tier1-smoke.metrics.json (+ .prom).
#
# after-rev defaults to HEAD; tag defaults to "pair". Example for the
# PR 8 SIMD evidence:
#
#   perf/capture_pair.sh 0d34285f HEAD pr8
#
# Revisions that already stamp provenance.report_fingerprint (PR 8
# fix-up onward) let you check "same results, less time" straight from
# the two JSON files. When the before rev predates the field, compare
# the `report fingerprint` stdout line of the after binary run with
# PDFFLOW_SIMD=off vs auto instead — same code path the pair is
# claiming didn't change.
set -eu

BEFORE=${1:?usage: perf/capture_pair.sh <before-rev> [<after-rev>] [<tag>]}
AFTER=${2:-HEAD}
TAG=${3:-pair}
REPO=$(cd "$(dirname "$0")/.." && pwd)
OUT=$REPO/perf
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT INT TERM

capture() { # $1 = rev-ish, $2 = snapshot path
    rev=$(git -C "$REPO" rev-parse "$1")
    clone=$WORK/$rev
    git clone -q --no-checkout "$REPO" "$clone"
    git -C "$clone" checkout -q --detach "$rev"
    echo "== building $rev"
    (cd "$clone" && cargo build -q --release)
    bin=$clone/target/release/pdfflow
    echo "== capturing $2"
    (cd "$clone" && "$bin" run --preset small --lines 4 --metrics-out "$2")
    (cd "$clone" && "$bin" telemetry validate "$2")
}

capture "$BEFORE" "$OUT/$TAG-before-tier1-smoke.metrics.json"
capture "$AFTER" "$OUT/$TAG-after-tier1-smoke.metrics.json"

if command -v python3 >/dev/null 2>&1; then
    python3 - "$OUT/$TAG-before-tier1-smoke.metrics.json" \
              "$OUT/$TAG-after-tier1-smoke.metrics.json" <<'EOF'
import json, sys
pair = [json.load(open(p)) for p in sys.argv[1:3]]
for label, snap in zip(("before", "after"), pair):
    prov = snap["provenance"]
    fit = snap["metrics"].get("span.fit.ns", {})
    print(f"{label}: git_rev {prov['git_rev'][:12]} "
          f"fingerprint {prov.get('report_fingerprint', '-')} "
          f"span.fit.ns p50 {fit.get('p50', '-')} count {fit.get('count', '-')}")
fps = [p["provenance"].get("report_fingerprint") for p in pair]
if all(fps):
    print("report fingerprints match" if fps[0] == fps[1]
          else "WARNING: report fingerprints DIFFER — results changed")
EOF
fi
