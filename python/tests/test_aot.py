"""AOT path checks: spec catalog, HLO text emission, manifest integrity."""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, distfit, model


class TestSpecCatalog:
    def test_build_specs_counts(self):
        specs = model.build_specs(8, 50)
        # 1 stats + 10 singles + 2 fit_all
        assert len(specs) == 13
        kinds = [s.kind for s in specs]
        assert kinds.count("stats") == 1
        assert kinds.count("fit_single") == 10
        assert kinds.count("fit_all") == 2

    def test_spec_shapes(self):
        for s in model.build_specs(8, 50):
            assert s.in_shape == (8, 50)
            out = s.fn(jnp.zeros((8, 50), dtype=jnp.float32) + 1.0)
            assert out.shape == s.out_shape

    def test_names_unique(self):
        specs = model.build_specs(8, 50) + model.build_specs(4, 20)
        names = [s.name for s in specs]
        assert len(names) == len(set(names))


class TestLowering:
    def test_hlo_text_emitted(self):
        spec = model.build_specs(4, 20)[0]  # stats — cheapest
        text = aot.to_hlo_text(model.lower_spec(spec))
        assert "ENTRY" in text
        assert "f32[4,20]" in text

    def test_build_writes_manifest(self, tmp_path):
        manifest = aot.build(str(tmp_path), [(4, 20)], verbose=False)
        with open(tmp_path / "manifest.json") as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        assert on_disk["l_bins"] == distfit.DEFAULT_BINS
        assert on_disk["types"] == distfit.TYPES
        assert on_disk["stats_cols"] == distfit.STATS_COLS
        assert len(on_disk["artifacts"]) == 13
        for a in on_disk["artifacts"]:
            path = tmp_path / a["file"]
            assert path.exists() and path.stat().st_size > 0
            assert a["batch"] == 4 and a["obs"] == 20

    def test_no_pallas_variant_matches_pallas_numerics(self):
        rng = np.random.default_rng(0)
        v = jnp.asarray(rng.normal(2.0, 1.0, (8, 200)), dtype=jnp.float32)
        a = np.asarray(distfit.fit_all(v, n_types=4, use_pallas=True))
        b = np.asarray(distfit.fit_all(v, n_types=4, use_pallas=False))
        np.testing.assert_array_equal(a[:, 0], b[:, 0])
        np.testing.assert_allclose(a[:, 1:], b[:, 1:], rtol=1e-4, atol=1e-4)
