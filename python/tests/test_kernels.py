"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes and data regimes; assert_allclose against ref.py
is the CORE correctness signal for the kernels that end up inside every
AOT artifact.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.histogram import histogram
from compile.kernels.moments import N_STATS, moments, pick_block

SHAPES = st.tuples(
    st.integers(min_value=1, max_value=33),   # B (incl. non-divisible sizes)
    st.integers(min_value=1, max_value=257),  # N
)

REGIMES = st.sampled_from(["normal", "positive", "negative", "mixed", "tiny", "huge"])


def _make_values(shape, regime, seed):
    rng = np.random.default_rng(seed)
    b, n = shape
    if regime == "normal":
        v = rng.normal(5.0, 2.0, size=(b, n))
    elif regime == "positive":
        v = rng.gamma(2.0, 3.0, size=(b, n)) + 1e-3
    elif regime == "negative":
        v = -rng.gamma(2.0, 3.0, size=(b, n)) - 1e-3
    elif regime == "mixed":
        v = rng.normal(0.0, 1.0, size=(b, n))
    elif regime == "tiny":
        v = rng.normal(0.0, 1e-6, size=(b, n))
    else:  # huge
        v = rng.normal(1e5, 1e4, size=(b, n))
    return jnp.asarray(v, dtype=jnp.float32)


class TestMoments:
    @settings(max_examples=25, deadline=None)
    @given(shape=SHAPES, regime=REGIMES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, regime, seed):
        v = _make_values(shape, regime, seed)
        got = np.asarray(moments(v))
        want = np.asarray(ref.moments_ref(v))
        assert got.shape == (shape[0], N_STATS)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_block_partition_invariance(self):
        """The revisited-output reduction must not depend on block shape."""
        v = _make_values((16, 240), "mixed", 7)
        base = np.asarray(moments(v, block_b=16, block_n=240))
        for bb, bn in [(1, 240), (16, 1), (4, 60), (8, 16), (2, 120)]:
            got = np.asarray(moments(v, block_b=bb, block_n=bn))
            np.testing.assert_allclose(got, base, rtol=2e-5, atol=2e-5)

    def test_constant_data(self):
        v = jnp.full((4, 64), 3.5, dtype=jnp.float32)
        got = np.asarray(moments(v))
        np.testing.assert_allclose(got[:, 4], 3.5)  # min
        np.testing.assert_allclose(got[:, 5], 3.5)  # max
        np.testing.assert_allclose(got[:, 0], 3.5 * 64, rtol=1e-6)

    def test_log_guard_on_nonpositive(self):
        """Non-positive values must contribute 0 to log sums, not NaN."""
        v = jnp.array([[-1.0, 0.0, 1.0, jnp.e]], dtype=jnp.float32)
        got = np.asarray(moments(v))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got[0, 6], 1.0, rtol=1e-5)  # log(e) only

    def test_pick_block(self):
        assert pick_block(1000, 512) == 500
        assert pick_block(100, 512) == 100
        assert pick_block(7, 4) == 1
        assert pick_block(4000, 512) == 500
        for n in [1, 2, 13, 100, 1000, 4000]:
            b = pick_block(n, 512)
            assert n % b == 0 and b <= max(512, n if n <= 512 else 512)


class TestHistogram:
    @settings(max_examples=25, deadline=None)
    @given(
        shape=SHAPES,
        regime=REGIMES,
        seed=st.integers(0, 2**31 - 1),
        n_bins=st.sampled_from([4, 16, 32]),
    )
    def test_matches_ref(self, shape, regime, seed, n_bins):
        v = _make_values(shape, regime, seed)
        mn, mx = jnp.min(v, axis=1), jnp.max(v, axis=1)
        got = np.asarray(histogram(v, mn, mx, n_bins=n_bins))
        want = np.asarray(ref.histogram_ref(v, mn, mx, n_bins))
        np.testing.assert_allclose(got, want)

    @settings(max_examples=15, deadline=None)
    @given(shape=SHAPES, regime=REGIMES, seed=st.integers(0, 2**31 - 1))
    def test_total_mass(self, shape, regime, seed):
        """Every observation lands in exactly one bin."""
        v = _make_values(shape, regime, seed)
        mn, mx = jnp.min(v, axis=1), jnp.max(v, axis=1)
        got = np.asarray(histogram(v, mn, mx, n_bins=32))
        np.testing.assert_allclose(got.sum(axis=1), float(shape[1]))

    def test_max_value_in_last_bin(self):
        v = jnp.array([[0.0, 0.5, 1.0, 1.0]], dtype=jnp.float32)
        got = np.asarray(histogram(v, jnp.array([0.0]), jnp.array([1.0]), n_bins=4))
        assert got[0, -1] == 2.0  # both 1.0s clip into the last bin
        assert got[0, 0] == 1.0

    def test_constant_data_single_bin(self):
        """min == max must not divide by zero; all mass in bin 0."""
        v = jnp.full((2, 32), 7.0, dtype=jnp.float32)
        got = np.asarray(histogram(v, jnp.full(2, 7.0), jnp.full(2, 7.0), n_bins=8))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got[:, 0], 32.0)
        np.testing.assert_allclose(got[:, 1:], 0.0)

    def test_block_partition_invariance(self):
        v = _make_values((8, 120), "mixed", 3)
        mn, mx = jnp.min(v, axis=1), jnp.max(v, axis=1)
        base = np.asarray(histogram(v, mn, mx, n_bins=16, block_b=8, block_n=120))
        for bb, bn in [(1, 120), (8, 1), (4, 30), (2, 60)]:
            got = np.asarray(histogram(v, mn, mx, n_bins=16, block_b=bb, block_n=bn))
            np.testing.assert_allclose(got, base)
