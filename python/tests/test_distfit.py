"""L2 estimator correctness: parameter recovery + Eq.5 semantics.

Each candidate distribution type is checked on clean synthetic draws of
itself: the fitted parameters must be close to the generating ones and the
type must win (or tie within tolerance) the fit_all argmin.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import distfit

RNG = np.random.default_rng(42)
N = 2000  # observations per point — enough for stable Eq.5 histograms


def _batch(draws):
    return jnp.asarray(np.stack(draws), dtype=jnp.float32)


def _fit(v, type_name):
    out = np.asarray(distfit.fit_single(v, type_name))
    return out[:, 0], out[:, 1:]  # err, params


class TestParameterRecovery:
    def test_normal(self):
        v = _batch([RNG.normal(10.0, 3.0, N) for _ in range(4)])
        err, p = _fit(v, "normal")
        np.testing.assert_allclose(p[:, 0], 10.0, atol=0.3)
        np.testing.assert_allclose(p[:, 1], 3.0, atol=0.3)
        assert (err < 0.25).all()

    def test_uniform(self):
        v = _batch([RNG.uniform(2.0, 8.0, N) for _ in range(4)])
        err, p = _fit(v, "uniform")
        np.testing.assert_allclose(p[:, 0], 2.0, atol=0.1)
        np.testing.assert_allclose(p[:, 1], 8.0, atol=0.1)
        assert (err < 0.25).all()

    def test_exponential(self):
        v = _batch([RNG.exponential(1.0 / 0.7, N) for _ in range(4)])
        err, p = _fit(v, "exponential")
        np.testing.assert_allclose(p[:, 0], 0.7, rtol=0.15)
        assert (err < 0.25).all()

    def test_lognormal(self):
        v = _batch([RNG.lognormal(1.0, 0.5, N) for _ in range(4)])
        err, p = _fit(v, "lognormal")
        np.testing.assert_allclose(p[:, 0], 1.0, atol=0.1)
        np.testing.assert_allclose(p[:, 1], 0.5, atol=0.1)
        assert (err < 0.3).all()

    def test_cauchy(self):
        v = _batch([RNG.standard_cauchy(N) * 2.0 + 5.0 for _ in range(4)])
        err, p = _fit(v, "cauchy")
        np.testing.assert_allclose(p[:, 0], 5.0, atol=0.5)
        np.testing.assert_allclose(p[:, 1], 2.0, rtol=0.3)

    def test_gamma(self):
        v = _batch([RNG.gamma(4.0, 2.5, N) for _ in range(4)])
        err, p = _fit(v, "gamma")
        np.testing.assert_allclose(p[:, 0], 4.0, rtol=0.25)
        np.testing.assert_allclose(p[:, 1], 2.5, rtol=0.25)
        assert (err < 0.3).all()

    def test_geometric(self):
        v = _batch([RNG.geometric(0.3, N) - 1.0 for _ in range(4)])  # support {0,1,..}
        err, p = _fit(v, "geometric")
        np.testing.assert_allclose(p[:, 0], 0.3, rtol=0.15)

    def test_logistic(self):
        v = _batch([RNG.logistic(3.0, 1.5, N) for _ in range(4)])
        err, p = _fit(v, "logistic")
        np.testing.assert_allclose(p[:, 0], 3.0, atol=0.4)
        np.testing.assert_allclose(p[:, 1], 1.5, rtol=0.25)
        assert (err < 0.3).all()

    def test_student_t(self):
        v = _batch([RNG.standard_t(6.0, N) for _ in range(4)])
        err, p = _fit(v, "student_t")
        np.testing.assert_allclose(p[:, 0], 0.0, atol=0.3)
        assert (p[:, 2] > 2.1).all() and (p[:, 2] < 200.0).all()
        assert (err < 0.3).all()

    def test_weibull(self):
        v = _batch([2.5 * RNG.weibull(1.8, N) for _ in range(4)])
        err, p = _fit(v, "weibull")
        np.testing.assert_allclose(p[:, 0], 1.8, rtol=0.2)
        np.testing.assert_allclose(p[:, 1], 2.5, rtol=0.2)
        assert (err < 0.3).all()


class TestSupportGuards:
    def test_positive_only_types_penalized_on_negative_data(self):
        v = _batch([RNG.normal(-10.0, 1.0, N)])
        for t in ["exponential", "lognormal", "gamma", "geometric", "weibull"]:
            err, _ = _fit(v, t)
            assert err[0] == distfit.PENALTY_ERROR, t

    def test_lognormal_penalized_on_zero(self):
        x = RNG.lognormal(0.0, 1.0, N)
        x[0] = 0.0
        err, _ = _fit(_batch([x]), "lognormal")
        assert err[0] == distfit.PENALTY_ERROR

    def test_all_errors_within_bounds(self):
        v = _batch([RNG.normal(0, 1, N), RNG.uniform(-5, 5, N)])
        for t in distfit.TYPES:
            err, _ = _fit(v, t)
            assert (err >= 0.0).all() and (err <= distfit.PENALTY_ERROR).all(), t


class TestFitAll:
    def test_argmin_consistent_with_singles(self):
        """fit_all's chosen error equals the min over fit_single errors."""
        v = _batch(
            [
                RNG.normal(5, 2, N),
                RNG.uniform(0, 1, N),
                RNG.exponential(2.0, N),
                RNG.lognormal(0.5, 0.8, N),
            ]
        )
        for n_types in (4, 10):
            fa = np.asarray(distfit.fit_all(v, n_types=n_types))
            singles = np.stack(
                [_fit(v, t)[0] for t in distfit.TYPES[:n_types]], axis=1
            )
            np.testing.assert_allclose(fa[:, 1], singles.min(axis=1), rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(
                fa[:, 0].astype(int), singles.argmin(axis=1)
            )

    def test_recovers_generating_family_4types(self):
        """On clean draws of the 4 input families, fit_all4 picks the family
        (or a strictly better-scoring one — must at least beat it rarely)."""
        draws = {
            0: RNG.normal(5, 2, N),
            1: RNG.uniform(0, 1, N),
            2: RNG.exponential(2.0, N),
            3: RNG.lognormal(0.5, 0.8, N),
        }
        v = _batch([draws[i] for i in range(4)])
        fa = np.asarray(distfit.fit_all(v, n_types=4))
        assert (fa[:, 0].astype(int) == np.arange(4)).sum() >= 3

    def test_10types_error_never_above_4types(self):
        """A superset of candidates can only lower the best error (paper
        observes smaller E for 10-types)."""
        v = _batch([RNG.normal(0, 1, N), RNG.standard_t(5, N), RNG.gamma(3, 1, N)])
        e4 = np.asarray(distfit.fit_all(v, n_types=4))[:, 1]
        e10 = np.asarray(distfit.fit_all(v, n_types=10))[:, 1]
        assert (e10 <= e4 + 1e-6).all()


class TestEq5:
    def test_perfect_uniform_histogram_zero_error(self):
        """If hist mass equals CDF increments exactly, the error is 0."""
        hist = jnp.full((1, 4), 25.0)
        cdf = jnp.array([[0.0, 0.25, 0.5, 0.75, 1.0]])
        err = np.asarray(distfit.eq5_error(hist, cdf, 100))
        np.testing.assert_allclose(err, 0.0, atol=1e-7)

    def test_worst_case_error_is_two(self):
        """All observed mass in one bin, all model mass outside [min,max]."""
        hist = jnp.zeros((1, 4)).at[0, 0].set(100.0)
        cdf = jnp.zeros((1, 5))  # model puts no mass in any interval
        err = np.asarray(distfit.eq5_error(hist, cdf, 100))
        np.testing.assert_allclose(err, 1.0)

    def test_edges_cover_range(self):
        mn = jnp.array([0.0, -3.0])
        mx = jnp.array([1.0, 7.0])
        e = np.asarray(distfit.interval_edges(mn, mx, 8))
        assert e.shape == (2, 9)
        np.testing.assert_allclose(e[:, 0], [0.0, -3.0])
        np.testing.assert_allclose(e[:, -1], [1.0, 7.0])
        assert (np.diff(e, axis=1) > 0).all()


class TestStatsArtifact:
    def test_columns_and_pallas_parity(self):
        v = _batch([RNG.normal(3, 1, 500), RNG.gamma(2, 2, 500)])
        sp = np.asarray(distfit.point_stats(v, use_pallas=True))
        sr = np.asarray(distfit.point_stats(v, use_pallas=False))
        assert sp.shape == (2, len(distfit.STATS_COLS))
        np.testing.assert_allclose(sp, sr, rtol=1e-4, atol=1e-4)
        cols = {c: i for i, c in enumerate(distfit.STATS_COLS)}
        np.testing.assert_allclose(sp[0, cols["mean"]], 3.0, atol=0.2)
        np.testing.assert_allclose(sp[0, cols["std"]], 1.0, atol=0.2)
        assert sp[1, cols["pos_frac"]] == 1.0
