"""AOT compile path: lower every L2 graph to HLO text + manifest.json.

Interchange format is HLO **text**, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); never on the request path.

    cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import distfit, model

# Default artifact configurations: (batch, obs, types)
#   256x1000 — Set1/Set2-analog production shape (paper: 1000 simulations)
#   64x100   — fast shape for tests and small workloads
#   64x4000  — Set3-analog (paper: 10000 observations/point, scaled 0.4x)
DEFAULT_CONFIGS = [
    (256, 1000),
    (64, 100),
    (64, 4000),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, configs, use_pallas: bool = True, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": 1,
        "l_bins": distfit.DEFAULT_BINS,
        "types": distfit.TYPES,
        "stats_cols": distfit.STATS_COLS,
        "penalty_error": distfit.PENALTY_ERROR,
        "use_pallas": use_pallas,
        "artifacts": [],
    }
    for batch, obs in configs:
        for spec in model.build_specs(batch, obs, use_pallas=use_pallas):
            t0 = time.time()
            text = to_hlo_text(model.lower_spec(spec))
            fname = f"{spec.name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": spec.name,
                    "file": fname,
                    "kind": spec.kind,
                    "type": spec.type_name,
                    "n_types": spec.n_types,
                    "batch": spec.batch,
                    "obs": spec.obs,
                    "out_cols": spec.out_cols,
                }
            )
            if verbose:
                print(
                    f"  {spec.name:40s} {len(text)/1024:8.1f} KiB "
                    f"({time.time()-t0:5.1f}s)"
                )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--configs",
        default=None,
        help="comma-separated BxN list, e.g. '256x1000,64x100' (default: all)",
    )
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower with the pure-jnp reference kernels instead of Pallas",
    )
    args = ap.parse_args()
    if args.configs:
        configs = []
        for part in args.configs.split(","):
            b, n = part.lower().split("x")
            configs.append((int(b), int(n)))
    else:
        configs = DEFAULT_CONFIGS
    print(f"jax {jax.__version__}; lowering {configs} -> {args.out}")
    t0 = time.time()
    manifest = build(args.out, configs, use_pallas=not args.no_pallas)
    print(f"wrote {len(manifest['artifacts'])} artifacts in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
