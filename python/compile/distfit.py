"""L2: batched distribution fitting + Eq.5 error for the paper's 10 types.

This module replaces the external R program (``fitdistr``) the paper calls
per point from a Spark Map task. Everything is a single fused XLA graph per
artifact: sufficient statistics (L1 Pallas kernel), per-type closed-form /
method-of-moments estimators, CDF evaluation on the Eq.5 interval edges,
and the histogram-vs-CDF error.

Canonical type order (index = type id used across python, rust and the
decision tree):

    0 normal      1 uniform      2 exponential  3 lognormal
    4 cauchy      5 gamma        6 geometric    7 logistic
    8 student_t   9 weibull

4-types = indices 0..3 (the paper's input-parameter families);
10-types = all of them.

Eq. 5 (paper): split [min, max] of each point's observations into L equal
intervals; error = sum_k | Freq_k/N - (CDF(e_k) - CDF(e_{k-1})) |. The
error lies in [0, 2]; types whose support excludes the data (e.g.
log-normal on v <= 0) receive the penalty error 2.0, mirroring an R fit
failure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import betainc, gammainc, gammaln


def erf(x):
    """erf via Abramowitz–Stegun 7.1.26 (|abs err| < 1.5e-7).

    jax.scipy.special.erf lowers to the dedicated `erf` HLO opcode, which
    the xla crate's XLA 0.5.1 text parser rejects ("Unknown opcode: erf").
    This polynomial uses only mul/add/exp — parseable everywhere — and its
    error is far below the f32 precision of the artifacts.
    """
    a = (0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429)
    s = jnp.sign(x)
    z = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * z)
    poly = t * (a[0] + t * (a[1] + t * (a[2] + t * (a[3] + t * a[4]))))
    return s * (1.0 - poly * jnp.exp(-z * z))

from .kernels.histogram import DEFAULT_BINS, histogram
from .kernels.moments import MAX, MIN, SUM, SUM2, SUM3, SUM4, SUMLOG, SUMLOG2, moments
from .kernels import ref as kref

TYPES = [
    "normal",
    "uniform",
    "exponential",
    "lognormal",
    "cauchy",
    "gamma",
    "geometric",
    "logistic",
    "student_t",
    "weibull",
]
TYPE_INDEX = {t: i for i, t in enumerate(TYPES)}
PENALTY_ERROR = 2.0
_EPS = 1e-12


# ---------------------------------------------------------------------------
# Sufficient statistics
# ---------------------------------------------------------------------------

# Quantiles are only consumed by the cauchy estimator. XLA's sort is the
# single most expensive op in the stats graph (87 of 92 ms per 256x1000
# batch on this host), so rows wider than QUANTILE_SUBSAMPLE columns are
# strided down first — observations are i.i.d. across simulation files,
# so a stride-k subsample is a uniform subsample; the induced quantile
# standard error (~1.25/sqrt(256) of the local density scale) is far
# below the Eq.5 histogram resolution. The rust oracle
# (stats::PointStats) mirrors this estimator exactly.
QUANTILE_SUBSAMPLE = 256


def _quantiles_sorted(values: jax.Array):
    """(q25, q50, q75) per row: strided subsample + sort + interpolation."""
    n_full = values.shape[1]
    stride = max(1, -(-n_full // QUANTILE_SUBSAMPLE))  # ceil div
    sub = values[:, ::stride]
    vs = jnp.sort(sub, axis=1)
    n = sub.shape[1]
    out = []
    for q in (0.25, 0.50, 0.75):
        pos = q * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        frac = pos - lo
        out.append(vs[:, lo] * (1.0 - frac) + vs[:, hi] * frac)
    return out




def sufficient_stats(values: jax.Array, use_pallas: bool = True) -> dict:
    """Per-point statistics shared by every estimator.

    Returns a dict of (B,) arrays: mean, var (sample), std, min, max, skew,
    kurt_ex, meanlog, stdlog, q25, q50, q75, pos_frac.
    """
    b, n = values.shape
    raw = moments(values) if use_pallas else kref.moments_ref(values)
    nf = float(n)
    m1 = raw[:, SUM] / nf
    # Central moments from raw power sums.
    m2 = jnp.maximum(raw[:, SUM2] / nf - m1 * m1, 0.0)
    m3 = raw[:, SUM3] / nf - 3.0 * m1 * raw[:, SUM2] / nf + 2.0 * m1**3
    m4 = (
        raw[:, SUM4] / nf
        - 4.0 * m1 * raw[:, SUM3] / nf
        + 6.0 * m1 * m1 * raw[:, SUM2] / nf
        - 3.0 * m1**4
    )
    var = m2 * nf / max(nf - 1.0, 1.0)  # sample variance
    std = jnp.sqrt(var)
    m2s = jnp.maximum(m2, _EPS)
    skew = m3 / m2s**1.5
    kurt_ex = m4 / (m2s * m2s) - 3.0
    meanlog = raw[:, SUMLOG] / nf
    stdlog = jnp.sqrt(jnp.maximum(raw[:, SUMLOG2] / nf - meanlog * meanlog, 0.0))
    # Quantiles for the cauchy estimator (sort-based; outside the L1
    # kernel). One jnp.sort + three static interpolated gathers: ~4x
    # cheaper than jnp.percentile's generic path, which dominated the
    # stats graph before (EXPERIMENTS.md §Perf L2-1).
    q25, q50, q75 = _quantiles_sorted(values)
    pos_frac = jnp.mean((values > 0.0).astype(jnp.float32), axis=1)
    return {
        "mean": m1,
        "var": var,
        "std": std,
        "min": raw[:, MIN],
        "max": raw[:, MAX],
        "skew": skew,
        "kurt_ex": kurt_ex,
        "meanlog": meanlog,
        "stdlog": stdlog,
        "q25": q25,
        "q50": q50,
        "q75": q75,
        "pos_frac": pos_frac,
    }


# ---------------------------------------------------------------------------
# Per-type estimators: stats -> (params (B,3), supported (B,) bool)
# and CDFs: (params, x (B,K)) -> (B,K)
# ---------------------------------------------------------------------------


def _fit_normal(s):
    p = jnp.stack([s["mean"], jnp.maximum(s["std"], _EPS), jnp.zeros_like(s["mean"])], 1)
    return p, jnp.ones_like(s["mean"], bool)


def _cdf_normal(p, x):
    mu, sigma = p[:, 0:1], p[:, 1:2]
    return 0.5 * (1.0 + erf((x - mu) / (sigma * jnp.sqrt(2.0) + _EPS)))


def _fit_uniform(s):
    p = jnp.stack([s["min"], s["max"], jnp.zeros_like(s["mean"])], 1)
    return p, jnp.ones_like(s["mean"], bool)


def _cdf_uniform(p, x):
    a, b = p[:, 0:1], p[:, 1:2]
    return jnp.clip((x - a) / jnp.maximum(b - a, _EPS), 0.0, 1.0)


def _fit_exponential(s):
    lam = 1.0 / jnp.maximum(s["mean"], _EPS)
    p = jnp.stack([lam, jnp.zeros_like(lam), jnp.zeros_like(lam)], 1)
    return p, s["min"] >= 0.0


def _cdf_exponential(p, x):
    lam = p[:, 0:1]
    return jnp.where(x < 0.0, 0.0, 1.0 - jnp.exp(-lam * jnp.maximum(x, 0.0)))


def _fit_lognormal(s):
    p = jnp.stack(
        [s["meanlog"], jnp.maximum(s["stdlog"], _EPS), jnp.zeros_like(s["mean"])], 1
    )
    return p, s["min"] > 0.0


def _cdf_lognormal(p, x):
    mu, sigma = p[:, 0:1], p[:, 1:2]
    lx = jnp.log(jnp.maximum(x, _EPS))
    c = 0.5 * (1.0 + erf((lx - mu) / (sigma * jnp.sqrt(2.0) + _EPS)))
    return jnp.where(x <= 0.0, 0.0, c)


def _fit_cauchy(s):
    scale = jnp.maximum((s["q75"] - s["q25"]) * 0.5, _EPS)
    p = jnp.stack([s["q50"], scale, jnp.zeros_like(scale)], 1)
    return p, jnp.ones_like(scale, bool)


def _cdf_cauchy(p, x):
    loc, scale = p[:, 0:1], p[:, 1:2]
    return jnp.arctan((x - loc) / scale) / jnp.pi + 0.5


def _fit_gamma(s):
    var = jnp.maximum(s["var"], _EPS)
    mean = jnp.maximum(s["mean"], _EPS)
    k = jnp.clip(mean * mean / var, 1e-3, 1e6)
    theta = var / mean
    p = jnp.stack([k, jnp.maximum(theta, _EPS), jnp.zeros_like(k)], 1)
    return p, (s["min"] >= 0.0) & (s["mean"] > 0.0)


def _cdf_gamma(p, x):
    k, theta = p[:, 0:1], p[:, 1:2]
    return gammainc(k, jnp.maximum(x, 0.0) / theta)


def _fit_geometric(s):
    prob = 1.0 / jnp.maximum(1.0 + s["mean"], 1.0 + _EPS)
    p = jnp.stack([prob, jnp.zeros_like(prob), jnp.zeros_like(prob)], 1)
    return p, s["min"] >= 0.0


def _cdf_geometric(p, x):
    prob = jnp.clip(p[:, 0:1], _EPS, 1.0 - _EPS)
    k = jnp.floor(jnp.maximum(x, -1.0))
    c = 1.0 - jnp.exp((k + 1.0) * jnp.log1p(-prob))
    return jnp.where(x < 0.0, 0.0, c)


def _fit_logistic(s):
    scale = jnp.maximum(s["std"] * jnp.sqrt(3.0) / jnp.pi, _EPS)
    p = jnp.stack([s["mean"], scale, jnp.zeros_like(scale)], 1)
    return p, jnp.ones_like(scale, bool)


def _cdf_logistic(p, x):
    loc, scale = p[:, 0:1], p[:, 1:2]
    return jax.nn.sigmoid((x - loc) / scale)


def _fit_student_t(s):
    # Method of moments: excess kurtosis of t_nu is 6/(nu-4).
    nu = 4.0 + 6.0 / jnp.maximum(s["kurt_ex"], 0.03)
    nu = jnp.clip(nu, 2.1, 200.0)
    scale = jnp.sqrt(jnp.maximum(s["var"] * (nu - 2.0) / nu, _EPS))
    p = jnp.stack([s["mean"], scale, nu], 1)
    return p, jnp.ones_like(nu, bool)


def _cdf_student_t(p, x):
    loc, scale, nu = p[:, 0:1], p[:, 1:2], p[:, 2:3]
    z = (x - loc) / scale
    w = nu / (nu + z * z)
    tail = 0.5 * betainc(nu * 0.5, 0.5, w)
    return jnp.where(z < 0.0, tail, 1.0 - tail)


def _fit_weibull(s):
    mean = jnp.maximum(s["mean"], _EPS)
    cv = jnp.maximum(s["std"], _EPS) / mean
    # Justus (1978) approximation for the shape parameter.
    k = jnp.clip(cv ** (-1.086), 0.05, 50.0)
    lam = mean / jnp.exp(gammaln(1.0 + 1.0 / k))
    p = jnp.stack([k, jnp.maximum(lam, _EPS), jnp.zeros_like(k)], 1)
    return p, s["min"] >= 0.0


def _cdf_weibull(p, x):
    k, lam = p[:, 0:1], p[:, 1:2]
    return 1.0 - jnp.exp(-jnp.power(jnp.maximum(x, 0.0) / lam, k))


_FITTERS = {
    "normal": (_fit_normal, _cdf_normal),
    "uniform": (_fit_uniform, _cdf_uniform),
    "exponential": (_fit_exponential, _cdf_exponential),
    "lognormal": (_fit_lognormal, _cdf_lognormal),
    "cauchy": (_fit_cauchy, _cdf_cauchy),
    "gamma": (_fit_gamma, _cdf_gamma),
    "geometric": (_fit_geometric, _cdf_geometric),
    "logistic": (_fit_logistic, _cdf_logistic),
    "student_t": (_fit_student_t, _cdf_student_t),
    "weibull": (_fit_weibull, _cdf_weibull),
}


# ---------------------------------------------------------------------------
# Eq. 5 error
# ---------------------------------------------------------------------------


def interval_edges(mn: jax.Array, mx: jax.Array, n_bins: int) -> jax.Array:
    """(B,) min/max -> (B, L+1) equal-width interval edges (Eq. 5)."""
    frac = jnp.arange(n_bins + 1, dtype=jnp.float32) / float(n_bins)
    return mn[:, None] + (mx - mn)[:, None] * frac[None, :]


def eq5_error(hist: jax.Array, cdf_at_edges: jax.Array, n_obs: int) -> jax.Array:
    """Eq. 5: sum_k |Freq_k/N - (CDF(e_k) - CDF(e_{k-1}))| per point."""
    probs = cdf_at_edges[:, 1:] - cdf_at_edges[:, :-1]
    freq = hist / float(n_obs)
    return jnp.sum(jnp.abs(freq - probs), axis=1)


def fit_one_type(
    type_name: str,
    stats: dict,
    hist: jax.Array,
    edges: jax.Array,
    n_obs: int,
):
    """Fit one distribution type; returns (error (B,), params (B,3))."""
    fit_fn, cdf_fn = _FITTERS[type_name]
    params, supported = fit_fn(stats)
    cdf = cdf_fn(params, edges)
    err = eq5_error(hist, cdf, n_obs)
    err = jnp.where(supported, err, PENALTY_ERROR)
    return err, params


# ---------------------------------------------------------------------------
# Graph builders (these become the AOT artifacts)
# ---------------------------------------------------------------------------


def _prep(values: jax.Array, n_bins: int, use_pallas: bool):
    stats = sufficient_stats(values, use_pallas=use_pallas)
    if use_pallas:
        hist = histogram(values, stats["min"], stats["max"], n_bins=n_bins)
    else:
        hist = kref.histogram_ref(values, stats["min"], stats["max"], n_bins)
    edges = interval_edges(stats["min"], stats["max"], n_bins)
    return stats, hist, edges


def fit_single(
    values: jax.Array,
    type_name: str,
    n_bins: int = DEFAULT_BINS,
    use_pallas: bool = True,
) -> jax.Array:
    """ML-path artifact body: fit exactly one type. (B,N) -> (B,4).

    Output columns: [error, p0, p1, p2].
    """
    _, n = values.shape
    stats, hist, edges = _prep(values, n_bins, use_pallas)
    err, params = fit_one_type(type_name, stats, hist, edges, n)
    return jnp.concatenate([err[:, None], params], axis=1)


def fit_all(
    values: jax.Array,
    n_types: int = 4,
    n_bins: int = DEFAULT_BINS,
    use_pallas: bool = True,
) -> jax.Array:
    """Baseline/Grouping artifact body: fit the first ``n_types`` candidate
    types and keep the minimum-error one (paper Algorithm 3). (B,N) -> (B,5).

    Output columns: [best_type_id, error, p0, p1, p2].
    """
    _, n = values.shape
    stats, hist, edges = _prep(values, n_bins, use_pallas)
    errs, params = [], []
    for t in TYPES[:n_types]:
        e, p = fit_one_type(t, stats, hist, edges, n)
        errs.append(e)
        params.append(p)
    err_mat = jnp.stack(errs, axis=1)              # (B, T)
    par_mat = jnp.stack(params, axis=1)            # (B, T, 3)
    best = jnp.argmin(err_mat, axis=1)             # (B,)
    best_err = jnp.take_along_axis(err_mat, best[:, None], axis=1)[:, 0]
    best_par = jnp.take_along_axis(par_mat, best[:, None, None], axis=1)[:, 0, :]
    return jnp.concatenate(
        [best.astype(jnp.float32)[:, None], best_err[:, None], best_par], axis=1
    )


# Column order of the stats artifact, mirrored by rust/src/runtime/manifest.rs.
STATS_COLS = [
    "mean",
    "std",
    "min",
    "max",
    "skew",
    "kurt_ex",
    "meanlog",
    "stdlog",
    "q25",
    "q50",
    "q75",
    "pos_frac",
]


def point_stats(values: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Data-loading artifact body (paper Algorithm 2 pre-processing).

    (B, N) -> (B, 12) with STATS_COLS columns.
    """
    s = sufficient_stats(values, use_pallas=use_pallas)
    return jnp.stack([s[c] for c in STATS_COLS], axis=1)
