"""L2 graph catalog: named builders for every AOT artifact.

Each entry maps an artifact name to a single-input jax function over a
``(batch, obs)`` f32 array. ``aot.py`` lowers each to HLO text; rust's
``runtime::manifest`` resolves artifacts by the same names.

Artifact naming scheme::

    stats_{B}x{N}                 point statistics (loading / grouping / ML features)
    fit_single_{type}_{B}x{N}     one-type fit (ML path)
    fit_all4_{B}x{N}              4-types argmin fit (Baseline / Grouping)
    fit_all10_{B}x{N}             10-types argmin fit
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import distfit


@dataclass(frozen=True)
class GraphSpec:
    """One AOT artifact: a named jax function plus its input/output shapes."""

    name: str
    fn: object          # callable (values,) -> array
    batch: int
    obs: int
    out_cols: int
    kind: str           # "stats" | "fit_single" | "fit_all"
    type_name: str | None = None   # for fit_single
    n_types: int | None = None     # for fit_all

    @property
    def in_shape(self):
        return (self.batch, self.obs)

    @property
    def out_shape(self):
        return (self.batch, self.out_cols)


def build_specs(
    batch: int,
    obs: int,
    types: list[str] | None = None,
    use_pallas: bool = True,
    n_bins: int = distfit.DEFAULT_BINS,
) -> list[GraphSpec]:
    """All artifacts for one (batch, obs) configuration."""
    types = types if types is not None else distfit.TYPES
    tag = f"{batch}x{obs}"
    specs = [
        GraphSpec(
            name=f"stats_{tag}",
            fn=functools.partial(distfit.point_stats, use_pallas=use_pallas),
            batch=batch,
            obs=obs,
            out_cols=len(distfit.STATS_COLS),
            kind="stats",
        )
    ]
    for t in types:
        specs.append(
            GraphSpec(
                name=f"fit_single_{t}_{tag}",
                fn=functools.partial(
                    distfit.fit_single, type_name=t, n_bins=n_bins, use_pallas=use_pallas
                ),
                batch=batch,
                obs=obs,
                out_cols=4,
                kind="fit_single",
                type_name=t,
            )
        )
    for n_types in (4, 10):
        specs.append(
            GraphSpec(
                name=f"fit_all{n_types}_{tag}",
                fn=functools.partial(
                    distfit.fit_all, n_types=n_types, n_bins=n_bins, use_pallas=use_pallas
                ),
                batch=batch,
                obs=obs,
                out_cols=5,
                kind="fit_all",
                n_types=n_types,
            )
        )
    return specs


def lower_spec(spec: GraphSpec):
    """jit+lower one artifact graph (single (B,N) f32 input, tuple output)."""
    arg = jax.ShapeDtypeStruct(spec.in_shape, jnp.float32)

    def wrapped(values):
        return (spec.fn(values),)   # 1-tuple: rust unwraps with to_tuple1()

    return jax.jit(wrapped).lower(arg)
