"""L1 Pallas kernel: per-point equal-width histogram (Eq. 5 substrate).

Input  : values (B, N) f32, mn (B, 1) f32, mx (B, 1) f32
Output : counts (B, L) f32 — L equal-width bins spanning [mn, mx] per point
         (paper Eq. 5: intervals evenly split between per-point min and max;
          values landing exactly on max fall in the last bin).

Schedule: grid (B/bB, N/bN). Each block computes bucket indices, expands to
a one-hot (bB, bN, L) tensor and reduces over bN — on a TPU this reduction
is expressed as a (bN x L) matmul against a ones vector, i.e. the histogram
rides the MXU instead of scatter-adds (TPUs have no fast scatter); see
DESIGN.md §Hardware-Adaptation. Output blocks are revisited along j.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .moments import pick_block

DEFAULT_BINS = 32


def _hist_kernel(v_ref, mn_ref, mx_ref, o_ref, *, n_bins: int):
    j = pl.program_id(1)
    v = v_ref[...]                       # (bB, bN)
    mn = mn_ref[...]                     # (bB, 1)
    mx = mx_ref[...]
    rng = jnp.maximum(mx - mn, 1e-30)
    idx = jnp.floor((v - mn) / rng * n_bins)
    idx = jnp.clip(idx, 0.0, float(n_bins - 1)).astype(jnp.int32)
    # One-hot + reduce == (bN, L) matmul with a ones vector on the MXU.
    one_hot = (idx[:, :, None] == jnp.arange(n_bins, dtype=jnp.int32)[None, None, :])
    counts = jnp.sum(one_hot.astype(jnp.float32), axis=1)  # (bB, L)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = counts

    @pl.when(j > 0)
    def _accumulate():
        o_ref[...] += counts


@functools.partial(jax.jit, static_argnames=("n_bins", "block_b", "block_n"))
def histogram(
    values: jax.Array,
    mn: jax.Array,
    mx: jax.Array,
    n_bins: int = DEFAULT_BINS,
    block_b: int = 32,
    block_n: int = 1024,
) -> jax.Array:
    """Per-point histogram via the Pallas kernel.

    ``mn``/``mx`` may be (B,) or (B, 1); they are broadcast per point.
    """
    b, n = values.shape
    mn = mn.reshape(b, 1).astype(jnp.float32)
    mx = mx.reshape(b, 1).astype(jnp.float32)
    bb = pick_block(b, block_b)
    bn = pick_block(n, block_n)
    kernel = functools.partial(_hist_kernel, n_bins=n_bins)
    return pl.pallas_call(
        kernel,
        grid=(b // bb, n // bn),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n_bins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_bins), jnp.float32),
        interpret=True,  # CPU PJRT; see module docstring
    )(values, mn, mx)
