"""L1 Pallas kernel: per-point sufficient statistics over observation vectors.

Input  : values  (B, N) f32 — B points, N observations each.
Output : stats   (B, 8) f32 — per point:
           [0] sum v      [1] sum v^2    [2] sum v^3   [3] sum v^4
           [4] min v      [5] max v      [6] sum log v [7] sum log^2 v
         (log sums are guarded: non-positive values contribute 0; the
          consumer checks min>0 before trusting columns 6/7.)

Schedule: grid (B/bB, N/bN); each (bB, bN) value block is staged into VMEM
by BlockSpec, reduced to a (bB, 8) partial, and accumulated into a
*revisited* output block (same output tile for every j) — the standard
revisited-output reduction pattern. On a real TPU this double-buffers the
HBM->VMEM stream along j; on this image it runs under interpret=True
(CPU PJRT cannot execute Mosaic custom-calls, see DESIGN.md §L1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column indices, shared with ref.py / distfit.py and mirrored in rust.
SUM, SUM2, SUM3, SUM4, MIN, MAX, SUMLOG, SUMLOG2 = range(8)
N_STATS = 8


def _block_stats(v: jax.Array) -> jax.Array:
    """Reduce one (bB, bN) block to (bB, 8) partial statistics."""
    v2 = v * v
    s1 = jnp.sum(v, axis=1)
    s2 = jnp.sum(v2, axis=1)
    s3 = jnp.sum(v2 * v, axis=1)
    s4 = jnp.sum(v2 * v2, axis=1)
    mn = jnp.min(v, axis=1)
    mx = jnp.max(v, axis=1)
    pos = v > 0.0
    lv = jnp.where(pos, jnp.log(jnp.where(pos, v, 1.0)), 0.0)
    sl = jnp.sum(lv, axis=1)
    sl2 = jnp.sum(lv * lv, axis=1)
    return jnp.stack([s1, s2, s3, s4, mn, mx, sl, sl2], axis=1)


def _moments_kernel(v_ref, o_ref):
    j = pl.program_id(1)
    bs = _block_stats(v_ref[...])

    @pl.when(j == 0)
    def _init():
        o_ref[...] = bs

    @pl.when(j > 0)
    def _accumulate():
        acc = o_ref[...]
        sums = acc[:, 0:4] + bs[:, 0:4]
        mn = jnp.minimum(acc[:, 4:5], bs[:, 4:5])
        mx = jnp.maximum(acc[:, 5:6], bs[:, 5:6])
        logs = acc[:, 6:8] + bs[:, 6:8]
        o_ref[...] = jnp.concatenate([sums, mn, mx, logs], axis=1)


def pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (VMEM-budget block picker)."""
    if n <= target:
        return n
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return 1


# Default blocks raised 8x512 -> 32x1024 after the perf pass: one grid
# step per row block (no revisited-output loop) cut kernel time ~2.3x in
# interpret mode while keeping the (32,1024)f32=128KiB block + scratch
# within a TPU core VMEM budget (EXPERIMENTS.md §Perf L1-1).
@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def moments(values: jax.Array, block_b: int = 32, block_n: int = 1024) -> jax.Array:
    """Per-point sufficient statistics via the Pallas reduction kernel."""
    b, n = values.shape
    bb = pick_block(b, block_b)
    bn = pick_block(n, block_n)
    return pl.pallas_call(
        _moments_kernel,
        grid=(b // bb, n // bn),
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bb, N_STATS), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_STATS), jnp.float32),
        interpret=True,  # CPU PJRT; see module docstring
    )(values)
