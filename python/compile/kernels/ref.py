"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: python/tests/test_kernels.py sweeps
shapes and data regimes with hypothesis and asserts the Pallas kernels
match these references to float tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .moments import N_STATS  # noqa: F401  (re-exported for tests)


def moments_ref(values: jax.Array) -> jax.Array:
    """Reference for kernels.moments.moments: (B, N) -> (B, 8)."""
    v = values.astype(jnp.float32)
    v2 = v * v
    pos = v > 0.0
    lv = jnp.where(pos, jnp.log(jnp.where(pos, v, 1.0)), 0.0)
    return jnp.stack(
        [
            jnp.sum(v, axis=1),
            jnp.sum(v2, axis=1),
            jnp.sum(v2 * v, axis=1),
            jnp.sum(v2 * v2, axis=1),
            jnp.min(v, axis=1),
            jnp.max(v, axis=1),
            jnp.sum(lv, axis=1),
            jnp.sum(lv * lv, axis=1),
        ],
        axis=1,
    )


def histogram_ref(values: jax.Array, mn: jax.Array, mx: jax.Array, n_bins: int) -> jax.Array:
    """Reference for kernels.histogram.histogram: (B, N) -> (B, L)."""
    b, _ = values.shape
    v = values.astype(jnp.float32)
    mn = mn.reshape(b, 1).astype(jnp.float32)
    mx = mx.reshape(b, 1).astype(jnp.float32)
    rng = jnp.maximum(mx - mn, 1e-30)
    idx = jnp.clip(jnp.floor((v - mn) / rng * n_bins), 0.0, float(n_bins - 1)).astype(jnp.int32)
    one_hot = idx[:, :, None] == jnp.arange(n_bins, dtype=jnp.int32)[None, None, :]
    return jnp.sum(one_hot.astype(jnp.float32), axis=1)
