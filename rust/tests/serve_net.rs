//! End-to-end tests of the socket serving front: every request class
//! round-tripped over a real loopback TCP connection must be
//! bit-identical to a direct in-process submission; overload must shed
//! with a *typed* wire reply that leaves the connection usable; and the
//! generation-stamped result cache must serve bit-identical hits and
//! flush wholesale on every event that could change an answer
//! (rerun-appended generation, compaction, scrub repair, mid-serve
//! quarantine).
//!
//! Tests share the process-global telemetry registry, so they serialize
//! on one mutex and assert on per-front stats or counter deltas only.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::PointId;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::pdfstore::{
    compact_run, scrub_store, QueryEngine, QueryOptions, RegionQuery, RunSelector,
};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::serve::net::{closed_loop_net, Client, NetOptions, NetServer};
use pdfflow::serve::{Class, Request, ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};

fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("native backend")
}

fn root_dir(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("pdfflow-servenet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn pipeline_cfg(store_dir: &Path, run_id: &str) -> PipelineConfig {
    PipelineConfig {
        batch: 64,
        window_lines: 4,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        run_id: Some(run_id.to_string()),
        ..PipelineConfig::default()
    }
}

/// Persist `slices` of the tiny dataset under run `run_id`, `reruns + 1`
/// generations each.
fn build_store(root: &Path, run_id: &str, slices: &[usize], reruns: usize) -> SyntheticDataset {
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&root.join("store"), run_id),
    );
    for _ in 0..=reruns {
        for &z in slices {
            pipe.run_slice(Method::Baseline, z, TypeSet::Four).unwrap();
        }
    }
    ds
}

fn open_engine(store: &Path, run: Option<&str>) -> QueryEngine {
    QueryEngine::open_run(store, RunSelector::from_opt(run), QueryOptions::default()).unwrap()
}

/// One request per class (diff included; callers without a diff engine
/// drop the last element).
fn all_class_requests(engine: &QueryEngine) -> Vec<Request> {
    let dims = engine.dims();
    let region = RegionQuery {
        z: 1,
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
    };
    let bx = BoxQuery {
        x0: 0,
        x1: dims.nx - 1,
        y0: 0,
        y1: dims.ny - 1,
        z0: 1,
        z1: 2,
    };
    vec![
        Request::Point(PointId(dims.slice_points() as u64 + 3)),
        Request::Region(region),
        Request::QuantileMean(region, 0.5),
        Request::Box(bx),
        Request::Radius(RadiusQuery {
            x: dims.nx / 2,
            y: dims.ny / 2,
            z: 1,
            radius: 2.0,
        }),
        Request::Knn(KnnQuery {
            x: 1,
            y: 2,
            z: 1,
            k: 7,
        }),
        Request::DiffRun(bx),
    ]
}

#[test]
fn wire_replies_match_direct_submission_bit_for_bit() {
    let _g = gate();
    let root = root_dir("parity");
    build_store(&root, "t", &[1, 2], 0);
    let store = root.join("store");
    // Second run for the diff class.
    {
        let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data-u")).unwrap();
        let backend = backend();
        let mut pipe = Pipeline::new(
            &ds,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            pipeline_cfg(&store, "u"),
        );
        pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
        pipe.run_slice(Method::Baseline, 2, TypeSet::Four).unwrap();
    }
    let engine = open_engine(&store, Some("t"));
    let requests = all_class_requests(&engine);
    let front = Arc::new(
        ServeFront::new(
            engine,
            ServeOptions {
                max_in_flight: 4,
                queue_depth: 8,
            },
        )
        .with_diff(open_engine(&store, Some("u"))),
    );
    let server = NetServer::start(
        Arc::clone(&front),
        "127.0.0.1:0",
        NetOptions {
            workers: 2,
            queue_depth: 8,
        },
    )
    .unwrap();
    let addr = server.addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    let meta = client.meta().unwrap();
    assert_eq!(meta.dims, front.engine().dims());
    assert_eq!(meta.slices, front.engine().store().slices());

    for req in requests {
        // Wire first (computed, inserted into the result cache), then
        // direct (served from cache): one pass checks transport
        // fidelity *and* cache coherence against the same reply.
        let wire = client.query(&req).unwrap();
        let direct = front.submit(req).unwrap();
        assert_eq!(
            format!("{:?}", wire.reply),
            format!("{:?}", direct.reply),
            "wire reply for {req:?} differs from direct submission"
        );
        assert_eq!(wire.degraded, direct.degraded);
        assert!(!wire.degraded, "healthy store must not serve degraded");
    }
    let stats = front.result_cache().unwrap().stats();
    assert!(stats.hits >= 7, "direct submissions should hit the cache, got {stats:?}");
    server.join();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn overload_sheds_typed_on_wire_and_connection_stays_usable() {
    let _g = gate();
    let root = root_dir("shed");
    build_store(&root, "t", &[1], 0);
    let engine = open_engine(&root.join("store"), None);
    let point = Request::Point(PointId(engine.dims().slice_points() as u64));
    let region = Request::Region(RegionQuery::slice(&engine.dims(), 1));
    let front = Arc::new(ServeFront::new(
        engine,
        ServeOptions {
            max_in_flight: 1,
            queue_depth: 1,
        },
    ));
    // workers: 0 — every query frame sheds at the dispatch queue, which
    // makes the typed-shed wire path deterministic.
    let server = NetServer::start(
        Arc::clone(&front),
        "127.0.0.1:0",
        NetOptions {
            workers: 0,
            queue_depth: 1,
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.addr().to_string()).unwrap();

    let err = client.query(&point).unwrap_err();
    assert!(err.is_overload(), "expected typed shed, got {err:?}");
    let err = client.query(&region).unwrap_err();
    assert!(err.is_overload(), "connection must stay usable after a shed");
    // Control frames still answered after sheds.
    assert!(!client.meta().unwrap().slices.is_empty());

    // Socket sheds land in the same per-class ledger as gate sheds.
    let m = front.metrics();
    assert_eq!(m.class(Class::Point).shed, 1);
    assert_eq!(m.class(Class::Region).shed, 1);
    assert_eq!(m.class(Class::Point).admitted, 0, "shed requests never enter the gate");
    server.join();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn result_cache_hits_are_bit_identical_and_metered() {
    let _g = gate();
    let root = root_dir("cachehit");
    build_store(&root, "t", &[1], 0);
    let engine = open_engine(&root.join("store"), None);
    let req = Request::Region(RegionQuery::slice(&engine.dims(), 1));
    let front = ServeFront::new(engine, ServeOptions::default());

    let first = front.submit(req).unwrap();
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.hits, 0);
    assert_eq!(stats.entries, 1);

    let second = front.submit(req).unwrap();
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.hits, 1, "repeat of an identical request must hit");
    assert_eq!(
        format!("{:?}", first.reply),
        format!("{:?}", second.reply),
        "cached reply differs from computed reply"
    );
    // The ledger counts hits as admitted + completed.
    let m = front.metrics();
    assert_eq!(m.class(Class::Region).admitted, 2);
    assert_eq!(m.class(Class::Region).completed, 2);

    // Disabling the cache really disables it.
    let engine = open_engine(&root.join("store"), None);
    let off = ServeFront::new(engine, ServeOptions::default()).with_result_cache(0);
    assert!(off.result_cache().is_none());
    off.submit(req).unwrap();
    off.submit(req).unwrap();
    assert_eq!(off.metrics().class(Class::Region).completed, 2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn rerun_and_compact_invalidate_the_result_cache_wholesale() {
    let _g = gate();
    let root = root_dir("swap");
    let ds = build_store(&root, "t", &[1], 0);
    let store = root.join("store");
    let engine = open_engine(&store, None);
    let req = Request::Region(RegionQuery::slice(&engine.dims(), 1));
    let front = ServeFront::new(engine, ServeOptions::default());

    let baseline = front.submit(req).unwrap();
    front.submit(req).unwrap();
    assert_eq!(front.result_cache().unwrap().stats().hits, 1);

    // A rerun appends generation g1 and atomically swaps CATALOG.json —
    // the stamp moves, the next lookup flushes wholesale.
    {
        let backend = backend();
        let mut pipe = Pipeline::new(
            &ds,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            pipeline_cfg(&store, "t"),
        );
        pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    }
    let after_rerun = front.submit(req).unwrap();
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.invalidations, 1, "catalog swap must flush the cache");
    // The deterministic rerun shadows g0 with identical records, so the
    // recomputed answer matches bit for bit.
    assert_eq!(format!("{:?}", after_rerun.reply), format!("{:?}", baseline.reply));

    // Warm the cache again, then compact: another swap, another flush.
    front.submit(req).unwrap();
    compact_run(&store, None).unwrap();
    let after_compact = front.submit(req).unwrap();
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.invalidations, 2, "compaction must flush the cache");
    assert_eq!(format!("{:?}", after_compact.reply), format!("{:?}", baseline.reply));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn quarantine_and_scrub_repair_invalidate_and_degraded_is_never_cached() {
    let _g = gate();
    let root = root_dir("degraded");
    build_store(&root, "t", &[1], 1); // two generations: g1 shadows g0
    let store = root.join("store");
    let newest = store.join("slice1_baseline_4_t_g1.seg");
    let len = std::fs::metadata(&newest).unwrap().len() as usize;
    let mut bytes = std::fs::read(&newest).unwrap();
    bytes[len / 3] ^= 0x01;
    std::fs::write(&newest, bytes).unwrap();

    let engine = open_engine(&store, None);
    let point = Request::Point(PointId(engine.dims().slice_points() as u64 + 2));
    let front = ServeFront::new(engine, ServeOptions::default());

    // First touch quarantines mid-serve and answers from g0, flagged.
    let served = front.submit(point).unwrap();
    assert!(served.degraded, "fallback answer must be flagged");
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.entries, 0, "degraded replies must never be cached");

    // The quarantine bumped the epoch → stamp moved → wholesale flush
    // on the next lookup; repeats stay misses (still degraded).
    let again = front.submit(point).unwrap();
    assert!(again.degraded);
    let stats = front.result_cache().unwrap().stats();
    assert!(stats.invalidations >= 1, "quarantine must flush the cache, got {stats:?}");
    assert_eq!(stats.hits, 0, "degraded replies must never be served from cache");
    assert_eq!(format!("{:?}", again.reply), format!("{:?}", served.reply));

    // Scrub --repair rewrites the survivors into a fresh generation and
    // swaps the catalog: stamp moves again, and once the front reopens
    // the repaired store, replies are undegraded and cacheable again.
    let report = scrub_store(&store, true).unwrap();
    assert!(report.runs[0].repaired);
    let inv_before = front.result_cache().unwrap().stats().invalidations;
    let _ = front.submit(point); // old handles may or may not still resolve; only the flush matters
    assert!(
        front.result_cache().unwrap().stats().invalidations > inv_before,
        "scrub repair must flush the cache"
    );

    let engine = open_engine(&store, None);
    let repaired_front = ServeFront::new(engine, ServeOptions::default());
    let healed = repaired_front.submit(point).unwrap();
    assert!(!healed.degraded, "repaired store must serve undegraded");
    assert_eq!(format!("{:?}", healed.reply), format!("{:?}", served.reply));
    repaired_front.submit(point).unwrap();
    assert_eq!(repaired_front.result_cache().unwrap().stats().hits, 1);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn socket_closed_loop_accounts_every_request_and_shuts_down_cleanly() {
    let _g = gate();
    let root = root_dir("loop");
    build_store(&root, "t", &[1, 2], 0);
    let engine = open_engine(&root.join("store"), None);
    let front = Arc::new(ServeFront::new(
        engine,
        ServeOptions {
            max_in_flight: 2,
            queue_depth: 4,
        },
    ));
    let server = NetServer::start(
        Arc::clone(&front),
        "127.0.0.1:0",
        NetOptions {
            workers: 2,
            queue_depth: 4,
        },
    )
    .unwrap();
    let addr = server.addr().to_string();

    let rep = closed_loop_net(&addr, 3, 25, 11).unwrap();
    assert_eq!(rep.requests, 75);
    assert_eq!(
        rep.completed + rep.shed + rep.errors,
        rep.requests,
        "every socket request must be accounted: {rep:?}"
    );
    assert!(rep.completed > 0, "closed loop made no progress: {rep:?}");
    // Server-side ledger agrees with the client-side view.
    let m = front.metrics();
    let total = m.total_completed() + m.total_shed();
    assert!(total >= rep.requests, "server ledger lost requests: {m:?} vs {rep:?}");

    // Graceful wire shutdown: ack arrives, threads drain and join.
    Client::connect(&addr).unwrap().shutdown_server().unwrap();
    server.wait();
    std::fs::remove_dir_all(&root).unwrap();
}
