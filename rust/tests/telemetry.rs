//! Integration contract of the telemetry layer: histogram algebra,
//! span nesting/ordering determinism across pool widths, the flight
//! recorder's panic dump, and the exported-snapshot schema.
//!
//! Tests that touch process-global state (the trace gate, the flight
//! ring, the dump dir) serialize through [`gate`] — the ring is one per
//! process and `cargo test` runs tests concurrently.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

use pdfflow::executor::Executor;
use pdfflow::telemetry::{self, export, flight, hist, Histogram, Registry, Span};
use pdfflow::util::json::Json;

fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn hist_of(vals: &[u64]) -> Histogram {
    let h = Histogram::new();
    for &v in vals {
        h.record(v);
    }
    h
}

#[test]
fn histogram_buckets_contain_their_values_and_quantiles_order() {
    let vals: Vec<u64> = (0..12).map(|k| 3u64.pow(k)).collect();
    let h = hist_of(&vals);
    assert_eq!(h.count(), vals.len() as u64);
    assert_eq!(h.sum(), vals.iter().sum::<u64>());
    assert_eq!(h.min(), Some(1));
    assert_eq!(h.max(), *vals.last().unwrap());
    for &v in &vals {
        let (lo, hi) = hist::bucket_bounds(hist::bucket_index(v));
        assert!(lo <= v && v <= hi, "value {v} outside its bucket [{lo},{hi}]");
    }
    // Quantiles are monotone, end at the exact max, and each sits within
    // the 1/32 relative-error bound of a true order statistic.
    let mut prev = 0u64;
    for q in [0.0, 0.25, 0.50, 0.75, 0.95, 0.99, 1.0] {
        let v = h.quantile(q);
        assert!(v >= prev, "quantile({q}) = {v} < quantile(prev) = {prev}");
        prev = v;
    }
    assert_eq!(h.quantile(1.0), h.max());
    let p50 = h.quantile(0.50);
    let exact = vals[vals.len().div_ceil(2) - 1];
    assert!(
        p50 >= exact && p50 - exact <= exact / 32 + 1,
        "p50 {p50} vs exact median {exact}"
    );
}

#[test]
fn histogram_merge_is_associative_and_commutative() {
    let a = hist_of(&[1, 2, 3, 100, 5_000]);
    let b = hist_of(&[7, 7, 7, 1 << 30]);
    let c = hist_of(&[0, u64::MAX, 42]);
    let left = Histogram::new(); // (a ∪ b) ∪ c
    left.merge(&a);
    left.merge(&b);
    left.merge(&c);
    let right = Histogram::new(); // b ∪ (c ∪ a), different grouping+order
    right.merge(&b);
    right.merge(&c);
    right.merge(&a);
    assert_eq!(left.nonzero_buckets(), right.nonzero_buckets());
    assert_eq!(left.count(), right.count());
    assert_eq!(left.sum(), right.sum());
    assert_eq!(left.min(), right.min());
    assert_eq!(left.max(), right.max());
    assert_eq!(left.count(), 12);
    // Saturating sum: u64::MAX is present, so the total pins at MAX
    // instead of wrapping into a small number.
    assert_eq!(left.sum(), u64::MAX);
}

/// One parallel pass: every item opens an outer span with a nested
/// inner span; returns the flight events our spans produced.
fn spanned_pass(width: usize, items: usize) -> Vec<flight::Event> {
    flight::take_events(); // start from an empty ring
    let exec = Executor::new(width);
    exec.run((0..items).collect::<Vec<_>>(), |i| {
        let _outer = Span::enter_with("tel.test.outer", || format!("item {i}"));
        let _inner = pdfflow::span!("tel.test.inner");
        std::hint::black_box(i * i)
    });
    flight::take_events()
        .into_iter()
        .filter(|e| e.name.starts_with("tel.test."))
        .collect()
}

#[test]
fn span_events_nest_and_match_across_pool_widths() {
    let _g = gate();
    telemetry::set_enabled(true);
    let items = 24usize;
    let mut per_width: Vec<Vec<String>> = Vec::new();
    for width in [1usize, 2, 8] {
        let mut events = spanned_pass(width, items);
        // Every span closed: 2 spans x (begin + end) per item.
        assert_eq!(events.len(), 4 * items, "width {width}: event count");
        // Seq is assigned before the ring lock, so ring order can lag it
        // slightly across threads; seq order is the canonical timeline
        // (and stays chronological within each thread).
        events.sort_by_key(|e| e.seq);
        let mut seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), events.len(), "global seq is unique");
        // Per-thread stack discipline: an End always closes the most
        // recent Begin on that thread, and inner nests inside outer.
        let mut stacks: BTreeMap<u64, Vec<&'static str>> = BTreeMap::new();
        for e in &events {
            let stack = stacks.entry(e.thread).or_default();
            match e.kind {
                flight::Kind::Begin => {
                    assert_eq!(e.depth as usize, stack.len(), "begin depth");
                    if e.name == "tel.test.inner" {
                        assert_eq!(stack.last(), Some(&"tel.test.outer"), "inner outside outer");
                    }
                    stack.push(e.name);
                }
                flight::Kind::End => {
                    assert_eq!(stack.pop(), Some(e.name), "end closes wrong span");
                    assert_eq!(e.depth as usize, stack.len(), "end depth");
                }
                flight::Kind::Mark => unreachable!("no marks emitted"),
            }
        }
        assert!(stacks.values().all(|s| s.is_empty()), "unclosed spans");
        // The work itself — which items ran, under which labels — is
        // width-invariant even though interleaving is not.
        let mut details: Vec<String> = events
            .iter()
            .filter_map(|e| e.detail.clone())
            .collect();
        details.sort();
        per_width.push(details);
    }
    assert_eq!(per_width[0].len(), items);
    assert!(
        per_width.iter().all(|d| *d == per_width[0]),
        "span details diverge across pool widths"
    );
    // Closed spans also landed in the registry's span histograms.
    let h = Registry::global().histogram("span.tel.test.inner.ns");
    assert!(h.count() >= 3 * items as u64, "span histogram undercounts");
}

#[test]
fn flight_recorder_dumps_parseable_json_on_panic() {
    let _g = gate();
    telemetry::set_enabled(true);
    let dir = std::env::temp_dir().join(format!("pdfflow-flightrec-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    flight::set_dump_dir(&dir);
    flight::install_crash_hook();
    let caught = std::panic::catch_unwind(|| {
        let _s = pdfflow::span!("tel.test.crash", "about to die");
        panic!("injected crash");
    });
    assert!(caught.is_err(), "the injected panic must propagate");
    // Leave later (unrelated) test panics without a hooked dump.
    flight::arm(false);
    flight::set_dump_dir(".");
    let dump = std::fs::read_dir(&dir)
        .expect("dump dir exists")
        .filter_map(|e| e.ok())
        .find(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.starts_with("flightrec-") && n.ends_with(".json")
        })
        .expect("a flightrec-<ts>.json dump was written");
    let text = std::fs::read_to_string(dump.path()).expect("readable dump");
    let j = Json::parse(&text).expect("dump parses as JSON");
    assert_eq!(
        j.get("schema").and_then(|s| s.as_str()),
        Some("pdfflow.flightrec.v1")
    );
    assert_eq!(j.get("reason").and_then(|s| s.as_str()), Some("panic"));
    let events = j.get("events").and_then(|e| e.as_arr()).expect("events array");
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("tel.test.crash")
                && e.get("detail").and_then(|d| d.as_str()) == Some("about to die")
        }),
        "the in-flight span at panic time is in the dump"
    );
    assert!(j.get("metrics").is_some(), "dump carries a metrics snapshot");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn exported_snapshot_validates_and_corruption_is_rejected() {
    let _g = gate();
    Registry::global().counter("tel.test.export.count").add(3);
    Registry::global().set_gauge("tel.test.export.gauge", 1.5);
    Registry::global()
        .histogram("tel.test.export.hist")
        .record(1234);
    let snap = export::snapshot();
    let n = export::validate_snapshot(&snap).expect("fresh snapshot validates");
    assert!(n >= 3, "snapshot holds at least the metrics just registered");
    // The same document survives a print → parse round trip.
    let reparsed = Json::parse(&snap.to_string()).expect("snapshot reparses");
    assert_eq!(export::validate_snapshot(&reparsed).expect("reparsed ok"), n);

    // Corruption 1: wrong schema tag.
    let Json::Obj(mut m) = reparsed.clone() else { panic!("snapshot is an object") };
    m.insert("schema".into(), Json::Str("bogus.v0".into()));
    assert!(export::validate_snapshot(&Json::Obj(m)).is_err());

    // Corruption 2: a histogram whose bucket counts disagree with count.
    let Json::Obj(mut m) = reparsed.clone() else { panic!() };
    let Some(Json::Obj(metrics)) = m.get_mut("metrics") else { panic!() };
    let Some(Json::Obj(h)) = metrics.get_mut("tel.test.export.hist") else {
        panic!("exported histogram present")
    };
    h.insert("count".into(), Json::Num(999.0));
    assert!(export::validate_snapshot(&Json::Obj(m)).is_err());

    // Corruption 3: provenance missing.
    let Json::Obj(mut m) = reparsed else { panic!() };
    m.remove("provenance");
    assert!(export::validate_snapshot(&Json::Obj(m)).is_err());

    // The Prometheus rendering carries the same families, sanitized.
    let prom = export::prometheus();
    assert!(prom.contains("pdfflow_tel_test_export_count 3"));
    assert!(prom.contains("# TYPE pdfflow_tel_test_export_hist histogram"));
    assert!(prom.contains("pdfflow_tel_test_export_hist_count 1"));
}

#[test]
fn write_metrics_emits_both_formats() {
    let _g = gate();
    Registry::global().counter("tel.test.write.count").inc();
    let dir = std::env::temp_dir().join(format!("pdfflow-metrics-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (json_path, prom_path) =
        export::write_metrics(dir.join("metrics.json")).expect("write_metrics");
    assert_eq!(prom_path, dir.join("metrics.json.prom"));
    let j = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).expect("json parses");
    export::validate_snapshot(&j).expect("written snapshot validates");
    let prom = std::fs::read_to_string(&prom_path).unwrap();
    assert!(prom.contains("pdfflow_tel_test_write_count"));
    std::fs::remove_dir_all(&dir).unwrap();
}
