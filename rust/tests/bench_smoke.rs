//! Tier-1 smoke recording of the perf trajectory: tiny versions of the
//! pipeline and queries benches that run inside `cargo test`, write
//! `BENCH_pipeline.json` / `BENCH_queries.json` at the repo root in the
//! shared schema `{bench, config, rows: [{threads, throughput}]}`, and
//! then validate what landed through the shared
//! `bench::validate_bench_json` checker — an empty or schema-violating
//! rows array **fails the tier**, so the trajectory files always carry
//! usable points. The queries record additionally carries a serving
//! row (`mode: "serve"`): closed-loop throughput through the
//! admission-controlled `ServeFront`. The numbers are smoke-grade (the
//! test harness runs other suites concurrently) — `cargo bench --bench
//! pipeline/queries -- --json` rewrites the files with proper
//! measurements — but they keep the trajectory populated on every
//! machine the tier-1 suite touches.

use std::time::Instant;

use pdfflow::bench::{validate_bench_json, write_bench_json, BenchRow};
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::{CubeDims, PointId};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::executor::Executor;
use pdfflow::pdfstore::{QueryEngine, QueryOptions};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::serve::{closed_loop, ServeFront, ServeOptions};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn native_backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            workers: 1,
            ..BackendOptions::default()
        },
    )
    .expect("backend")
}

/// Shared-schema validation of a written record; returns the rows.
/// `validate_bench_json` rejects empty rows and malformed fields, so a
/// bench that recorded nothing usable fails loudly here.
fn check_schema(name: &str) -> Vec<Json> {
    validate_bench_json(name).expect("bench record validates against the shared schema")
}

#[test]
fn records_pipeline_bench_json() {
    let root = std::env::temp_dir().join(format!("pdfflow-benchsmoke-p-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(32, 16, 4);
    spec.n_sims = 120;
    spec.seed = 20180601;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let n_windows = spec.dims.ny.div_ceil(4);

    let run_once = |threads: usize| -> f64 {
        let backend = native_backend();
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: threads,
            cache_bytes: 0,
            ..PipelineConfig::default()
        };
        let mut pipe = Pipeline::new(
            &ds,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            cfg,
        );
        let t0 = Instant::now();
        pipe.run_slice(Method::Baseline, 2, TypeSet::Four).expect("run");
        t0.elapsed().as_secs_f64()
    };
    let _ = run_once(1); // warm-up

    let rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            let secs = run_once(threads);
            BenchRow {
                threads,
                throughput: n_windows as f64 / secs,
                extra: vec![("secs", Json::Num(secs))],
            }
        })
        .collect();
    write_bench_json(
        "pipeline",
        vec![
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("windows_per_s".into())),
            ("windows", Json::Num(n_windows as f64)),
            ("observations", Json::Num(spec.n_sims as f64)),
            ("backend_workers", Json::Num(1.0)),
            ("window_lines", Json::Num(4.0)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_pipeline.json");

    let rows = check_schema("pipeline");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn records_queries_bench_json() {
    let root = std::env::temp_dir().join(format!("pdfflow-benchsmoke-q-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store_dir = root.join("store");
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(32, 16, 4);
    spec.seed = 20180599;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let backend = native_backend();
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        ..PipelineConfig::default()
    };
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    pipe.run_slice(Method::Baseline, 2, TypeSet::Four).expect("persist");

    let engine = QueryEngine::open(&store_dir, QueryOptions::default()).expect("open store");
    let slice_pts = spec.dims.slice_points() as u64;
    let n_queries = 3_000usize;
    let mut rng = Rng::new(7);
    let ids: Vec<PointId> = (0..n_queries)
        .map(|_| PointId(2 * slice_pts + rng.below(slice_pts as usize) as u64))
        .collect();

    let mut rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            engine.clear_cache();
            let exec = Executor::new(threads);
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<PointId>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            // One measurement pass: (xor-of-ids checksum, queries/s).
            let pass = || -> (u64, f64) {
                let t0 = Instant::now();
                let sum = exec
                    .run(chunks.clone(), |chunk| {
                        let mut acc = 0u64;
                        for id in chunk {
                            acc ^= engine.point_by_id(id).expect("point").point.0;
                        }
                        acc
                    })
                    .into_iter()
                    .fold(0, |a, b| a ^ b);
                (sum, n_queries as f64 / t0.elapsed().as_secs_f64())
            };
            let (cold, cold_qps) = pass();
            let (warm, warm_qps) = pass();
            assert_eq!(cold, warm, "cold/warm reads diverged");
            BenchRow {
                threads,
                throughput: warm_qps,
                extra: vec![("cold_qps", Json::Num(cold_qps))],
            }
        })
        .collect();

    // The serving row: closed-loop load through the admission-controlled
    // front door, recorded next to the raw engine rows (mode: "serve").
    let clients = 4usize;
    let front = ServeFront::new(
        QueryEngine::open(&store_dir, QueryOptions::default()).expect("open store for serving"),
        ServeOptions {
            max_in_flight: 2,
            queue_depth: 4,
        },
    );
    let load = closed_loop(&front, clients, 150, 11);
    assert!(
        load.metrics.total_completed() > 0,
        "serving tier completed no requests"
    );
    assert!(load.metrics.peak_in_flight <= 2, "in-flight cap violated");
    assert!(load.metrics.peak_queued <= 4, "queue-depth cap violated");
    rows.push(BenchRow {
        threads: clients,
        throughput: load.throughput,
        extra: vec![
            ("mode", Json::Str("serve".into())),
            ("shed", Json::Num(load.metrics.total_shed() as f64)),
            ("max_in_flight", Json::Num(2.0)),
            ("queue_depth", Json::Num(4.0)),
        ],
    });

    write_bench_json(
        "queries",
        vec![
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("warm_queries_per_s".into())),
            ("n_queries", Json::Num(n_queries as f64)),
            ("records", Json::Num(engine.store().n_records() as f64)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_queries.json");

    let rows = check_schema("queries");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let _ = std::fs::remove_dir_all(&root);
}
