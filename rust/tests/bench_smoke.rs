//! Tier-1 smoke recording of the perf trajectory: tiny versions of the
//! pipeline and queries benches that run inside `cargo test`, write
//! `BENCH_pipeline.json` / `BENCH_queries.json` at the repo root in the
//! shared schema `{bench, config, rows: [{threads, throughput}]}`, and
//! then validate the schema by re-parsing what they wrote. The numbers
//! are smoke-grade (the test harness runs other suites concurrently) —
//! `cargo bench --bench pipeline/queries -- --json` rewrites the files
//! with proper measurements — but they keep the trajectory populated on
//! every machine the tier-1 suite touches.

use std::time::Instant;

use pdfflow::bench::{bench_json_path, write_bench_json, BenchRow};
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::{CubeDims, PointId};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::executor::Executor;
use pdfflow::pdfstore::{QueryEngine, QueryOptions};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn native_backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            workers: 1,
            ..BackendOptions::default()
        },
    )
    .expect("backend")
}

/// Validate the shared schema of a written record and return the rows.
fn check_schema(name: &str) -> Vec<Json> {
    let path = bench_json_path(name);
    let text = std::fs::read_to_string(&path).expect("bench json readable");
    let doc = Json::parse(&text).expect("bench json parses");
    assert_eq!(doc.get("bench").and_then(|b| b.as_str()), Some(name));
    assert!(doc.get("config").is_some(), "{name}: config object");
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .unwrap_or_else(|| panic!("{name}: rows array"));
    assert!(!rows.is_empty(), "{name}: rows non-empty");
    for row in rows {
        assert!(row.get("threads").and_then(|t| t.as_f64()).is_some());
        assert!(row.get("throughput").and_then(|t| t.as_f64()).is_some());
    }
    rows.to_vec()
}

#[test]
fn records_pipeline_bench_json() {
    let root = std::env::temp_dir().join(format!("pdfflow-benchsmoke-p-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(32, 16, 4);
    spec.n_sims = 120;
    spec.seed = 20180601;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let n_windows = spec.dims.ny.div_ceil(4);

    let run_once = |threads: usize| -> f64 {
        let backend = native_backend();
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: threads,
            cache_bytes: 0,
            ..PipelineConfig::default()
        };
        let mut pipe = Pipeline::new(
            &ds,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            cfg,
        );
        let t0 = Instant::now();
        pipe.run_slice(Method::Baseline, 2, TypeSet::Four).expect("run");
        t0.elapsed().as_secs_f64()
    };
    let _ = run_once(1); // warm-up

    let rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            let secs = run_once(threads);
            BenchRow {
                threads,
                throughput: n_windows as f64 / secs,
                extra: vec![("secs", Json::Num(secs))],
            }
        })
        .collect();
    write_bench_json(
        "pipeline",
        vec![
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("windows_per_s".into())),
            ("windows", Json::Num(n_windows as f64)),
            ("observations", Json::Num(spec.n_sims as f64)),
            ("backend_workers", Json::Num(1.0)),
            ("window_lines", Json::Num(4.0)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_pipeline.json");

    let rows = check_schema("pipeline");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn records_queries_bench_json() {
    let root = std::env::temp_dir().join(format!("pdfflow-benchsmoke-q-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store_dir = root.join("store");
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(32, 16, 4);
    spec.seed = 20180599;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let backend = native_backend();
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        ..PipelineConfig::default()
    };
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    pipe.run_slice(Method::Baseline, 2, TypeSet::Four).expect("persist");

    let engine = QueryEngine::open(&store_dir, QueryOptions::default()).expect("open store");
    let slice_pts = spec.dims.slice_points() as u64;
    let n_queries = 3_000usize;
    let mut rng = Rng::new(7);
    let ids: Vec<PointId> = (0..n_queries)
        .map(|_| PointId(2 * slice_pts + rng.below(slice_pts as usize) as u64))
        .collect();

    let rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            engine.clear_cache();
            let exec = Executor::new(threads);
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<PointId>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            // One measurement pass: (xor-of-ids checksum, queries/s).
            let pass = || -> (u64, f64) {
                let t0 = Instant::now();
                let sum = exec
                    .run(chunks.clone(), |chunk| {
                        let mut acc = 0u64;
                        for id in chunk {
                            acc ^= engine.point_by_id(id).expect("point").point.0;
                        }
                        acc
                    })
                    .into_iter()
                    .fold(0, |a, b| a ^ b);
                (sum, n_queries as f64 / t0.elapsed().as_secs_f64())
            };
            let (cold, cold_qps) = pass();
            let (warm, warm_qps) = pass();
            assert_eq!(cold, warm, "cold/warm reads diverged");
            BenchRow {
                threads,
                throughput: warm_qps,
                extra: vec![("cold_qps", Json::Num(cold_qps))],
            }
        })
        .collect();
    write_bench_json(
        "queries",
        vec![
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("warm_queries_per_s".into())),
            ("n_queries", Json::Num(n_queries as f64)),
            ("records", Json::Num(engine.store().n_records() as f64)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_queries.json");

    let rows = check_schema("queries");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let _ = std::fs::remove_dir_all(&root);
}
