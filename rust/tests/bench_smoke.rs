//! Tier-1 smoke recording of the perf trajectory: tiny versions of the
//! pipeline and queries benches that run inside `cargo test`, write
//! `BENCH_pipeline.json` / `BENCH_queries.json` at the repo root in the
//! shared schema `{bench, config, rows: [{threads, throughput}]}`, and
//! then validate what landed through the shared
//! `bench::validate_bench_json` checker — an empty or schema-violating
//! rows array **fails the tier**, so the trajectory files always carry
//! usable points. Before rewriting, each test also rejects a
//! `"placeholder"` profile in the committed file: zero-throughput
//! stand-in records must never be checked in again now that real
//! baselines exist. The queries record additionally carries a serving
//! row (`mode: "serve"`) — closed-loop throughput through the
//! admission-controlled `ServeFront` — and spatial rows
//! (`mode: "spatial_box"` / `"spatial_radius"` / `"spatial_knn"`) from
//! the grid-indexed query tier, all over **one** store build via the
//! shared `bench::QueryStoreFixture`. The numbers are smoke-grade (the
//! test harness runs other suites concurrently) — `cargo bench --bench
//! pipeline/queries -- --json` rewrites the files with proper
//! measurements — but they keep the trajectory populated on every
//! machine the tier-1 suite touches.

use std::sync::Arc;
use std::time::Instant;

use pdfflow::bench::{
    committed_profile, validate_bench_json, write_bench_json, BenchRow, QueryStoreFixture,
};
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::CubeDims;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::executor::Executor;
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::serve::net::{closed_loop_net, NetOptions, NetServer};
use pdfflow::serve::{ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn native_backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            workers: 1,
            ..BackendOptions::default()
        },
    )
    .expect("backend")
}

/// The committed `BENCH_<name>.json` must never be a placeholder again:
/// this tier records real baselines on every run, so a zero-throughput
/// stand-in in the tree means someone reverted the trajectory.
fn reject_committed_placeholder(name: &str) {
    if let Some(profile) = committed_profile(name) {
        assert_ne!(
            profile, "placeholder",
            "committed BENCH_{name}.json carries a placeholder profile; \
             re-record it (cargo test, or cargo bench --bench {name} -- --json)"
        );
    }
}

/// Shared-schema validation of a written record; returns the rows.
/// `validate_bench_json` rejects empty rows and malformed fields, so a
/// bench that recorded nothing usable fails loudly here.
fn check_schema(name: &str) -> Vec<Json> {
    validate_bench_json(name).expect("bench record validates against the shared schema")
}

#[test]
fn records_pipeline_bench_json() {
    reject_committed_placeholder("pipeline");
    let root = std::env::temp_dir().join(format!("pdfflow-benchsmoke-p-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(32, 16, 4);
    spec.n_sims = 120;
    spec.seed = 20180601;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let n_windows = spec.dims.ny.div_ceil(4);

    let run_once = |threads: usize| -> f64 {
        let backend = native_backend();
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: threads,
            cache_bytes: 0,
            ..PipelineConfig::default()
        };
        let mut pipe = Pipeline::new(
            &ds,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            cfg,
        );
        let t0 = Instant::now();
        pipe.run_slice(Method::Baseline, 2, TypeSet::Four).expect("run");
        t0.elapsed().as_secs_f64()
    };
    let _ = run_once(1); // warm-up

    let mut rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            let secs = run_once(threads);
            BenchRow {
                threads,
                throughput: n_windows as f64 / secs,
                extra: vec![("secs", Json::Num(secs))],
            }
        })
        .collect();

    // Kernel micro-row: fused run_fit_all throughput with no pipeline
    // around it, so kernel-only changes stay visible separately from
    // the end-to-end windows/s trajectory.
    let kern_points = 1024usize;
    let kern_obs = spec.n_sims;
    let kern_types = 10usize;
    let kernel_fps = {
        let mut rng = Rng::new(20180602);
        let values: Vec<f32> = (0..kern_points * kern_obs)
            .map(|_| rng.gamma(3.0, 2.0) as f32)
            .collect();
        let backend = make_backend(BackendKind::Native, "artifacts", &BackendOptions::default())
            .expect("backend");
        backend
            .run_fit_all(&values, kern_points, kern_obs, kern_types)
            .expect("warm-up");
        let t0 = Instant::now();
        let reps = 2usize;
        for _ in 0..reps {
            backend
                .run_fit_all(&values, kern_points, kern_obs, kern_types)
                .expect("fit");
        }
        (reps * kern_points) as f64 / t0.elapsed().as_secs_f64()
    };
    rows.push(BenchRow {
        threads: pdfflow::runtime::hostpool::default_budget(),
        throughput: kernel_fps,
        extra: vec![
            ("mode", Json::Str("kernel".into())),
            ("unit", Json::Str("fit_points_per_s".into())),
            ("points", Json::Num(kern_points as f64)),
            ("obs", Json::Num(kern_obs as f64)),
            ("types", Json::Num(kern_types as f64)),
        ],
    });

    write_bench_json(
        "pipeline",
        vec![
            (
                "note",
                Json::Str(
                    "tier1-smoke baseline recorded by tests/bench_smoke.rs (32x16x4 cube, \
                     120 observations, Baseline/4-types over slice 2); regenerated on every \
                     tier-1 run and by `cargo bench --bench pipeline -- --json`"
                        .into(),
                ),
            ),
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("windows_per_s".into())),
            ("windows", Json::Num(n_windows as f64)),
            ("observations", Json::Num(spec.n_sims as f64)),
            ("backend_workers", Json::Num(1.0)),
            ("window_lines", Json::Num(4.0)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_pipeline.json");

    let rows = check_schema("pipeline");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let kernel_rows = rows
        .iter()
        .filter(|r| r.get("mode").and_then(|m| m.as_str()) == Some("kernel"))
        .count();
    assert_eq!(kernel_rows, 1, "pipeline record must carry the kernel micro-row");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn records_queries_bench_json() {
    reject_committed_placeholder("queries");
    // One store build (dataset + persist phase) feeds the point, serve
    // and spatial passes below.
    let fixture =
        QueryStoreFixture::build("benchsmoke-q", CubeDims::new(32, 16, 4), 20180599, 4, &[2])
            .expect("store build");
    let dims = fixture.dims();
    let engine = fixture.engine(0).expect("open store");
    let n_queries = 3_000usize;
    let ids = fixture.point_ids(n_queries, 7);

    let mut rows: Vec<BenchRow> = THREADS
        .iter()
        .map(|&threads| {
            engine.clear_cache();
            let exec = Executor::new(threads);
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<_>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            // One measurement pass: (xor-of-ids checksum, queries/s).
            let pass = || -> (u64, f64) {
                let t0 = Instant::now();
                let sum = exec
                    .run(chunks.clone(), |chunk| {
                        let mut acc = 0u64;
                        for id in chunk {
                            acc ^= engine.point_by_id(id).expect("point").point.0;
                        }
                        acc
                    })
                    .into_iter()
                    .fold(0, |a, b| a ^ b);
                (sum, n_queries as f64 / t0.elapsed().as_secs_f64())
            };
            let (cold, cold_qps) = pass();
            let (warm, warm_qps) = pass();
            assert_eq!(cold, warm, "cold/warm reads diverged");
            BenchRow {
                threads,
                throughput: warm_qps,
                extra: vec![("cold_qps", Json::Num(cold_qps))],
            }
        })
        .collect();

    // Spatial rows: grid-index-pruned box summaries, radius scans and
    // kNN lookups over the same store. Smoke-grade but real — the rows
    // must clear the schema's throughput > 0 bar like everything else.
    let n_spatial = 300usize;
    let mut rng = Rng::new(23);
    let t0 = Instant::now();
    let mut pts = 0usize;
    for _ in 0..n_spatial {
        let c = (rng.below(dims.nx), rng.below(dims.ny), rng.below(dims.nz));
        let q = BoxQuery::around(&dims, c, 1 + rng.below(6));
        pts += engine.box_summary(&q).expect("box").n_points;
    }
    assert!(pts > 0, "spatial smoke boxes matched no records");
    let box_per_s = n_spatial as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..n_spatial {
        let q = RadiusQuery {
            x: rng.below(dims.nx),
            y: rng.below(dims.ny),
            z: rng.below(dims.nz),
            radius: 1.0 + rng.below(4) as f64,
        };
        std::hint::black_box(engine.radius_records(&q).expect("radius").len());
    }
    let radius_per_s = n_spatial as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..n_spatial {
        let q = KnnQuery {
            x: rng.below(dims.nx),
            y: rng.below(dims.ny),
            z: rng.below(dims.nz),
            k: 1 + rng.below(8),
        };
        let hits = engine.knn(&q).expect("knn");
        assert_eq!(hits.len(), q.k.min(engine.store().n_records() as usize));
    }
    let knn_per_s = n_spatial as f64 / t0.elapsed().as_secs_f64();
    for (mode, throughput) in [
        ("spatial_box", box_per_s),
        ("spatial_radius", radius_per_s),
        ("spatial_knn", knn_per_s),
    ] {
        rows.push(BenchRow {
            threads: 1,
            throughput,
            extra: vec![
                ("mode", Json::Str(mode.into())),
                ("queries", Json::Num(n_spatial as f64)),
            ],
        });
    }

    // The serving row: closed-loop load driven through the *socket*
    // front — real loopback TCP connections, wire codec and dispatch
    // queue included — recorded next to the raw engine rows
    // (mode: "serve", transport: "socket").
    let clients = 4usize;
    let front = Arc::new(ServeFront::new(
        fixture.engine(0).expect("open store for serving"),
        ServeOptions {
            max_in_flight: 2,
            queue_depth: 4,
        },
    ));
    let server = NetServer::start(
        Arc::clone(&front),
        "127.0.0.1:0",
        NetOptions {
            workers: 2,
            queue_depth: 4,
        },
    )
    .expect("socket front");
    let load = closed_loop_net(&server.addr().to_string(), clients, 150, 11)
        .expect("socket closed loop");
    server.join();
    assert!(load.completed > 0, "serving tier completed no requests");
    assert_eq!(
        load.completed + load.shed + load.errors,
        load.requests,
        "socket closed loop lost requests: {load:?}"
    );
    let m = front.metrics();
    assert!(m.peak_in_flight <= 2, "in-flight cap violated");
    assert!(m.peak_queued <= 4, "queue-depth cap violated");
    rows.push(BenchRow {
        threads: clients,
        throughput: load.throughput,
        extra: vec![
            ("mode", Json::Str("serve".into())),
            ("transport", Json::Str("socket".into())),
            ("shed", Json::Num(load.shed as f64)),
            ("max_in_flight", Json::Num(2.0)),
            ("queue_depth", Json::Num(4.0)),
        ],
    });

    write_bench_json(
        "queries",
        vec![
            (
                "note",
                Json::Str(
                    "tier1-smoke baseline recorded by tests/bench_smoke.rs (32x16x4 cube, \
                     slice 2 persisted, shared QueryStoreFixture build); regenerated on every \
                     tier-1 run and by `cargo bench --bench queries -- --json`"
                        .into(),
                ),
            ),
            ("profile", Json::Str("tier1-smoke".into())),
            ("unit", Json::Str("warm_queries_per_s".into())),
            ("n_queries", Json::Num(n_queries as f64)),
            ("records", Json::Num(engine.store().n_records() as f64)),
        ],
        rows,
        Vec::new(),
    )
    .expect("write BENCH_queries.json");

    let rows = check_schema("queries");
    for row in &rows {
        assert!(row.get("throughput").and_then(|t| t.as_f64()).unwrap() > 0.0);
    }
    let spatial_rows = rows
        .iter()
        .filter(|r| {
            r.get("mode")
                .and_then(|m| m.as_str())
                .is_some_and(|m| m.starts_with("spatial_"))
        })
        .count();
    assert_eq!(spatial_rows, 3, "spatial rows missing from BENCH_queries.json");
    let serve_row = rows
        .iter()
        .find(|r| r.get("mode").and_then(|m| m.as_str()) == Some("serve"))
        .expect("serve row missing from BENCH_queries.json");
    assert_eq!(
        serve_row.get("transport").and_then(|t| t.as_str()),
        Some("socket"),
        "serve row must be driven through the socket front"
    );
}
