//! End-to-end pipeline tests on a generated tiny dataset: every method ×
//! type set runs through datagen → NFS reader → stats kernel → method
//! coordinator → fit kernels → Eq.6 error, and the paper's qualitative
//! relationships are asserted.
//!
//! Runs on the native backend by default (no artifacts needed); build
//! with `--features xla` + `make artifacts` and set `PDFFLOW_BACKEND=xla`
//! to drive the same suite through the PJRT engine.

use std::sync::OnceLock;

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, Sampler, TypeSet};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};

/// One backend per test (the PJRT client is Rc-based — not Sync — so a
/// process-wide shared backend would be unsound under the parallel test
/// harness). Native unless the build has the xla feature AND the
/// environment asks for it; on xla builds a malformed PDFFLOW_BACKEND
/// fails loudly rather than silently falling back to native.
fn backend() -> Box<dyn Backend> {
    let kind = if cfg!(feature = "xla") {
        BackendKind::resolve(None).expect("PDFFLOW_BACKEND")
    } else {
        BackendKind::Native
    };
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    make_backend(
        kind,
        dir.to_str().unwrap(),
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("backend construction")
}

fn dataset() -> &'static SyntheticDataset {
    static DS: OnceLock<SyntheticDataset> = OnceLock::new();
    DS.get_or_init(|| {
        let dir = std::env::temp_dir().join("pdfflow-e2e-dataset");
        SyntheticDataset::generate(&DatasetSpec::tiny(), dir).unwrap()
    })
}

fn pipeline(backend: &dyn Backend) -> Pipeline<'_> {
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        ..PipelineConfig::default()
    };
    Pipeline::new(dataset(), backend, SimCluster::new(ClusterSpec::lncc()), cfg)
}

#[test]
fn every_method_runs_and_covers_all_points() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    p.ensure_tree(0, TypeSet::Four, 500).unwrap();
    let dims = dataset().spec.dims;
    for method in Method::ALL {
        let r = p.run_slice(method, 2, TypeSet::Four).unwrap();
        assert_eq!(r.n_points, dims.slice_points(), "{}", method.name());
        assert!(r.avg_error.is_finite() && r.avg_error >= 0.0 && r.avg_error <= 2.0);
        assert!(r.fit_real_s > 0.0);
        assert!(r.fit_sim_s > 0.0);
        assert_eq!(
            r.windows.len(),
            dims.ny.div_ceil(4),
            "window count for {}",
            method.name()
        );
    }
}

#[test]
fn grouping_reduces_fits_without_extra_error() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    let base = p.run_slice(Method::Baseline, 2, TypeSet::Four).unwrap();
    let grp = p.run_slice(Method::Grouping, 2, TypeSet::Four).unwrap();
    // Grouping must fit strictly fewer points (the dataset is built with
    // a ~60% redundancy) and produce the SAME average error: grouped
    // points share identical observation vectors.
    assert!(
        (grp.fits as f64) < 0.8 * base.fits as f64,
        "grouping fits {} vs baseline {}",
        grp.fits,
        base.fits
    );
    assert!(
        (grp.avg_error - base.avg_error).abs() < 1e-5,
        "grouping E {} vs baseline E {}",
        grp.avg_error,
        base.avg_error
    );
    assert!(grp.shuffle_bytes > 0);
}

#[test]
fn reuse_hits_across_windows() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    let r = p.run_slice(Method::Reuse, 2, TypeSet::Four).unwrap();
    // Layers repeat the same (mean, std) groups in every window, so
    // later windows must hit the cross-window cache.
    assert!(r.reuse_hits > 0, "no reuse hits");
    assert!(r.fits < r.groups, "fits {} !< groups {}", r.fits, r.groups);
    let (lookups, hits, entries) = p.reuse_stats();
    assert_eq!(lookups as usize, r.groups);
    assert_eq!(hits as usize, r.reuse_hits);
    assert_eq!(entries, r.fits);
}

#[test]
fn ml_reduces_work_with_bounded_extra_error() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    let model_err = p.ensure_tree(0, TypeSet::Ten, 500).unwrap();
    assert!(model_err < 0.5, "model error {model_err}");
    let base = p.run_slice(Method::Baseline, 2, TypeSet::Ten).unwrap();
    let ml = p.run_slice(Method::Ml, 2, TypeSet::Ten).unwrap();
    // Paper: WithML error is slightly larger but bounded.
    assert!(
        ml.avg_error <= base.avg_error + 0.1,
        "ml E {} vs baseline E {}",
        ml.avg_error,
        base.avg_error
    );
    // ML fits one type per point instead of ten: the simulated stage
    // (emulated external-fitter regime, see ClusterSpec) must shrink.
    assert!(
        ml.fit_sim_s < base.fit_sim_s,
        "ml sim {} vs baseline sim {}",
        ml.fit_sim_s,
        base.fit_sim_s
    );
}

#[test]
fn ten_types_cost_more_but_err_not_worse() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    let four = p.run_slice(Method::Baseline, 2, TypeSet::Four).unwrap();
    let ten = p.run_slice(Method::Baseline, 2, TypeSet::Ten).unwrap();
    assert!(ten.avg_error <= four.avg_error + 1e-6);
    assert!(ten.fit_sim_s > four.fit_sim_s);
}

#[test]
fn run_lines_small_workload() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    let r = p.run_lines(Method::Baseline, 2, TypeSet::Four, 8).unwrap();
    let dims = dataset().spec.dims;
    assert_eq!(r.n_points, 8 * dims.nx);
    assert_eq!(r.windows.len(), 2);
}

#[test]
fn ml_methods_fail_fast_without_tree() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    assert!(p.run_slice(Method::Ml, 2, TypeSet::Four).is_err());
    assert!(p.run_slice(Method::GroupingMl, 2, TypeSet::Four).is_err());
}

#[test]
fn persistence_writes_one_record_per_point() {
    let out = std::env::temp_dir().join(format!("pdfflow-persist-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    let mut cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        ..PipelineConfig::default()
    };
    cfg.persist_dir = Some(out.to_str().unwrap().to_string());
    let backend = backend();
    let mut p = Pipeline::new(dataset(), backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
    let r = p.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    let path = out.join("slice1_baseline_4.pdfout");
    let bytes = std::fs::metadata(&path).unwrap().len();
    assert_eq!(bytes, r.n_points as u64 * 28); // 8+4+4+12 per record
    std::fs::remove_dir_all(&out).unwrap();
}

#[test]
fn sampling_is_cheaper_than_fitting_and_close_in_features() {
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    p.ensure_tree(0, TypeSet::Four, 500).unwrap();
    let tree = p.tree.clone().unwrap();
    let ds = dataset();
    let reader = pdfflow::storage::DatasetReader::new(ds);
    let cache = pdfflow::storage::WindowCache::new(64 << 20);
    let cluster = SimCluster::new(ClusterSpec::lncc());
    let full = pdfflow::coordinator::sampling::full_slice_features(
        &reader, &cache, backend.as_ref(), &cluster, &tree, 2,
    )
    .unwrap();
    for rate in [0.1, 0.5] {
        let rep = pdfflow::coordinator::sampling::run_sampling(
            &reader,
            &cache,
            backend.as_ref(),
            &cluster,
            &tree,
            2,
            rate,
            Sampler::Random,
            7,
        )
        .unwrap();
        assert_eq!(
            rep.n_sampled,
            (ds.spec.dims.slice_points() as f64 * rate).round() as usize
        );
        let d = rep.features.type_distance(&full);
        assert!(d < 0.5, "rate {rate}: distance {d}");
        assert!(rep.compute_real_s < 1.0, "prediction should be instant");
    }
    // k-means path also works and returns <= k points.
    let rep = pdfflow::coordinator::sampling::run_sampling(
        &reader, &cache, backend.as_ref(), &cluster, &tree, 2, 0.1, Sampler::KMeans, 7,
    )
    .unwrap();
    assert!(rep.n_sampled <= (ds.spec.dims.slice_points() as f64 * 0.1).round() as usize);
    assert!(rep.features.type_percentages.iter().sum::<f64>() > 0.99);
}

#[test]
fn simulated_time_scales_down_with_more_nodes() {
    let backend = backend();
    let ds = dataset();
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        ..PipelineConfig::default()
    };
    let mut p10 = Pipeline::new(ds, backend.as_ref(), SimCluster::new(ClusterSpec::g5k(10)), cfg.clone());
    let mut p60 = Pipeline::new(ds, backend.as_ref(), SimCluster::new(ClusterSpec::g5k(60)), cfg);
    let r10 = p10.run_slice(Method::Baseline, 2, TypeSet::Ten).unwrap();
    let r60 = p60.run_slice(Method::Baseline, 2, TypeSet::Ten).unwrap();
    assert!(
        r60.fit_sim_s <= r10.fit_sim_s,
        "60 nodes {} !<= 10 nodes {}",
        r60.fit_sim_s,
        r10.fit_sim_s
    );
}

#[test]
fn every_method_typeset_reports_internally_consistent() {
    // Satellite invariant suite: every Method × TypeSet covers all slice
    // points, and the SliceReport's phase times / fit counts are the
    // exact aggregates of its per-window reports.
    let backend = backend();
    let mut p = pipeline(backend.as_ref());
    p.ensure_tree(0, TypeSet::Ten, 500).unwrap();
    let dims = dataset().spec.dims;
    for types in [TypeSet::Four, TypeSet::Ten] {
        for method in Method::ALL {
            let r = p.run_slice(method, 2, types).unwrap();
            let tag = format!("{}/{}", method.name(), types.name());
            assert_eq!(r.n_points, dims.slice_points(), "{tag}: point coverage");
            let win_points: usize = r.windows.iter().map(|w| w.n_points).sum();
            assert_eq!(win_points, r.n_points, "{tag}: window point sum");
            let win_fits: usize = r.windows.iter().map(|w| w.fits).sum();
            assert_eq!(win_fits, r.fits, "{tag}: fit sum");
            let win_groups: usize = r.windows.iter().map(|w| w.groups).sum();
            assert_eq!(win_groups, r.groups, "{tag}: group sum");
            let win_hits: usize = r.windows.iter().map(|w| w.reuse_hits).sum();
            assert_eq!(win_hits, r.reuse_hits, "{tag}: reuse-hit sum");
            let win_shuffle: u64 = r.windows.iter().map(|w| w.shuffle_bytes).sum();
            assert_eq!(win_shuffle, r.shuffle_bytes, "{tag}: shuffle sum");
            for (phase, total, per_window) in [
                ("load_real", r.load_real_s, r.windows.iter().map(|w| w.load_real_s).sum::<f64>()),
                ("load_sim", r.load_sim_s, r.windows.iter().map(|w| w.load_sim_s).sum::<f64>()),
                ("fit_real", r.fit_real_s, r.windows.iter().map(|w| w.fit_real_s).sum::<f64>()),
                ("fit_sim", r.fit_sim_s, r.windows.iter().map(|w| w.fit_sim_s).sum::<f64>()),
            ] {
                assert!(total >= 0.0, "{tag}: negative {phase}");
                assert!(
                    (total - per_window).abs() < 1e-9 * total.abs().max(1.0),
                    "{tag}: {phase} total {total} != window sum {per_window}"
                );
            }
            assert!(
                (r.total_real_s() - (r.load_real_s + r.fit_real_s)).abs() < 1e-12,
                "{tag}: total_real_s"
            );
            // Fit economics: never more fits than points; grouping never
            // more groups than points; reuse hits only for reuse methods.
            assert!(r.fits <= r.n_points, "{tag}: fits {} > points", r.fits);
            assert!(r.groups <= r.n_points, "{tag}: groups {} > points", r.groups);
            if method.uses_grouping() {
                assert!(r.groups > 0, "{tag}: no groups");
                if method.uses_reuse() {
                    assert_eq!(r.fits + r.reuse_hits, r.groups, "{tag}: fits+hits");
                } else {
                    assert_eq!(r.fits, r.groups, "{tag}: fits==groups");
                }
            } else {
                assert_eq!(r.fits, r.n_points, "{tag}: baseline fits all");
                assert_eq!(r.reuse_hits, 0, "{tag}: no reuse hits");
            }
            // Eq. 6 is the mean of per-window error sums.
            let err_total: f64 = r.windows.iter().map(|w| w.err_sum).sum();
            assert!(
                (r.avg_error - err_total / r.n_points as f64).abs() < 1e-12,
                "{tag}: Eq.6 aggregate"
            );
        }
    }
}
