//! Oracle-differential property tests for the spatial query tier.
//!
//! Every spatial query kind — 3D box scan/summary, radius, kNN,
//! per-cell aggregation, cross-run diff — is checked **bit-identical**
//! against the brute-force oracle in `pdfflow::spatial::oracle`, which
//! answers by full store scans with none of the engine's machinery (no
//! grid index, no block cache, no host-pool fan-out). Stores are
//! synthesized directly through the writer API over randomized cube
//! shapes, per-slice window heights, slice holes and window gaps, and
//! each case draws a random worker count and grid geometry, so the
//! comparison covers region edges (empty box, single point, whole
//! cube, boxes straddling slice/window boundaries) and any thread
//! count. Case count per property: `testkit::cases(60)` — override
//! with `PDFFLOW_PROPTEST_CASES` (CI cranks it up).

use std::path::{Path, PathBuf};

use pdfflow::cube::{CellGrid, CubeDims};
use pdfflow::pdfstore::{PdfRecord, QueryEngine, QueryOptions, RunKey, RunSelector, StoreWriter};
use pdfflow::prop_assert;
use pdfflow::spatial::{dist2, oracle, BoxQuery, KnnQuery, RadiusQuery};
use pdfflow::stats::DistType;
use pdfflow::util::prng::Rng;
use pdfflow::util::testkit;

/// Observation count recorded in every synthesized catalog (the spatial
/// tier never reads it, but reruns must agree with the first writer).
const N_OBS: usize = 50;

fn case_dir(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static N: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "pdfflow-spatialoracle-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

fn random_dims(rng: &mut Rng) -> CubeDims {
    CubeDims::new(2 + rng.below(8), 3 + rng.below(17), 2 + rng.below(4))
}

/// Synthesize one run of a store directly through the writer API (no
/// fit pipeline): each persisted slice is covered by windows of a
/// random per-slice height, with occasional skipped slices (holes the
/// resolved view never saw) and occasional window gaps inside a slice.
fn synth_run(dir: &Path, dims: CubeDims, key: &RunKey, rng: &mut Rng) -> Result<(), String> {
    let err = |e: pdfflow::PdfflowError| e.to_string();
    let mut w = StoreWriter::create(dir, dims, N_OBS).map_err(err)?;
    let mut persisted = false;
    for z in 0..dims.nz {
        let last = z == dims.nz - 1;
        if !(last && !persisted) && rng.below(6) == 0 {
            continue; // hole: this run never fitted slice z
        }
        persisted = true;
        let mut sw = w.open_segment(z, key).map_err(err)?;
        let window_lines = 1 + rng.below(dims.ny.min(5));
        let mut y0 = 0usize;
        while y0 < dims.ny {
            let lines = window_lines.min(dims.ny - y0);
            if y0 > 0 && rng.below(8) == 0 {
                y0 += lines; // gap: a window this run never persisted
                continue;
            }
            let mut records = Vec::with_capacity(lines * dims.nx);
            for y in y0..y0 + lines {
                for x in 0..dims.nx {
                    records.push(PdfRecord {
                        point: dims.point_id(x, y, z),
                        dist: DistType::from_id(rng.below(10)).unwrap(),
                        error: (rng.below(2000) as f32) / 1000.0,
                        params: [rng.f32(), rng.f32(), rng.f32()],
                    });
                }
            }
            sw.append_records(y0 as u64, lines as u64, &records).map_err(err)?;
            y0 += lines;
        }
        w.add_segment(sw.finish().map_err(err)?).map_err(err)?;
    }
    Ok(())
}

/// Random engine knobs: worker width (the invariance axis) and grid
/// geometry (None → `CellGrid::default_for`, Some → arbitrary sides,
/// possibly larger than the cube).
fn random_opts(dims: CubeDims, rng: &mut Rng) -> QueryOptions {
    let cell = if rng.below(2) == 0 {
        Some([
            1 + rng.below(dims.nx + 1),
            1 + rng.below(dims.ny + 1),
            1 + rng.below(dims.nz + 1),
        ])
    } else {
        None
    };
    QueryOptions {
        cache_bytes: 1 << 20,
        workers: [1, 2, 3, 8][rng.below(4)],
        cell,
        ..QueryOptions::default()
    }
}

/// One synthesized single-run store + engine over it.
fn synth_case(tag: &str, rng: &mut Rng) -> Result<(PathBuf, QueryEngine), String> {
    let dims = random_dims(rng);
    let dir = case_dir(tag);
    let _ = std::fs::remove_dir_all(&dir);
    synth_run(&dir, dims, &RunKey::new("baseline", 4, "a"), rng)?;
    let engine = QueryEngine::open(&dir, random_opts(dims, rng)).map_err(|e| e.to_string())?;
    Ok((dir, engine))
}

/// Random box over (and slightly beyond) the cube, biased toward the
/// edge shapes the index must get right.
fn random_box(dims: CubeDims, rng: &mut Rng) -> BoxQuery {
    let pair = |rng: &mut Rng, n: usize| {
        let (a, b) = (rng.below(n + 2), rng.below(n + 2));
        (a.min(b), a.max(b))
    };
    match rng.below(8) {
        // Empty by inversion: no point can satisfy x0 <= x <= x1.
        0 => BoxQuery {
            x0: 1,
            x1: 0,
            y0: 0,
            y1: 0,
            z0: 0,
            z1: 0,
        },
        1 => BoxQuery::point(rng.below(dims.nx), rng.below(dims.ny), rng.below(dims.nz)),
        2 => BoxQuery::whole(&dims),
        // Slab straddling a slice boundary.
        3 => {
            let z = rng.below(dims.nz);
            BoxQuery {
                z0: z.saturating_sub(1),
                z1: (z + 1).min(dims.nz - 1),
                ..BoxQuery::whole(&dims)
            }
        }
        // Thin y-band straddling window boundaries.
        4 => {
            let y = rng.below(dims.ny);
            BoxQuery {
                y0: y.saturating_sub(1),
                y1: (y + 1).min(dims.ny - 1),
                ..BoxQuery::whole(&dims)
            }
        }
        _ => {
            let (x0, x1) = pair(rng, dims.nx);
            let (y0, y1) = pair(rng, dims.ny);
            let (z0, z1) = pair(rng, dims.nz);
            BoxQuery {
                x0,
                x1,
                y0,
                y1,
                z0,
                z1,
            }
        }
    }
}

#[test]
fn box_queries_match_oracle() {
    testkit::check("spatial_box_oracle", testkit::cases(60), |rng| {
        let (dir, engine) = synth_case("box", rng)?;
        for _ in 0..4 {
            let q = random_box(engine.dims(), rng);
            let got = engine.box_records(&q).map_err(|e| e.to_string())?;
            let want = oracle::box_records(engine.store(), &q).map_err(|e| e.to_string())?;
            prop_assert!(
                got == want,
                "box_records mismatch for {q:?}: {} vs {} records",
                got.len(),
                want.len()
            );
            let gs = engine.box_summary(&q).map_err(|e| e.to_string())?;
            let ws = oracle::box_summary(engine.store(), &q).map_err(|e| e.to_string())?;
            prop_assert!(gs == ws, "box_summary mismatch for {q:?}: {gs:?} vs {ws:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn radius_queries_match_oracle() {
    testkit::check("spatial_radius_oracle", testkit::cases(60), |rng| {
        let (dir, engine) = synth_case("radius", rng)?;
        let dims = engine.dims();
        for _ in 0..4 {
            let q = RadiusQuery {
                // Centers may sit slightly outside the cube.
                x: rng.below(dims.nx + 2),
                y: rng.below(dims.ny + 2),
                z: rng.below(dims.nz + 2),
                radius: match rng.below(6) {
                    0 => -1.0,
                    1 => 0.0,
                    2 => 0.7,
                    3 => 2.5,
                    4 => (dims.nx + dims.ny + dims.nz) as f64,
                    _ => rng.uniform(0.0, dims.ny as f64),
                },
            };
            let got = engine.radius_records(&q).map_err(|e| e.to_string())?;
            let want = oracle::radius_records(engine.store(), &q).map_err(|e| e.to_string())?;
            prop_assert!(
                got == want,
                "radius mismatch for {q:?}: {} vs {} records",
                got.len(),
                want.len()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn knn_matches_oracle_with_deterministic_ties() {
    testkit::check("spatial_knn_oracle", testkit::cases(60), |rng| {
        let (dir, engine) = synth_case("knn", rng)?;
        let dims = engine.dims();
        let n_records = engine.store().n_records() as usize;
        for _ in 0..4 {
            let q = KnnQuery {
                x: rng.below(dims.nx + 2),
                y: rng.below(dims.ny + 2),
                z: rng.below(dims.nz + 2),
                // 0, tiny, mid, and beyond-the-store k values.
                k: match rng.below(4) {
                    0 => 0,
                    1 => 1,
                    2 => 1 + rng.below(n_records.max(1)),
                    _ => n_records + 1 + rng.below(5),
                },
            };
            let got = engine.knn(&q).map_err(|e| e.to_string())?;
            let want = oracle::knn(engine.store(), &q).map_err(|e| e.to_string())?;
            prop_assert!(
                got == want,
                "knn mismatch for {q:?}: {} vs {} records",
                got.len(),
                want.len()
            );
            prop_assert!(got.len() == q.k.min(n_records), "knn returned wrong count for {q:?}");
            // Ties break toward the lower PointId: the (distance, id)
            // key must be strictly increasing.
            let center = (q.x, q.y, q.z);
            for w in got.windows(2) {
                let a = (dist2(dims.coords(w[0].point), center), w[0].point);
                let b = (dist2(dims.coords(w[1].point), center), w[1].point);
                prop_assert!(a < b, "knn order not strictly increasing at {a:?} vs {b:?}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn cell_aggregation_matches_oracle() {
    testkit::check("spatial_agg_oracle", testkit::cases(60), |rng| {
        let (dir, engine) = synth_case("agg", rng)?;
        for _ in 0..3 {
            let q = random_box(engine.dims(), rng);
            let grid = engine.spatial_index().grid();
            let got = engine.cell_aggregate(&q).map_err(|e| e.to_string())?;
            let want =
                oracle::cell_aggregate(engine.store(), grid, &q).map_err(|e| e.to_string())?;
            prop_assert!(
                got == want,
                "cell_aggregate mismatch for {q:?}: {} vs {} cells, boundary {} vs {}",
                got.cells.len(),
                want.cells.len(),
                got.boundary.len(),
                want.boundary.len()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn cross_run_diff_matches_oracle() {
    testkit::check("spatial_diff_oracle", testkit::cases(60), |rng| {
        let dims = random_dims(rng);
        let dir = case_dir("diff");
        let _ = std::fs::remove_dir_all(&dir);
        // Two runs in one generational catalog, with independent slice
        // holes and window gaps so only_a/only_b are exercised.
        synth_run(&dir, dims, &RunKey::new("baseline", 4, "a"), rng)?;
        synth_run(&dir, dims, &RunKey::new("baseline", 4, "b"), rng)?;
        let opts = random_opts(dims, rng);
        let ea = QueryEngine::open_run(&dir, RunSelector::Id("a"), opts)
            .map_err(|e| e.to_string())?;
        let eb = QueryEngine::open_run(&dir, RunSelector::Id("b"), opts)
            .map_err(|e| e.to_string())?;
        for _ in 0..3 {
            let q = random_box(dims, rng);
            let got = ea.diff_run(&eb, &q).map_err(|e| e.to_string())?;
            let want = oracle::diff(ea.store(), eb.store(), ea.spatial_index().grid(), &q)
                .map_err(|e| e.to_string())?;
            prop_assert!(got == want, "diff mismatch for {q:?}: {got:?} vs {want:?}");
        }
        // A run diffed against itself reports no drift at all.
        let q = BoxQuery::whole(&dims);
        let zero = ea.diff_run(&ea, &q).map_err(|e| e.to_string())?;
        prop_assert!(
            zero.only_a == 0
                && zero.only_b == 0
                && zero.type_changed == 0
                && zero.err_delta_sum == 0.0
                && zero.max_err_delta == 0.0
                && zero.changed_cells.is_empty(),
            "self-diff reported drift: {zero:?}"
        );
        prop_assert!(
            zero.n_compared as u64 == ea.store().n_records(),
            "self-diff compared {} of {} records",
            zero.n_compared,
            ea.store().n_records()
        );
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Deterministic pin of the kNN tie contract: a uniform 3x3 plane of
/// equidistant points around the center must come back in ascending
/// PointId order, k truncating that order.
#[test]
fn knn_tie_break_is_point_id_order() {
    let dims = CubeDims::new(3, 3, 2);
    let dir = case_dir("tiepin");
    let _ = std::fs::remove_dir_all(&dir);
    let key = RunKey::new("baseline", 4, "a");
    let mut w = StoreWriter::create(&dir, dims, N_OBS).expect("create");
    let mut sw = w.open_segment(0, &key).expect("segment");
    let records: Vec<PdfRecord> = (0..dims.ny)
        .flat_map(|y| {
            (0..dims.nx).map(move |x| PdfRecord {
                point: dims.point_id(x, y, 0),
                dist: DistType::Normal,
                error: 0.5,
                params: [0.0; 3],
            })
        })
        .collect();
    sw.append_records(0, dims.ny as u64, &records).expect("append");
    w.add_segment(sw.finish().expect("finish")).expect("add");
    let engine = QueryEngine::open(&dir, QueryOptions::default()).expect("open");
    // Center of the plane: the 4 axis neighbors all sit at distance 1,
    // the 4 diagonals at sqrt(2). Ties resolve by ascending PointId.
    let got = engine.knn(&KnnQuery { x: 1, y: 1, z: 0, k: 5 }).expect("knn");
    let ids: Vec<u64> = got.iter().map(|r| r.point.0).collect();
    let center = dims.point_id(1, 1, 0).0;
    assert_eq!(ids[0], center, "nearest must be the center itself");
    let axis: Vec<u64> = vec![
        dims.point_id(1, 0, 0).0,
        dims.point_id(0, 1, 0).0,
        dims.point_id(2, 1, 0).0,
        dims.point_id(1, 2, 0).0,
    ];
    assert_eq!(&ids[1..], &axis[..], "distance-1 ties must come back in PointId order");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CellGrid geometry vs the oracle's boundary detector on a hand-built
/// two-type store: every cell bordering the type transition is flagged,
/// cells away from it are not.
#[test]
fn boundary_cells_flag_type_transitions() {
    let dims = CubeDims::new(4, 4, 2);
    let dir = case_dir("boundary");
    let _ = std::fs::remove_dir_all(&dir);
    let key = RunKey::new("baseline", 4, "a");
    let mut w = StoreWriter::create(&dir, dims, N_OBS).expect("create");
    for z in 0..dims.nz {
        let mut sw = w.open_segment(z, &key).expect("segment");
        let records: Vec<PdfRecord> = (0..dims.ny)
            .flat_map(|y| {
                (0..dims.nx).map(move |x| PdfRecord {
                    point: dims.point_id(x, y, z),
                    // Left half Normal, right half Gamma: one vertical
                    // type transition between x=1 and x=2.
                    dist: if x < 2 { DistType::Normal } else { DistType::Gamma },
                    error: 1.0,
                    params: [0.0; 3],
                })
            })
            .collect();
        sw.append_records(0, dims.ny as u64, &records).expect("append");
        w.add_segment(sw.finish().expect("finish")).expect("add");
    }
    // 2-wide cells along x → cells (0,*,*) are all-Normal, (1,*,*) all-
    // Gamma; every cell touches the transition, so all are boundary.
    let opts = QueryOptions {
        cell: Some([2, 4, 2]),
        ..QueryOptions::default()
    };
    let engine = QueryEngine::open(&dir, opts).expect("open");
    let agg = engine.cell_aggregate(&BoxQuery::whole(&dims)).expect("agg");
    assert_eq!(agg.cells.len(), 2, "expected one all-Normal and one all-Gamma cell");
    assert_eq!(
        agg.boundary,
        vec![(0, 0, 0), (1, 0, 0)],
        "both cells border the type transition"
    );
    let grid = CellGrid::new(dims, 2, 4, 2);
    let want = oracle::cell_aggregate(engine.store(), grid, &BoxQuery::whole(&dims)).expect("agg");
    assert_eq!(agg, want, "engine and oracle disagree on the hand-built cube");
    let _ = std::fs::remove_dir_all(&dir);
}
