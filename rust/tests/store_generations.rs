//! Generational-catalog acceptance tests: reruns append generations
//! instead of clobbering, runs are selectable at query time, compaction
//! is bit-identical and crash-safe, the serving front door enforces its
//! admission caps under closed-loop load, and store-backed tree
//! training equals the refit path.

use std::path::{Path, PathBuf};

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{mlmodel, Method, Pipeline, TypeSet};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::pdfstore::{
    compact_run, Catalog, PdfRecord, PdfStore, QueryEngine, QueryOptions, RegionQuery, RunSelector,
    CATALOG_NAME,
};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::serve::{closed_loop, Class, Request, ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};

fn backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("native backend")
}

fn root_dir(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("pdfflow-gens-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn pipeline_cfg(store_dir: Option<&Path>, run_id: Option<&str>) -> PipelineConfig {
    PipelineConfig {
        batch: 64,
        window_lines: 4,
        store_dir: store_dir.map(|p| p.to_string_lossy().into_owned()),
        run_id: run_id.map(|s| s.to_string()),
        ..PipelineConfig::default()
    }
}

fn fold_record(acc: u64, rec: &PdfRecord) -> u64 {
    acc.rotate_left(7)
        .wrapping_add(rec.point.0)
        .wrapping_add((rec.dist.id() as u64) << 48)
        .wrapping_add(rec.error.to_bits() as u64)
        .wrapping_add((rec.params[0].to_bits() as u64) << 16)
        .wrapping_add((rec.params[1].to_bits() as u64) << 24)
        .wrapping_add((rec.params[2].to_bits() as u64) << 32)
}

/// Bit-exact face of everything the query surface can answer for one
/// slice: every record's wire bits, the region summary, a quantile
/// surface, and the spatial tier (box scan + summary, radius ball, kNN,
/// cell aggregation). Identical u64 ⇔ identical answers.
fn query_fingerprint(engine: &QueryEngine, z: usize) -> u64 {
    let dims = engine.dims();
    let full = RegionQuery::slice(&dims, z);
    let mut acc = 0x9e37_79b9_7f4a_7c15u64;
    for rec in engine.region(&full).expect("region scan") {
        acc = fold_record(acc, &rec);
    }
    let s = engine.region_summary(&full).expect("summary");
    acc = acc.rotate_left(9).wrapping_add(s.avg_error.to_bits());
    acc = acc.rotate_left(9).wrapping_add(s.max_error.to_bits());
    let q = RegionQuery {
        z,
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
    };
    let m = engine.region_quantile_mean(&q, 0.5).expect("quantile mean");
    acc = acc.rotate_left(9).wrapping_add(m.to_bits());
    // Spatial surface over the same slice: a box straddling its z
    // neighbors, a radius ball and kNN at the slice center, and the
    // per-cell aggregation — all must answer bit-identically across
    // compaction and rerun generations.
    let bx = BoxQuery {
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
        z0: z.saturating_sub(1),
        z1: (z + 1).min(dims.nz - 1),
    };
    for rec in engine.box_records(&bx).expect("box records") {
        acc = fold_record(acc, &rec);
    }
    let bs = engine.box_summary(&bx).expect("box summary");
    acc = acc.rotate_left(9).wrapping_add(bs.avg_error.to_bits());
    acc = acc.rotate_left(9).wrapping_add(bs.max_error.to_bits());
    let ball = RadiusQuery {
        x: dims.nx / 2,
        y: dims.ny / 2,
        z,
        radius: 2.5,
    };
    for rec in engine.radius_records(&ball).expect("radius records") {
        acc = fold_record(acc, &rec);
    }
    let near = KnnQuery {
        x: 1,
        y: 2,
        z,
        k: 9,
    };
    for rec in engine.knn(&near).expect("knn") {
        acc = fold_record(acc, &rec);
    }
    let agg = engine.cell_aggregate(&bx).expect("cell aggregate");
    for cell in &agg.cells {
        acc = acc
            .rotate_left(5)
            .wrapping_add(cell.n_points as u64)
            .wrapping_add(cell.err_sum.to_bits())
            .wrapping_add(cell.max_error.to_bits() as u64)
            .wrapping_add((cell.dominant.id() as u64) << 40);
    }
    acc.rotate_left(5).wrapping_add(agg.boundary.len() as u64)
}

#[test]
fn reruns_append_generations_and_runs_are_selectable() {
    let root = root_dir("append");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();

    // Run "a" (baseline) persists slice 1, then reruns the same slice:
    // the rerun must append generation 1, not truncate generation 0.
    let mut pa = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), Some("a")),
    );
    pa.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    let g0_bytes = std::fs::read(store.join("slice1_baseline_4_a_g0.seg")).unwrap();
    pa.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    assert_eq!(
        std::fs::read(store.join("slice1_baseline_4_a_g0.seg")).unwrap(),
        g0_bytes,
        "rerun clobbered the prior generation"
    );
    let g1_bytes = std::fs::read(store.join("slice1_baseline_4_a_g1.seg")).unwrap();
    // Deterministic pipeline: the rerun wrote identical content.
    assert_eq!(g0_bytes, g1_bytes);

    // Run "b" (different method + run id) never touches run "a" files.
    let mut pb = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), Some("b")),
    );
    pb.run_slice(Method::Grouping, 1, TypeSet::Four).unwrap();
    assert_eq!(
        std::fs::read(store.join("slice1_baseline_4_a_g0.seg")).unwrap(),
        g0_bytes
    );
    assert!(store.join("slice1_grouping_4_b_g0.seg").exists());

    // Catalog shape: two runs; run "a" holds two generations of slice 1.
    let catalog = Catalog::load(&store).unwrap();
    assert_eq!(catalog.runs.len(), 2);
    let a = catalog.select(Some("a")).unwrap();
    assert_eq!(a.segments.len(), 2);
    assert_eq!(a.n_generations(), 2);
    assert_eq!(a.next_gen_for_slice(1), 2);

    // Latest run is "b" (most recent write); --run selects "a".
    let latest = PdfStore::open(&store).unwrap();
    assert_eq!(latest.run_key().run_id, "b");
    assert_eq!(latest.run_key().method, "grouping");
    let run_a = PdfStore::open_run(&store, RunSelector::Id("a")).unwrap();
    assert_eq!(run_a.run_key().method, "baseline");
    assert_eq!(run_a.n_segments(), 2);
    // Resolved view: exactly one record set for the slice (newest gen),
    // even though two generations are on disk.
    let n = ds.spec.dims.slice_points() as u64;
    assert_eq!(run_a.n_records(), n);
    run_a.verify().unwrap();

    // Both runs answer queries independently.
    let ea = QueryEngine::new(run_a, QueryOptions::default());
    let eb = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let pa_rec = ea.point(3, 2, 1).unwrap();
    let pb_rec = eb.point(3, 2, 1).unwrap();
    assert_eq!(pa_rec.point, pb_rec.point);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn compaction_is_bit_identical_and_retires_generations() {
    let root = root_dir("compact");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), Some("exp")),
    );
    // Generation 0 covers the whole slice; generation 1 reruns only the
    // first 8 lines — the resolved view must mix generations
    // window-by-window (lines 0..8 from gen 1, the rest from gen 0).
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    let g0_engine = QueryEngine::open_run(
        &store,
        RunSelector::Id("exp"),
        QueryOptions::default(),
    )
    .unwrap();
    let g0 = query_fingerprint(&g0_engine, 1);
    drop(g0_engine);
    pipe.run_lines(Method::Baseline, 1, TypeSet::Four, 8).unwrap();

    let before_engine = QueryEngine::open_run(
        &store,
        RunSelector::Id("exp"),
        QueryOptions::default(),
    )
    .unwrap();
    assert_eq!(before_engine.store().n_segments(), 2);
    let before = query_fingerprint(&before_engine, 1);
    drop(before_engine);
    // The rerun is deterministic: appending generation 1 must not change
    // any query answer (spatial included) versus the gen-0-only view.
    assert_eq!(before, g0, "appended generation changed query answers");

    let rep = compact_run(&store, Some("exp")).unwrap();
    assert!(!rep.already_compact);
    assert_eq!(rep.segments_before, 2);
    assert_eq!(rep.segments_after, 1);
    assert_eq!(rep.retired_files, 2);
    assert!(rep.bytes_after < rep.bytes_before, "compaction must drop dead bytes");

    // Old generations are gone from disk; the new one answers
    // bit-identically and passes a full checksum verify.
    assert!(!store.join("slice1_baseline_4_exp_g0.seg").exists());
    assert!(!store.join("slice1_baseline_4_exp_g1.seg").exists());
    assert!(store.join(format!("slice1_baseline_4_exp_g{}.seg", rep.gen)).exists());
    let after_engine = QueryEngine::open_run(
        &store,
        RunSelector::Id("exp"),
        QueryOptions::default(),
    )
    .unwrap();
    assert_eq!(after_engine.store().n_segments(), 1);
    after_engine.store().verify().unwrap();
    assert_eq!(
        query_fingerprint(&after_engine, 1),
        before,
        "query results diverged across compaction"
    );

    // Compacting a dense run is a no-op.
    let rep2 = compact_run(&store, Some("exp")).unwrap();
    assert!(rep2.already_compact);
    assert_eq!(rep2.retired_files, 0);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn crashed_compaction_cold_opens_to_previous_generation() {
    let root = root_dir("crash");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), Some("exp")),
    );
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    pipe.run_lines(Method::Baseline, 1, TypeSet::Four, 8).unwrap();
    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let before = query_fingerprint(&engine, 1);
    drop(engine);

    // Simulate a crash mid-compaction: a half-written segment tmp, an
    // orphan segment that never made it into the catalog, and a
    // truncated catalog tmp from a dying save. None of these is
    // referenced by CATALOG.json, so a cold open must ignore them all.
    std::fs::write(store.join("slice1_baseline_4_exp_g7.seg.tmp"), b"PDFS\x01\x00garbage").unwrap();
    std::fs::write(store.join("slice1_baseline_4_exp_g7.seg"), b"PDFSorphaned-not-in-catalog").unwrap();
    let catalog_text = std::fs::read_to_string(store.join(CATALOG_NAME)).unwrap();
    std::fs::write(
        store.join(format!("{CATALOG_NAME}.tmp")),
        &catalog_text[..catalog_text.len() / 2],
    )
    .unwrap();

    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    engine.store().verify().unwrap();
    assert_eq!(
        query_fingerprint(&engine, 1),
        before,
        "crash debris changed query results"
    );
    drop(engine);

    // A later compaction still succeeds over the debris and stays
    // bit-identical.
    let rep = compact_run(&store, None).unwrap();
    assert!(!rep.already_compact);
    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    assert_eq!(query_fingerprint(&engine, 1), before);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn serve_front_enforces_admission_caps_under_closed_loop_load() {
    let root = root_dir("serve");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), None),
    );
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();

    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    // First point of slice 1 (the persisted slice).
    let first_id = pdfflow::cube::PointId(ds.spec.dims.slice_points() as u64);
    let direct = engine.point_by_id(first_id).unwrap();
    let opts = ServeOptions {
        max_in_flight: 1,
        queue_depth: 1,
    };
    let front = ServeFront::new(engine, opts);

    // Replies through the front match direct engine answers, and a
    // healthy store never serves degraded.
    let served = front.submit(Request::Point(first_id)).unwrap();
    assert!(!served.degraded, "healthy store flagged degraded");
    match served.reply {
        pdfflow::serve::Reply::Point(rec) => assert_eq!(rec, direct),
        other => panic!("unexpected reply {other:?}"),
    }

    // 8 closed-loop clients against capacity 1+1: concurrency must stay
    // inside the caps and the overflow must be shed, not queued.
    let load = closed_loop(&front, 8, 200, 99);
    let m = &load.metrics;
    assert_eq!(load.requests, 8 * 200);
    assert!(m.total_completed() > 0, "nothing served");
    assert!(
        m.peak_in_flight <= opts.max_in_flight,
        "in-flight cap violated: {} > {}",
        m.peak_in_flight,
        opts.max_in_flight
    );
    assert!(
        m.peak_queued <= opts.queue_depth,
        "queue-depth cap violated: {} > {}",
        m.peak_queued,
        opts.queue_depth
    );
    assert!(m.total_shed() > 0, "8 clients on capacity 2 never shed");
    // Ledger closes: every request completed, shed, or errored — summed
    // across all seven request classes, spatial included.
    let errors: u64 = Class::ALL.iter().map(|&c| m.class(c).errors).sum();
    let accounted = m.total_completed() + m.total_shed() + errors;
    assert_eq!(accounted, load.requests);
    // Shed is an explicit, typed signal.
    let err = pdfflow::PdfflowError::Overloaded("x".into());
    assert!(err.is_overload());
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn store_backed_training_matches_refit() {
    let root = root_dir("train");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();

    // Persist a full-fit baseline run over every training slice — the
    // "previously generated output" the paper's §5.3.1 trains on.
    let slices = mlmodel::training_slices(&ds.spec.dims, 0, ds.spec.n_value_layers());
    let mut writer = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), None),
    );
    for &z in &slices {
        writer.run_slice(Method::Baseline, z, TypeSet::Four).unwrap();
    }
    drop(writer);

    // Store-backed: labels read through the QueryEngine.
    let mut from_store = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&store), None),
    );
    let err_store = from_store.ensure_tree(0, TypeSet::Four, 500).unwrap();
    assert!(
        from_store.tree_from_store,
        "matching prior run present but training refit anyway"
    );

    // Refit path: no store configured.
    let mut refit = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(None, None),
    );
    let err_refit = refit.ensure_tree(0, TypeSet::Four, 500).unwrap();
    assert!(!refit.tree_from_store);

    // Same samples → bit-identical model error and tree.
    assert_eq!(err_store.to_bits(), err_refit.to_bits());
    assert_eq!(
        from_store.tree.as_ref().unwrap().to_json().to_string(),
        refit.tree.as_ref().unwrap().to_json().to_string(),
        "store-backed tree diverged from refit tree"
    );

    // A store that does not cover the training slices falls back to the
    // refit path (here: a store holding only one slice).
    let partial_store = root.join("partial");
    let mut partial_writer = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&partial_store), None),
    );
    partial_writer.run_slice(Method::Baseline, slices[0], TypeSet::Four).unwrap();
    drop(partial_writer);
    let mut fallback = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(Some(&partial_store), None),
    );
    let err_fallback = fallback.ensure_tree(0, TypeSet::Four, 500).unwrap();
    assert!(!fallback.tree_from_store, "incomplete store must fall back to refit");
    assert_eq!(err_fallback.to_bits(), err_refit.to_bits());
    std::fs::remove_dir_all(&root).unwrap();
}
