//! Integration tests: the default compute backend against the pure-rust
//! stats oracle, plus (under `--features xla`, after `make artifacts`)
//! the same checks against the PJRT engine and the real AOT artifacts.

use pdfflow::runtime::{Backend, NativeBackend};
use pdfflow::stats::{self, DistType, PointStats, DEFAULT_BINS};
use pdfflow::util::prng::Rng;

/// Backend under test. Native by default — it must work on a machine
/// with no HLO artifacts and no XLA toolchain. The batch of 64 mirrors
/// the smallest artifact batch so chunking paths are exercised.
fn backend() -> Box<dyn Backend> {
    Box::new(NativeBackend::with_options(4, 64, DEFAULT_BINS))
}

/// The PJRT engine over the real artifacts (xla builds only).
#[cfg(feature = "xla")]
fn xla_backend() -> Box<dyn Backend> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Box::new(pdfflow::runtime::Engine::load_default(dir).expect("run `make artifacts` first"))
}

/// Observation batch: `n` points of `obs` draws each, mixed families.
fn mixed_batch(n: usize, obs: usize, seed: u64) -> (Vec<f32>, Vec<DistType>) {
    let mut rng = Rng::new(seed);
    let mut values = Vec::with_capacity(n * obs);
    let mut families = Vec::with_capacity(n);
    for i in 0..n {
        let fam = DistType::FOUR[i % 4];
        families.push(fam);
        for _ in 0..obs {
            let v = match fam {
                DistType::Normal => rng.normal(10.0, 2.0),
                DistType::Uniform => rng.uniform(3.0, 9.0),
                DistType::Exponential => rng.exponential(0.25),
                DistType::Lognormal => rng.lognormal(1.5, 0.4),
                _ => unreachable!(),
            };
            values.push(v as f32);
        }
    }
    (values, families)
}

#[test]
fn backend_reports_name_and_runs_without_artifacts() {
    let b = backend();
    assert_eq!(b.name(), "native");
    let (values, _) = mixed_batch(4, 100, 0);
    assert_eq!(b.run_stats(&values, 4, 100).unwrap().n_rows, 4);
}

#[test]
fn stats_kernel_matches_rust_oracle() {
    let b = backend();
    let (values, _) = mixed_batch(32, 100, 1);
    let out = b.run_stats(&values, 32, 100).unwrap();
    assert_eq!((out.n_rows, out.n_cols), (32, 12));
    // STATS_COLS order: mean=0, std=1, min=2, max=3.
    for p in 0..32 {
        let s = PointStats::of(&values[p * 100..(p + 1) * 100]);
        let row = out.row(p);
        assert!(
            (row[0] as f64 - s.mean).abs() < 1e-2 * s.mean.abs().max(1.0),
            "point {p}: backend mean {} vs oracle {}",
            row[0],
            s.mean
        );
        assert!((row[1] as f64 - s.std).abs() < 1e-2 * s.std.abs().max(1e-3));
        assert!((row[2] as f64 - s.min).abs() < 1e-4 * s.min.abs().max(1.0));
        assert!((row[3] as f64 - s.max).abs() < 1e-4 * s.max.abs().max(1.0));
    }
}

#[test]
fn fit_all4_recovers_generating_families() {
    let b = backend();
    let (values, families) = mixed_batch(64, 100, 2);
    let out = b.run_fit_all(&values, 64, 100, 4).unwrap();
    assert_eq!(out.n_cols, 5);
    let mut correct = 0;
    for p in 0..64 {
        let row = out.row(p);
        let picked = DistType::from_id(row[0] as usize).unwrap();
        let err = row[1] as f64;
        assert!((0.0..=2.0).contains(&err), "err {err}");
        if picked == families[p] {
            correct += 1;
        }
    }
    // With 100 observations some confusion is expected; the bulk must
    // still land on the generating family.
    assert!(correct >= 40, "only {correct}/64 recovered");
}

#[test]
fn fit_all_matches_rust_oracle_argmin() {
    let b = backend();
    let (values, _) = mixed_batch(16, 100, 3);
    let out = b.run_fit_all(&values, 16, 100, 10).unwrap();
    for p in 0..16 {
        let row = out.row(p);
        let oracle = stats::fit_best(
            &values[p * 100..(p + 1) * 100],
            &DistType::ALL,
            DEFAULT_BINS,
        );
        // Errors are computed in f32 vs f64; allow small slack, and allow
        // a different winner only when errors are nearly tied.
        let got_err = row[1] as f64;
        assert!(
            (got_err - oracle.error).abs() < 0.02
                || DistType::from_id(row[0] as usize) == Some(oracle.dist),
            "point {p}: backend ({}, {:.4}) vs oracle ({:?}, {:.4})",
            row[0],
            got_err,
            oracle.dist,
            oracle.error
        );
    }
}

#[test]
fn fit_single_matches_rust_oracle_per_type() {
    let b = backend();
    let (values, _) = mixed_batch(8, 100, 4);
    for &t in &DistType::ALL {
        let out = b.run_fit_single(&values, 8, 100, t).unwrap();
        assert_eq!(out.n_cols, 4);
        for p in 0..8 {
            let row = out.row(p);
            let oracle =
                stats::fit_single(&values[p * 100..(p + 1) * 100], t, DEFAULT_BINS);
            assert!(
                (row[0] as f64 - oracle.error).abs() < 0.02,
                "{t:?} point {p}: backend err {} vs oracle {}",
                row[0],
                oracle.error
            );
        }
    }
}

#[test]
fn partial_batch_is_processed_exactly() {
    let b = backend();
    // 70 points with a 64-point batch: 2 executions, no lost/extra rows.
    let (values, _) = mixed_batch(70, 100, 5);
    let out = b.run_fit_all(&values, 70, 100, 4).unwrap();
    assert_eq!(out.n_rows, 70);
    let m = b.metrics();
    assert_eq!(m.rows_processed, 70);
    assert_eq!(m.executions, 2);
    // Same points in a different batching give identical results.
    let single = b.run_fit_all(&values[..100 * 64], 64, 100, 4).unwrap();
    assert_eq!(&out.data[..64 * 5], &single.data[..]);
}

#[test]
fn run_rejects_shape_mismatch() {
    let b = backend();
    let values = vec![1.0f32; 100];
    assert!(b.run_stats(&values, 2, 100).is_err());
    assert!(b.run_stats(&values, 1, 99).is_err());
}

#[test]
fn obs_4000_variant_works() {
    let b = backend();
    let mut rng = Rng::new(6);
    let values: Vec<f32> = (0..2 * 4000).map(|_| rng.normal(5.0, 1.0) as f32).collect();
    let out = b.run_fit_all(&values, 2, 4000, 4).unwrap();
    assert_eq!(out.n_rows, 2);
    for p in 0..2 {
        assert_eq!(out.row(p)[0] as usize, DistType::Normal.id());
        assert!(out.row(p)[1] < 0.1, "err {}", out.row(p)[1]);
    }
}

// ------------------------------------------------------------------
// XLA-only: the PJRT engine against the real artifacts.
// ------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla {
    use super::*;
    use pdfflow::runtime::ArtifactKind;

    #[test]
    fn engine_loads_and_reports_platform() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let e = pdfflow::runtime::Engine::load_default(dir).expect("run `make artifacts` first");
        assert_eq!(e.platform(), "cpu");
        assert!(e.manifest.artifacts.len() >= 13);
        assert!(e
            .manifest
            .find(ArtifactKind::FitSingle, Some(DistType::Cauchy), None, 1000)
            .is_some());
        assert!(e
            .manifest
            .find(ArtifactKind::FitSingle, Some(DistType::Cauchy), Some(4), 1000)
            .is_none());
    }

    #[test]
    fn xla_padding_rows_are_discarded() {
        let e = xla_backend();
        let (values, _) = mixed_batch(70, 100, 5);
        let out = e.run_fit_all(&values, 70, 100, 4).unwrap();
        assert_eq!(out.n_rows, 70);
        let m = e.metrics();
        assert_eq!(m.rows_processed, 70);
        assert_eq!(m.rows_padded, 58);
        assert_eq!(m.executions, 2);
    }

    #[test]
    fn xla_agrees_with_native_backend() {
        let e = xla_backend();
        let n = backend();
        let (values, _) = mixed_batch(16, 100, 7);
        let a = e.run_fit_all(&values, 16, 100, 10).unwrap();
        let b = n.run_fit_all(&values, 16, 100, 10).unwrap();
        for p in 0..16 {
            let (ra, rb) = (a.row(p), b.row(p));
            assert!(
                (ra[1] as f64 - rb[1] as f64).abs() < 0.02
                    || ra[0] == rb[0],
                "point {p}: xla {ra:?} vs native {rb:?}"
            );
        }
    }
}
