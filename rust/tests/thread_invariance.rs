//! Thread-count invariance of the staged window pipeline: the same
//! slice run at 1, 2 and 8 executor threads must produce identical
//! `SliceReport` aggregates and **bit-identical** persisted segment
//! bytes. This is the acceptance contract of the executor refactor —
//! parallelism may only change wall-clock, never results.

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, SliceReport, TypeSet};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::executor::Executor;
use pdfflow::pdfstore::{QueryEngine, QueryOptions, RunKey, RunSelector};
use pdfflow::runtime::{
    make_backend, Backend, BackendKind, BackendOptions, HostPool, NativeBackend,
};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};
use std::sync::Arc;

const THREADS: [usize; 3] = [1, 2, 8];

fn backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("native backend")
}

fn dataset(root: &std::path::Path) -> SyntheticDataset {
    SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).expect("dataset")
}

/// The deterministic face of a report: everything that must not depend
/// on the executor width (times are measurements and may differ).
fn fingerprint(r: &SliceReport) -> (u64, usize, usize, usize, usize, u64, u64, usize, usize) {
    (
        r.avg_error.to_bits(),
        r.n_points,
        r.fits,
        r.groups,
        r.reuse_hits,
        r.shuffle_bytes,
        r.persist_bytes,
        r.cache_hits,
        r.cache_misses,
    )
}

fn run_at(
    ds: &SyntheticDataset,
    method: Method,
    store_dir: &std::path::Path,
    threads: usize,
) -> (SliceReport, Vec<u8>) {
    let backend = backend();
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        executor_threads: threads,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        ..PipelineConfig::default()
    };
    let mut pipe = Pipeline::new(ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
    if method.uses_ml() {
        pipe.ensure_tree(0, TypeSet::Four, 500).expect("tree");
    }
    let report = pipe.run_slice(method, 2, TypeSet::Four).expect("slice run");
    let seg = store_dir.join(format!("slice2_{}_4_default_g0.seg", method.name()));
    let bytes = std::fs::read(&seg).expect("segment bytes");
    (report, bytes)
}

fn assert_invariant(method: Method, tag: &str) {
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    let mut runs = Vec::new();
    for threads in THREADS {
        let store = root.join(format!("store-{threads}"));
        runs.push((threads, run_at(&ds, method, &store, threads)));
    }
    let (_, (base_report, base_bytes)) = &runs[0];
    for (threads, (report, bytes)) in &runs[1..] {
        assert_eq!(
            fingerprint(report),
            fingerprint(base_report),
            "{tag}: report aggregates diverge at {threads} threads"
        );
        assert_eq!(
            report.windows.len(),
            base_report.windows.len(),
            "{tag}: window count at {threads} threads"
        );
        assert!(
            bytes == base_bytes,
            "{tag}: persisted segment bytes diverge at {threads} threads \
             ({} vs {} bytes)",
            bytes.len(),
            base_bytes.len()
        );
    }
    // The decomposed per-window reports must agree too (same windows, in
    // slice order, with identical deterministic columns).
    for (threads, (report, _)) in &runs[1..] {
        for (w1, w0) in report.windows.iter().zip(&base_report.windows) {
            assert_eq!(w1.window.y0, w0.window.y0, "{tag}: window order @{threads}");
            assert_eq!(w1.fits, w0.fits, "{tag}: per-window fits @{threads}");
            assert_eq!(
                w1.err_sum.to_bits(),
                w0.err_sum.to_bits(),
                "{tag}: per-window error @{threads}"
            );
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn baseline_is_thread_count_invariant() {
    assert_invariant(Method::Baseline, "baseline");
}

#[test]
fn grouping_is_thread_count_invariant() {
    assert_invariant(Method::Grouping, "grouping");
}

#[test]
fn reuse_is_thread_count_invariant() {
    // Reuse threads state across windows: the pipeline must sequence its
    // fits even when loads run wide.
    assert_invariant(Method::Reuse, "reuse");
}

#[test]
fn grouping_ml_is_thread_count_invariant() {
    assert_invariant(Method::GroupingMl, "gml");
}

#[test]
fn adaptive_batching_is_result_invariant() {
    // `pipeline.adaptive_batch` + the backend's occupancy-adaptive
    // controller may only change scheduling granularity (chunk width,
    // fan-out), never results: a fixed-width run and an adaptive run
    // must agree on report aggregates and persisted segment bytes, bit
    // for bit.
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-adapt-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    let mut runs = Vec::new();
    for (tag, adaptive) in [("fixed", false), ("adaptive", true)] {
        let store = root.join(format!("store-{tag}"));
        let backend = make_backend(
            BackendKind::Native,
            "artifacts",
            &BackendOptions {
                batch: 64,
                adaptive,
                ..BackendOptions::default()
            },
        )
        .expect("native backend");
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: 4,
            adaptive_batch: adaptive,
            store_dir: Some(store.to_string_lossy().into_owned()),
            ..PipelineConfig::default()
        };
        let mut pipe =
            Pipeline::new(&ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
        let report = pipe.run_slice(Method::Grouping, 2, TypeSet::Four).expect("run");
        let bytes = std::fs::read(store.join("slice2_grouping_4_default_g0.seg"))
            .expect("segment bytes");
        runs.push((report, bytes));
    }
    assert_eq!(
        fingerprint(&runs[0].0),
        fingerprint(&runs[1].0),
        "adaptive batching changed report aggregates"
    );
    assert!(
        runs[0].1 == runs[1].1,
        "adaptive batching changed persisted segment bytes"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn host_budget_bounds_live_threads_under_nested_backend_calls() {
    // The no-oversubscription acceptance contract: backend chunk
    // fan-out nested inside executor tasks draws from ONE pool budget —
    // the pool's thread census stays budget - 1 (workers) + 1 (helping
    // caller) <= budget, where the old design would have spawned
    // executor_threads x workers scoped threads.
    let budget = 4usize;
    let pool = HostPool::new(budget);
    let exec = Executor::on_pool(8, Arc::clone(&pool));
    let backend = NativeBackend::with_pool(Arc::clone(&pool), 8, 8, 32);
    let mut rng = pdfflow::util::prng::Rng::new(5);
    let values: Vec<f32> = (0..40 * 60).map(|_| rng.gamma(3.0, 2.0) as f32).collect();
    let reference = backend.run_fit_all(&values, 40, 60, 10).unwrap();
    // 16 executor tasks each running a nested batched backend call.
    let outs = exec.run((0..16).collect::<Vec<_>>(), |_| {
        backend.run_fit_all(&values, 40, 60, 10).unwrap().data
    });
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o, &reference.data, "task {i}");
    }
    // Census: the pool never grew beyond its fixed worker set, and no
    // more workers were ever busy at once than exist.
    assert_eq!(pool.spawned_threads(), budget - 1);
    assert!(pool.spawned_threads() < pool.budget());
    let m = pool.metrics();
    assert!(
        m.peak_busy <= pool.spawned_threads(),
        "peak busy {} > workers {}",
        m.peak_busy,
        pool.spawned_threads()
    );
    pool.stop();
    // The global pool (defaults path) obeys the same bound.
    let g = HostPool::global();
    assert_eq!(g.spawned_threads(), g.budget() - 1);
}

#[test]
fn nested_backend_fanout_is_thread_count_invariant() {
    // Executor width x backend width combinations over the shared pool
    // must all produce bit-identical slice results.
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-nested-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    let mut fingerprints = Vec::new();
    for (threads, workers) in [(1usize, 1usize), (2, 4), (8, 2), (8, 8)] {
        let backend = make_backend(
            BackendKind::Native,
            "artifacts",
            &BackendOptions {
                batch: 64,
                workers,
                ..BackendOptions::default()
            },
        )
        .expect("backend");
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: threads,
            workers,
            ..PipelineConfig::default()
        };
        let mut pipe =
            Pipeline::new(&ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
        let report = pipe.run_slice(Method::Grouping, 2, TypeSet::Four).expect("run");
        fingerprints.push(((threads, workers), fingerprint(&report)));
    }
    let (_, base) = fingerprints[0];
    for ((threads, workers), fp) in &fingerprints[1..] {
        assert_eq!(
            *fp, base,
            "diverged at executor_threads={threads} workers={workers}"
        );
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn overlapped_training_matches_ensure_tree_then_run() {
    // run_slice_overlapped (tree training overlapping first-window
    // prefetch) must produce the same fit results and identical
    // persisted bytes as the sequential ensure_tree + run_slice path;
    // only the cache-hit/NFS attribution moves into (unmeasured) setup.
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-overlap-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);

    let seq_store = root.join("store-seq");
    let (seq_report, seq_bytes) = run_at(&ds, Method::GroupingMl, &seq_store, 2);

    let ovl_store = root.join("store-ovl");
    let backend = backend();
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        executor_threads: 2,
        store_dir: Some(ovl_store.to_string_lossy().into_owned()),
        ..PipelineConfig::default()
    };
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
    let ovl_report = pipe
        .run_slice_overlapped(Method::GroupingMl, 2, TypeSet::Four, 0, 500)
        .expect("overlapped run");
    let ovl_bytes =
        std::fs::read(ovl_store.join("slice2_grouping+ml_4_default_g0.seg")).expect("segment bytes");

    assert_eq!(
        seq_report.avg_error.to_bits(),
        ovl_report.avg_error.to_bits(),
        "fit results must not depend on training overlap"
    );
    assert_eq!(seq_report.fits, ovl_report.fits);
    assert_eq!(seq_report.n_points, ovl_report.n_points);
    assert!(seq_bytes == ovl_bytes, "persisted bytes diverge");
    assert!(pipe.model_error.is_some(), "overlap path trained the tree");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn spatial_queries_are_worker_count_invariant() {
    // Spatial answers are a property of the persisted store, not of the
    // host-pool width: box / radius / kNN / cell aggregation / cross-run
    // diff must be bit-identical whether the engine fans its window
    // scans over 1, 2 or 8 workers. Two runs (baseline + grouping) live
    // in one catalog so the diff side exercises RunSelector::Key too.
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-spatial-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    let store = root.join("store");
    run_at(&ds, Method::Baseline, &store, 2);
    run_at(&ds, Method::Grouping, &store, 2);
    let key_a = RunKey::new("baseline", 4, "default");
    let key_b = RunKey::new("grouping", 4, "default");

    let answers = |workers: usize| {
        let opts = QueryOptions {
            workers,
            ..QueryOptions::default()
        };
        let a = QueryEngine::open_run(&store, RunSelector::Key(&key_a), opts).expect("engine a");
        let b = QueryEngine::open_run(&store, RunSelector::Key(&key_b), opts).expect("engine b");
        let bx = BoxQuery {
            x0: 2,
            x1: 13,
            y0: 1,
            y1: 10,
            z0: 1,
            z1: 3,
        };
        let whole = BoxQuery::whole(&a.dims());
        let radius = RadiusQuery {
            x: 8,
            y: 6,
            z: 2,
            radius: 3.5,
        };
        let knn = KnnQuery {
            x: 3,
            y: 4,
            z: 2,
            k: 17,
        };
        (
            a.box_records(&bx).expect("box records"),
            a.box_summary(&bx).expect("box summary"),
            a.radius_records(&radius).expect("radius records"),
            a.knn(&knn).expect("knn"),
            a.cell_aggregate(&whole).expect("cell aggregate"),
            a.diff_run(&b, &whole).expect("diff run"),
        )
    };

    let base = answers(THREADS[0]);
    for threads in &THREADS[1..] {
        assert_eq!(
            answers(*threads),
            base,
            "spatial answers diverge at {threads} workers"
        );
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn telemetry_tracing_does_not_perturb_results() {
    // Span tracing observes the pipeline, it must never participate:
    // the same run with tracing on and off — and at different widths
    // while traced — produces identical report aggregates and
    // bit-identical persisted segment bytes.
    let root = std::env::temp_dir().join(format!(
        "pdfflow-invariance-telemetry-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    pdfflow::telemetry::set_enabled(true);
    let (r_on1, b_on1) = run_at(&ds, Method::Grouping, &root.join("store-on1"), 1);
    let (r_on8, b_on8) = run_at(&ds, Method::Grouping, &root.join("store-on8"), 8);
    pdfflow::telemetry::set_enabled(false);
    let (r_off, b_off) = run_at(&ds, Method::Grouping, &root.join("store-off"), 8);
    pdfflow::telemetry::set_enabled(true);
    assert_eq!(
        fingerprint(&r_on1),
        fingerprint(&r_on8),
        "traced runs diverge across widths"
    );
    assert_eq!(
        fingerprint(&r_on8),
        fingerprint(&r_off),
        "tracing changed report aggregates"
    );
    assert!(b_on1 == b_on8, "traced segment bytes diverge across widths");
    assert!(b_on8 == b_off, "tracing changed persisted segment bytes");
    // The traced runs really did trace: the stage spans exist with a
    // plausible number of closures.
    let spans = pdfflow::telemetry::Registry::global().histogram("span.window.ns");
    assert!(spans.count() > 0, "no window spans were recorded");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn simulated_ledger_is_thread_count_invariant() {
    // The shared SimCluster ledger is merged in window order, so even
    // the *simulated* persist/shuffle accounts (pure functions of bytes,
    // not wall-clock) are identical across widths.
    let root = std::env::temp_dir().join(format!("pdfflow-invariance-ledger-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let ds = dataset(&root);
    let mut persists = Vec::new();
    for threads in THREADS {
        let backend = backend();
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: 4,
            executor_threads: threads,
            store_dir: Some(root.join(format!("s{threads}")).to_string_lossy().into_owned()),
            ..PipelineConfig::default()
        };
        let mut pipe =
            Pipeline::new(&ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
        pipe.run_slice(Method::Grouping, 2, TypeSet::Four).unwrap();
        persists.push(pipe.cluster.account("persist.nfs").to_bits());
    }
    assert!(
        persists.iter().all(|&p| p == persists[0]),
        "persist.nfs diverges across thread counts: {persists:?}"
    );
    std::fs::remove_dir_all(&root).unwrap();
}
