//! Backend parity: the batched [`NativeBackend`] must reproduce the
//! scalar oracle in `pdfflow::stats` — same statistics, same per-type
//! fits, same Algorithm 3 argmin — within 1e-5, for every `DistType`,
//! across every batching edge case (0 points, 1 point, exactly one
//! batch, partial final batch).
//!
//! With `--features xla` (and `make artifacts`), the same harness also
//! checks the PJRT engine against the native backend.

use pdfflow::runtime::{Backend, HostPool, NativeBackend};
use pdfflow::stats::{self, DistType, PointStats, DEFAULT_BINS};
use pdfflow::util::prng::Rng;
use std::sync::Arc;

const TOL: f64 = 1e-5;

/// Seeded draws from each candidate family (guard-safe: every family's
/// own data is inside its support).
fn family_batch(fam: DistType, n: usize, obs: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut values = Vec::with_capacity(n * obs);
    for _ in 0..n * obs {
        let v = match fam {
            DistType::Normal => rng.normal(10.0, 2.0),
            DistType::Uniform => rng.uniform(3.0, 9.0),
            DistType::Exponential => rng.exponential(0.25),
            DistType::Lognormal => rng.lognormal(1.5, 0.4),
            DistType::Cauchy => rng.cauchy(0.0, 2.0),
            DistType::Gamma => rng.gamma(3.0, 2.0),
            DistType::Geometric => rng.geometric(0.4),
            DistType::Logistic => rng.logistic(5.0, 1.5),
            DistType::StudentT => rng.student_t(5.0),
            DistType::Weibull => rng.weibull(2.0, 1.0),
        };
        values.push(v as f32);
    }
    values
}

fn backend_with_batch(batch: usize) -> NativeBackend {
    NativeBackend::with_options(4, batch, DEFAULT_BINS)
}

/// Relative-ish closeness: absolute for small magnitudes.
fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn fit_single_matches_oracle_for_every_dist_type() {
    let obs = 200;
    let n = 24;
    let b = backend_with_batch(16); // forces a partial final batch
    for (i, &data_fam) in DistType::ALL.iter().enumerate() {
        let values = family_batch(data_fam, n, obs, 100 + i as u64);
        for &fit_t in &DistType::ALL {
            let out = b.run_fit_single(&values, n, obs, fit_t).unwrap();
            for p in 0..n {
                let v = &values[p * obs..(p + 1) * obs];
                let oracle = stats::fit_single(v, fit_t, DEFAULT_BINS);
                let row = out.row(p);
                assert!(
                    close(row[0] as f64, oracle.error, TOL),
                    "data {data_fam:?} fit {fit_t:?} point {p}: err {} vs oracle {}",
                    row[0],
                    oracle.error
                );
                for (c, op) in oracle.params.iter().enumerate() {
                    assert!(
                        close(row[1 + c] as f64, *op, TOL),
                        "data {data_fam:?} fit {fit_t:?} point {p} param {c}: {} vs {}",
                        row[1 + c],
                        op
                    );
                }
            }
        }
    }
}

#[test]
fn fit_all_matches_oracle_argmin_for_both_type_sets() {
    let obs = 300;
    let n = 20;
    let b = backend_with_batch(8);
    for (i, &fam) in DistType::ALL.iter().enumerate() {
        let values = family_batch(fam, n, obs, 200 + i as u64);
        for n_types in [4usize, 10] {
            let out = b.run_fit_all(&values, n, obs, n_types).unwrap();
            for p in 0..n {
                let v = &values[p * obs..(p + 1) * obs];
                let oracle = stats::fit_best(v, &DistType::ALL[..n_types], DEFAULT_BINS);
                let row = out.row(p);
                assert_eq!(
                    row[0] as usize,
                    oracle.dist.id(),
                    "data {fam:?} n_types {n_types} point {p}: winner"
                );
                assert!(
                    close(row[1] as f64, oracle.error, TOL),
                    "data {fam:?} n_types {n_types} point {p}: err {} vs {}",
                    row[1],
                    oracle.error
                );
            }
        }
    }
}

#[test]
fn stats_match_oracle_for_every_dist_type() {
    let obs = 500;
    let n = 6;
    let b = backend_with_batch(64);
    for (i, &fam) in DistType::ALL.iter().enumerate() {
        let values = family_batch(fam, n, obs, 300 + i as u64);
        let out = b.run_stats(&values, n, obs).unwrap();
        for p in 0..n {
            let s = PointStats::of(&values[p * obs..(p + 1) * obs]);
            let expect = [
                s.mean, s.std, s.min, s.max, s.skew, s.kurt_ex, s.meanlog, s.stdlog,
                s.q25, s.q50, s.q75, s.pos_frac,
            ];
            let row = out.row(p);
            for (c, e) in expect.iter().enumerate() {
                assert!(
                    close(row[c] as f64, *e, TOL),
                    "data {fam:?} point {p} col {c}: {} vs oracle {}",
                    row[c],
                    e
                );
            }
        }
    }
}

#[test]
fn batching_edge_cases_keep_results_and_shapes() {
    let obs = 100;
    let batch = 16;
    let b = backend_with_batch(batch);
    // Reference computed with a batch big enough to hold everything.
    let big = backend_with_batch(1 << 20);
    for n_points in [0usize, 1, batch, batch + 5, 3 * batch, 3 * batch + 1] {
        let values = family_batch(DistType::Gamma, n_points, obs, 400 + n_points as u64);
        for n_types in [4usize, 10] {
            let out = b.run_fit_all(&values, n_points, obs, n_types).unwrap();
            assert_eq!((out.n_rows, out.n_cols), (n_points, 5), "n={n_points}");
            assert_eq!(out.data.len(), n_points * 5);
            let reference = big.run_fit_all(&values, n_points, obs, n_types).unwrap();
            assert_eq!(out.data, reference.data, "n={n_points} t={n_types}");
        }
        let st = b.run_stats(&values, n_points, obs).unwrap();
        assert_eq!((st.n_rows, st.n_cols), (n_points, 12), "n={n_points}");
    }
    // Execution accounting: ceil-div chunks, every row exactly once.
    b.reset_metrics();
    let values = family_batch(DistType::Normal, batch + 5, obs, 7);
    b.run_fit_all(&values, batch + 5, obs, 4).unwrap();
    let m = b.metrics();
    assert_eq!(m.executions, 2);
    assert_eq!(m.rows_processed, (batch + 5) as u64);
}

#[test]
fn fused_kernel_is_bit_identical_to_stats_oracle() {
    // Stronger than the 1e-5 closeness: the fused batched kernels must
    // agree with the scalar oracle to the last f32 bit, across worker /
    // batch / pool-budget combinations, for every DistType's data.
    let obs = 180;
    let n = 21;
    for (i, &fam) in DistType::ALL.iter().enumerate() {
        let values = family_batch(fam, n, obs, 500 + i as u64);
        for (budget, workers, batch) in [(1usize, 1usize, 4usize), (2, 4, 8), (6, 8, 64)] {
            let pool = HostPool::new(budget);
            let b = NativeBackend::with_pool(Arc::clone(&pool), workers, batch, DEFAULT_BINS);
            let st = b.run_stats(&values, n, obs).unwrap();
            let all = b.run_fit_all(&values, n, obs, 10).unwrap();
            for p in 0..n {
                let v = &values[p * obs..(p + 1) * obs];
                let s = PointStats::of(v);
                let expect = [
                    s.mean, s.std, s.min, s.max, s.skew, s.kurt_ex, s.meanlog, s.stdlog,
                    s.q25, s.q50, s.q75, s.pos_frac,
                ];
                for (c, e) in expect.iter().enumerate() {
                    assert_eq!(
                        st.row(p)[c].to_bits(),
                        (*e as f32).to_bits(),
                        "{fam:?} budget {budget} point {p} stats col {c}"
                    );
                }
                let oracle = stats::fit_best(v, &DistType::ALL, DEFAULT_BINS);
                let row = all.row(p);
                assert_eq!(row[0].to_bits(), (oracle.dist.id() as f32).to_bits());
                assert_eq!(
                    row[1].to_bits(),
                    (oracle.error as f32).to_bits(),
                    "{fam:?} budget {budget} point {p} error"
                );
                for c in 0..3 {
                    assert_eq!(
                        row[2 + c].to_bits(),
                        (oracle.params[c] as f32).to_bits(),
                        "{fam:?} budget {budget} point {p} param {c}"
                    );
                }
            }
            pool.stop();
        }
    }
}

#[test]
fn unsupported_types_get_penalty_error() {
    // Negative data: exponential/lognormal/gamma/geometric/weibull guards
    // must fire identically in the batched path and the oracle.
    let obs = 150;
    let n = 10;
    let mut rng = Rng::new(9);
    let values: Vec<f32> = (0..n * obs).map(|_| rng.normal(-50.0, 1.0) as f32).collect();
    let b = backend_with_batch(4);
    for t in [
        DistType::Exponential,
        DistType::Lognormal,
        DistType::Gamma,
        DistType::Geometric,
        DistType::Weibull,
    ] {
        let out = b.run_fit_single(&values, n, obs, t).unwrap();
        for p in 0..n {
            assert_eq!(out.row(p)[0] as f64, stats::PENALTY_ERROR, "{t:?} point {p}");
        }
    }
}

#[test]
fn simd_and_scalar_paths_are_bit_identical() {
    // The SIMD tolerance policy is zero: forced-scalar and
    // runtime-dispatched (`PDFFLOW_SIMD=scalar` vs `auto`) runs must
    // produce byte-identical output matrices for all 10 DistTypes at
    // every tested length — including observation counts around the
    // 4-lane width (width−1, width, width+1) and non-multiple tails.
    // On hardware without AVX2 both modes run the same scalar loops and
    // the comparison is trivially true; the CI matrix runs the whole
    // suite under both env values so each mode also gets a full pass.
    use pdfflow::stats::simd::{self, SimdMode};
    let prev = simd::mode();
    let obs_lens = [2usize, 3, 4, 5, 7, 8, 9, 31, 32, 33, 100, 257];
    let mut rng = Rng::new(20180603);
    for (i, &fam) in DistType::ALL.iter().enumerate() {
        for &obs in &obs_lens {
            // A couple of randomized point counts per (family, length).
            for _ in 0..2 {
                let n = 1 + (rng.uniform(0.0, 24.0) as usize);
                let values = family_batch(fam, n, obs, 500 + i as u64 + obs as u64);
                let b = backend_with_batch(8);
                simd::set_mode(SimdMode::Scalar);
                let scalar_fit = b.run_fit_all(&values, n, obs, 10).unwrap();
                let scalar_stats = b.run_stats(&values, n, obs).unwrap();
                let scalar_single = b.run_fit_single(&values, n, obs, fam).unwrap();
                simd::set_mode(SimdMode::Auto);
                let auto_fit = b.run_fit_all(&values, n, obs, 10).unwrap();
                let auto_stats = b.run_stats(&values, n, obs).unwrap();
                let auto_single = b.run_fit_single(&values, n, obs, fam).unwrap();
                assert_eq!(scalar_fit.data, auto_fit.data, "{fam:?} obs={obs} fit_all");
                assert_eq!(scalar_stats.data, auto_stats.data, "{fam:?} obs={obs} stats");
                assert_eq!(scalar_single.data, auto_single.data, "{fam:?} obs={obs} single");
            }
        }
    }
    simd::set_mode(prev);
}

#[cfg(feature = "xla")]
mod xla_parity {
    use super::*;

    fn xla_backend() -> Box<dyn Backend> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Box::new(pdfflow::runtime::Engine::load_default(dir).expect("run `make artifacts` first"))
    }

    #[test]
    fn xla_tracks_native_within_f32_slack() {
        let e = xla_backend();
        let nb = backend_with_batch(64);
        let values = family_batch(DistType::Gamma, 32, 100, 11);
        let a = e.run_fit_all(&values, 32, 100, 10).unwrap();
        let b = nb.run_fit_all(&values, 32, 100, 10).unwrap();
        for p in 0..32 {
            let (ra, rb) = (a.row(p), b.row(p));
            // f32 HLO vs f64 oracle: same winner, or near-tied errors.
            assert!(
                ra[0] == rb[0] || (ra[1] as f64 - rb[1] as f64).abs() < 0.02,
                "point {p}: xla {ra:?} vs native {rb:?}"
            );
        }
    }
}
