//! End-to-end pdfstore tests: a pipeline run persists a slice, a fresh
//! process-equivalent reopen (catalog alone, no rescan) serves point /
//! region / quantile queries, and concurrent reads are bit-identical to
//! single-threaded ones. Also covers the corruption surface: truncated
//! segments, flipped payload bytes and tampered catalogs must all be
//! rejected rather than served. (Generational / compaction / crash
//! coverage lives in `tests/store_generations.rs`.)

use std::path::PathBuf;

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::PointId;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::pdfstore::{
    PdfStore, QueryEngine, QueryOptions, RegionQuery, CATALOG_NAME, REC_LEN,
};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::executor::Executor;

const SLICE: usize = 1;

fn backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("native backend")
}

/// Generate a tiny dataset and persist SLICE through both sinks.
/// Returns (root dir, store dir, legacy .pdfout path, persisted points).
fn build_store(tag: &str) -> (PathBuf, PathBuf, PathBuf, usize) {
    let root = std::env::temp_dir().join(format!(
        "pdfflow-storetest-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store_dir = root.join("store");
    let legacy_dir = root.join("legacy");
    let mut cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        ..PipelineConfig::default()
    };
    cfg.store_dir = Some(store_dir.to_string_lossy().into_owned());
    cfg.persist_dir = Some(legacy_dir.to_string_lossy().into_owned());
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    let r = pipe.run_slice(Method::Baseline, SLICE, TypeSet::Four).unwrap();
    // Both sinks write one 28-byte record per point; the cluster was
    // charged for the persisted bytes.
    assert_eq!(r.persist_bytes, 2 * (r.n_points * REC_LEN) as u64);
    assert!(r.persist_sim_s > 0.0);
    assert!(pipe.cluster.account("persist.nfs") > 0.0);
    assert_eq!(r.cache_hits + r.cache_misses, r.windows.len());
    let legacy = legacy_dir.join(format!("slice{SLICE}_baseline_4.pdfout"));
    (root, store_dir, legacy, r.n_points)
}

#[test]
fn reopen_cold_and_query_bit_identical_to_legacy_persist() {
    let (root, store_dir, legacy, n_points) = build_store("roundtrip");
    // Cold reopen: manifest + footers only, then full checksum pass.
    let store = PdfStore::open(&store_dir).unwrap();
    assert_eq!(store.n_segments(), 1);
    assert_eq!(store.n_records(), n_points as u64);
    store.verify().unwrap();

    let engine = QueryEngine::new(store, QueryOptions::default());
    let legacy_bytes = std::fs::read(&legacy).unwrap();
    assert_eq!(legacy_bytes.len(), n_points * REC_LEN);
    // Every point: the stored record must re-encode to the exact bytes
    // the legacy persist path wrote (bit-identical params).
    for row in legacy_bytes.chunks_exact(REC_LEN) {
        let id = PointId(u64::from_le_bytes(row[0..8].try_into().unwrap()));
        let rec = engine.point_by_id(id).unwrap();
        let mut buf = [0u8; REC_LEN];
        rec.encode(&mut buf);
        assert_eq!(&buf[..], row, "point {id:?} not bit-identical");
    }
    // Region scan over the whole slice covers every record once.
    let dims = engine.dims();
    let full = engine.region(&RegionQuery::slice(&dims, SLICE)).unwrap();
    assert_eq!(full.len(), n_points);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn concurrent_queries_match_single_threaded() {
    let (root, store_dir, _, n_points) = build_store("concurrent");
    let serial = QueryEngine::open(
        &store_dir,
        QueryOptions {
            workers: 1,
            ..QueryOptions::default()
        },
    )
    .unwrap();
    let parallel = QueryEngine::open(
        &store_dir,
        QueryOptions {
            workers: 4,
            // Tiny budget so concurrent reads also exercise eviction.
            cache_bytes: 4 * 4 * 16 * REC_LEN as u64,
            shards: 2,
            ..QueryOptions::default()
        },
    )
    .unwrap();
    let dims = serial.dims();
    let ids: Vec<PointId> = (0..n_points as u64)
        .map(|i| PointId(dims.slice_points() as u64 * SLICE as u64 + i))
        .collect();

    // Point queries: batched 4-thread reads == sequential reads.
    let seq: Vec<_> = ids.iter().map(|&id| serial.point_by_id(id).unwrap()).collect();
    let par = parallel.points(&ids).unwrap();
    assert_eq!(par, seq);
    // Raw 4-way fan-out through the shared pool hits the same records.
    let exec = Executor::new(4);
    let fanned = exec.run(ids.clone(), |id| parallel.point_by_id(id).unwrap());
    assert_eq!(fanned, seq);

    // Region + quantile analytics: identical at any thread count.
    let q = RegionQuery {
        z: SLICE,
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
    };
    let s1 = serial.region_summary(&q).unwrap();
    let s4 = parallel.region_summary(&q).unwrap();
    assert_eq!(s1, s4);
    assert_eq!(s1.n_points, q.n_points());
    assert_eq!(s1.type_counts.iter().sum::<u64>(), q.n_points() as u64);
    let m1 = serial.region_quantile_mean(&q, 0.5).unwrap();
    let m4 = parallel.region_quantile_mean(&q, 0.5).unwrap();
    assert_eq!(m1.to_bits(), m4.to_bits(), "{m1} vs {m4}");

    // Concurrent mixed workload on one shared engine stays identical.
    let mixed = exec.run((0..8).collect::<Vec<usize>>(), |i| {
        if i % 2 == 0 {
            parallel.region_summary(&q).unwrap().avg_error
        } else {
            parallel.region_quantile_mean(&q, 0.5).unwrap()
        }
    });
    for (i, v) in mixed.iter().enumerate() {
        let want = if i % 2 == 0 { s1.avg_error } else { m1 };
        assert_eq!(v.to_bits(), want.to_bits());
    }
    let meters = parallel.meters();
    assert!(meters.hits + meters.misses > 0);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn truncated_segment_is_rejected_at_open() {
    let (root, store_dir, _, _) = build_store("trunc");
    let store = PdfStore::open(&store_dir).unwrap();
    let seg_file = store_dir.join(&store.run().segments[0].file);
    drop(store);
    let len = std::fs::metadata(&seg_file).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&seg_file).unwrap();
    f.set_len(len - 13).unwrap();
    drop(f);
    assert!(PdfStore::open(&store_dir).is_err(), "truncated segment served");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn corrupt_payload_fails_verify_and_tampered_catalog_fails_open() {
    let (root, store_dir, _, _) = build_store("corrupt");
    let store = PdfStore::open(&store_dir).unwrap();
    let seg_file = store_dir.join(&store.run().segments[0].file);
    drop(store);
    // Flip one payload byte (length unchanged): open still succeeds off
    // the index, but the full checksum pass must fail.
    let mut bytes = std::fs::read(&seg_file).unwrap();
    bytes[40] ^= 0x01;
    std::fs::write(&seg_file, &bytes).unwrap();
    let store = PdfStore::open(&store_dir).unwrap();
    assert!(store.verify().is_err(), "corrupt payload passed verify");
    drop(store);
    // Tampered catalog body (DatasetSpec::tiny has 100 observations;
    // claim 101): the self-checksum must reject it.
    let cpath = store_dir.join(CATALOG_NAME);
    let text = std::fs::read_to_string(&cpath).unwrap();
    let tampered = text.replacen("\"n_obs\":100", "\"n_obs\":101", 1);
    assert_ne!(text, tampered);
    std::fs::write(&cpath, tampered).unwrap();
    assert!(PdfStore::open(&store_dir).is_err(), "tampered catalog accepted");
    std::fs::remove_dir_all(&root).unwrap();
}
