//! Property-based tests (via util::testkit, the offline proptest
//! substitute) over the coordinator's invariants: routing, grouping,
//! window coverage, codecs, cluster accounting, tree behaviour.

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::cube::CubeDims;
use pdfflow::executor::Executor;
use pdfflow::mltree::{DecisionTree, Sample, TreeParams};
use pdfflow::prop_assert;
use pdfflow::rdd::Rdd;
use pdfflow::sampling::{random_sample, SliceFeatures};
use pdfflow::stats::{self, DistType, PointStats, DEFAULT_BINS, PENALTY_ERROR};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;
use pdfflow::util::testkit::check;
use pdfflow::util::toml::TomlDoc;

fn random_dims(rng: &mut Rng) -> CubeDims {
    CubeDims::new(
        1 + rng.below(40),
        1 + rng.below(40),
        1 + rng.below(20),
    )
}

#[test]
fn prop_windows_partition_every_slice_point_exactly_once() {
    check("window_partition", 50, |rng| {
        let dims = random_dims(rng);
        let z = rng.below(dims.nz);
        let w = 1 + rng.below(dims.ny + 3); // may exceed ny
        let windows = dims.windows(z, w);
        let mut seen = std::collections::HashSet::new();
        for win in &windows {
            for p in dims.window_points(win) {
                prop_assert!(seen.insert(p), "point {p:?} covered twice");
            }
        }
        prop_assert!(
            seen.len() == dims.slice_points(),
            "covered {} of {} points",
            seen.len(),
            dims.slice_points()
        );
        Ok(())
    });
}

#[test]
fn prop_point_id_roundtrip() {
    check("point_id_roundtrip", 100, |rng| {
        let dims = random_dims(rng);
        let (x, y, z) = (rng.below(dims.nx), rng.below(dims.ny), rng.below(dims.nz));
        let id = dims.point_id(x, y, z);
        prop_assert!(dims.coords(id) == (x, y, z), "roundtrip failed at {x},{y},{z}");
        Ok(())
    });
}

#[test]
fn prop_rdd_aggregate_by_key_is_a_partition_of_inputs() {
    check("aggregate_partition", 40, |rng| {
        let n = 1 + rng.below(500);
        let n_keys = 1 + rng.below(20);
        let parts = 1 + rng.below(8);
        let threads = 1 + rng.below(8);
        let items: Vec<(u64, u64)> = (0..n)
            .map(|i| (rng.below(n_keys) as u64, i as u64))
            .collect();
        let mut expected: Vec<u64> = items.iter().map(|(_, v)| *v).collect();
        expected.sort_unstable();
        let exec = Executor::new(threads);
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let (grouped, _) = Rdd::from_vec(items, parts).aggregate_by_key(
            parts,
            &exec,
            &cluster,
            "s",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_, c| c.len() as u64,
        );
        let mut got: Vec<u64> = grouped
            .collect(&exec)
            .into_iter()
            .flat_map(|(_, vs)| vs)
            .collect();
        got.sort_unstable();
        prop_assert!(got == expected, "values lost or duplicated by shuffle");
        Ok(())
    });
}

#[test]
fn prop_eq5_error_bounded_for_every_type() {
    check("eq5_bounds", 30, |rng| {
        let n = 50 + rng.below(500);
        let shift = rng.uniform(-10.0, 10.0);
        let scale = rng.uniform(0.1, 100.0);
        let v: Vec<f32> = (0..n)
            .map(|_| (shift + scale * rng.std_normal()) as f32)
            .collect();
        for &t in &DistType::ALL {
            let f = stats::fit_single(&v, t, DEFAULT_BINS);
            prop_assert!(
                (0.0..=PENALTY_ERROR).contains(&f.error),
                "{t:?} error {} out of bounds",
                f.error
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fit_best_never_worse_than_any_candidate() {
    check("fit_best_min", 25, |rng| {
        let n = 100 + rng.below(400);
        let v: Vec<f32> = (0..n).map(|_| rng.gamma(2.0, 3.0) as f32).collect();
        let best = stats::fit_best(&v, &DistType::ALL, DEFAULT_BINS);
        for &t in &DistType::ALL {
            let f = stats::fit_single(&v, t, DEFAULT_BINS);
            prop_assert!(
                best.error <= f.error + 1e-12,
                "best {:?} {} beaten by {t:?} {}",
                best.dist,
                best.error,
                f.error
            );
        }
        Ok(())
    });
}

#[test]
fn prop_scaling_preserves_normal_uniform_fit_quality() {
    // Multiplicative gains (the generator's grouping mechanism) must not
    // change which family fits: normal stays normal under scaling.
    check("scale_invariance", 20, |rng| {
        let n = 800;
        let base: Vec<f64> = (0..n).map(|_| rng.normal(10.0, 1.5)).collect();
        let gain = rng.uniform(0.5, 2.0);
        let v: Vec<f32> = base.iter().map(|x| (x * gain) as f32).collect();
        let f = stats::fit_single(&v, DistType::Normal, DEFAULT_BINS);
        prop_assert!(f.error < 0.35, "scaled normal fit error {}", f.error);
        Ok(())
    });
}

#[test]
fn prop_point_stats_shift_and_scale() {
    check("stats_affine", 40, |rng| {
        let n = 100 + rng.below(200);
        let v: Vec<f32> = (0..n).map(|_| rng.std_normal() as f32).collect();
        let scale = rng.uniform(0.5, 10.0);
        let shift = rng.uniform(-5.0, 5.0);
        let w: Vec<f32> = v.iter().map(|x| (*x as f64 * scale + shift) as f32).collect();
        let sv = PointStats::of(&v);
        let sw = PointStats::of(&w);
        prop_assert!(
            (sw.mean - (sv.mean * scale + shift)).abs() < 1e-3 * (1.0 + sw.mean.abs()),
            "mean affine"
        );
        prop_assert!(
            (sw.std - sv.std * scale).abs() < 1e-3 * (1.0 + sw.std.abs()),
            "std scale"
        );
        Ok(())
    });
}

#[test]
fn prop_histogram_mass_conserved() {
    check("histogram_mass", 40, |rng| {
        let n = 1 + rng.below(1000);
        let bins = 1 + rng.below(64);
        let v: Vec<f32> = (0..n).map(|_| rng.cauchy(0.0, 2.0) as f32).collect();
        let s = PointStats::of(&(if v.len() >= 2 { v.clone() } else { vec![v[0], v[0]] }));
        let h = stats::histogram(&v, s.min, s.max, bins);
        let total: f64 = h.iter().sum();
        prop_assert!(total == v.len() as f64, "mass {total} != {}", v.len());
        Ok(())
    });
}

#[test]
fn prop_random_sample_sorted_distinct_in_range() {
    check("random_sample", 50, |rng| {
        let n = 1 + rng.below(5000);
        let rate = rng.f64();
        let s = random_sample(rng, n, rate);
        prop_assert!(!s.is_empty() && s.len() <= n);
        prop_assert!(s.windows(2).all(|w| w[0] < w[1]), "not sorted-distinct");
        prop_assert!(*s.last().unwrap() < n, "index out of range");
        Ok(())
    });
}

#[test]
fn prop_slice_features_percentages_sum_to_one() {
    check("features_sum", 30, |rng| {
        let n = 1 + rng.below(300);
        let means: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let stds: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let types: Vec<DistType> = (0..n)
            .map(|_| DistType::from_id(rng.below(10)).unwrap())
            .collect();
        let f = SliceFeatures::from_points(&means, &stds, &types);
        let sum: f64 = f.type_percentages.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9, "percentages sum {sum}");
        Ok(())
    });
}

#[test]
fn prop_tree_json_roundtrip_predictions() {
    check("tree_roundtrip", 10, |rng| {
        let n = 50 + rng.below(200);
        let samples: Vec<Sample> = (0..n)
            .map(|_| {
                let label = rng.below(4);
                Sample {
                    features: vec![
                        label as f64 * 3.0 + rng.std_normal() * 0.3,
                        rng.std_normal(),
                    ],
                    label,
                }
            })
            .collect();
        let tree = DecisionTree::train(&samples, TreeParams::default())
            .map_err(|e| e.to_string())?;
        let back = DecisionTree::from_json(
            &Json::parse(&tree.to_json().to_string()).map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        for s in &samples {
            prop_assert!(
                tree.predict(&s.features) == back.predict(&s.features),
                "roundtrip prediction diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    check("json_roundtrip", 60, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.f64() < 0.5),
                2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round()),
                3 => Json::Str(format!("s{}\n\"x\"", rng.below(1000))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let j = gen(rng, 3);
        let round = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        prop_assert!(round == j, "json roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_toml_numbers_roundtrip() {
    check("toml_numbers", 50, |rng| {
        let i = rng.next_u64() as i64 / 1000;
        let f = rng.uniform(-1e6, 1e6);
        let doc = format!("a = {i}\nb = {f:.6}\n");
        let d = TomlDoc::parse(&doc).map_err(|e| e)?;
        prop_assert!(d.i64_or("a", i64::MIN) == i, "int roundtrip");
        prop_assert!((d.f64_or("b", f64::NAN) - f).abs() < 1e-3, "float roundtrip");
        Ok(())
    });
}

#[test]
fn prop_cluster_stage_bounds() {
    // Makespan is bounded below by the longest task and the average load,
    // and above by serial execution.
    check("stage_bounds", 40, |rng| {
        let spec = ClusterSpec::g5k(1 + rng.below(64));
        let slots = spec.total_slots() as f64;
        let overhead = spec.task_overhead;
        let n = 1 + rng.below(300);
        let costs: Vec<f64> = (0..n).map(|_| rng.f64() * 0.1).collect();
        let c = SimCluster::new(spec);
        let t = c.run_stage("s", &costs);
        let with_oh: Vec<f64> = costs.iter().map(|x| x + overhead).collect();
        let serial: f64 = with_oh.iter().sum();
        let longest = with_oh.iter().cloned().fold(0.0, f64::max);
        prop_assert!(t <= serial + 1e-9, "makespan above serial");
        prop_assert!(t >= longest - 1e-9, "makespan below longest task");
        prop_assert!(t >= serial / slots - 1e-9, "makespan below average load");
        Ok(())
    });
}

#[test]
fn prop_shuffle_monotone_in_bytes() {
    check("shuffle_monotone", 30, |rng| {
        let nodes = 2 + rng.below(63);
        let a = rng.below(1 << 28) as u64;
        let b = a + rng.below(1 << 28) as u64;
        let ta = SimCluster::new(ClusterSpec::g5k(nodes)).charge_shuffle("s", a);
        let tb = SimCluster::new(ClusterSpec::g5k(nodes)).charge_shuffle("s", b);
        prop_assert!(tb >= ta - 1e-12, "shuffle not monotone: {a}B->{ta}s {b}B->{tb}s");
        Ok(())
    });
}

#[test]
fn prop_rdd_from_vec_balances_all_edge_cases() {
    // Satellite invariants: 0 items, n_partitions == 0, and
    // n_partitions > items must all yield max(1, requested) partitions
    // whose sizes differ by at most one, preserving item order.
    check("rdd_balance", 120, |rng| {
        let n = rng.below(200); // includes 0 items
        let parts = rng.below(12); // includes 0 partitions
        let threads = 1 + rng.below(6);
        let exec = Executor::new(threads);
        let items: Vec<u32> = (0..n as u32).collect();
        let r = Rdd::from_vec(items.clone(), parts);
        prop_assert!(
            r.n_partitions() == parts.max(1),
            "{} partitions for request {parts}",
            r.n_partitions()
        );
        let partitions = r.collect_partitions(&exec);
        let sizes: Vec<usize> = partitions.iter().map(|p| p.len()).collect();
        let mn = sizes.iter().copied().min().unwrap();
        let mx = sizes.iter().copied().max().unwrap();
        prop_assert!(mx - mn <= 1, "unbalanced: {sizes:?} for {n} items");
        let flat: Vec<u32> = partitions.into_iter().flatten().collect();
        prop_assert!(flat == items, "order not preserved");
        Ok(())
    });
}

#[test]
fn prop_rdd_coalesce_preserves_items_and_order() {
    // Coalesce merges *contiguous* runs of source partitions (Spark's
    // adjacent-merge, now lazy): partition count shrinks to the target
    // and the flattened item order never changes.
    check("rdd_coalesce", 120, |rng| {
        let n = rng.below(150);
        let parts = 1 + rng.below(10);
        let target = rng.below(14); // may be 0 or above current count
        let threads = 1 + rng.below(6);
        let exec = Executor::new(threads);
        let items: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let r = Rdd::from_vec(items.clone(), parts).coalesce(target);
        let want = parts.min(target.max(1));
        prop_assert!(
            r.n_partitions() == want,
            "{} partitions, wanted {want} (from {parts}, target {target})"
        );
        let partitions = r.collect_partitions(&exec);
        prop_assert!(
            partitions.iter().all(|p| !p.is_empty()) || n < parts,
            "empty partition without item shortage"
        );
        let flat: Vec<u32> = partitions.into_iter().flatten().collect();
        prop_assert!(flat == items, "coalesce reordered items");
        Ok(())
    });
}

#[test]
fn prop_pdf_record_codec_roundtrips_bit_exact() {
    use pdfflow::cube::PointId;
    use pdfflow::pdfstore::{PdfRecord, REC_LEN};
    check("pdf_record_codec", 200, |rng| {
        let rec = PdfRecord {
            point: PointId(rng.next_u64() >> 1),
            dist: DistType::from_id(rng.below(10)).unwrap(),
            error: rng.uniform(0.0, 2.0) as f32,
            params: [
                rng.uniform(-1e6, 1e6) as f32,
                rng.uniform(-1e6, 1e6) as f32,
                rng.uniform(-1e6, 1e6) as f32,
            ],
        };
        let mut buf = [0u8; REC_LEN];
        rec.encode(&mut buf);
        let back = PdfRecord::decode(&buf).map_err(|e| e.to_string())?;
        prop_assert!(back == rec, "decode({rec:?}) = {back:?}");
        let mut buf2 = [0u8; REC_LEN];
        back.encode(&mut buf2);
        prop_assert!(buf == buf2, "re-encode not bit-identical");
        Ok(())
    });
}
