//! Seeded fault-torture suite: the store's single safety contract is
//! that under injected I/O faults, on-disk bit rot, and truncation,
//! every query returns one of exactly three outcomes — a bit-identical
//! answer (possibly flagged `degraded`), or a typed error. **Never a
//! silently different answer.**
//!
//! Fault state is process-global, so every test serializes on one
//! mutex and disarms via a drop guard even on panic. Run under a
//! different seed with `PDFFLOW_TORTURE_SEED=<n>` (CI runs seeds 1 and
//! 2 across both SIMD modes); the randomized rounds derive their fault
//! specs from it, the scripted scenarios are seed-fixed by design.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::PointId;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::fault;
use pdfflow::pdfstore::{
    scrub_store, PdfRecord, PdfStore, QueryEngine, QueryOptions, ReadPath, RegionQuery,
    QUARANTINED,
};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::serve::{Class, Reply, Request, ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery};
use pdfflow::telemetry::{self, flight, Registry};
use pdfflow::util::prng::Rng;
use pdfflow::{PdfflowError, Result};

/// Serialize every test in this binary: the fault plan is one global.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarm on scope exit so a panicking scenario can't leak its faults
/// into the next one.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn torture_seed() -> u64 {
    std::env::var("PDFFLOW_TORTURE_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn counter(name: &str) -> u64 {
    Registry::global().counter(name).get()
}

fn backend() -> Box<dyn Backend> {
    make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            ..BackendOptions::default()
        },
    )
    .expect("native backend")
}

fn root_dir(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("pdfflow-torture-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn pipeline_cfg(store_dir: &Path) -> PipelineConfig {
    PipelineConfig {
        batch: 64,
        window_lines: 4,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        run_id: Some("t".to_string()),
        ..PipelineConfig::default()
    }
}

/// Two generations of slice 1 under run "t": g1 fully shadows g0, so
/// quarantining g1 must fall back to g0 with bit-identical answers.
fn build_two_gen(root: &Path) -> (SyntheticDataset, PathBuf) {
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let store = root.join("store");
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store),
    );
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    (ds, store)
}

const NEWEST_GEN: &str = "slice1_baseline_4_t_g1.seg";

fn copy_store(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

fn flip_byte(path: &Path, at: usize) {
    let mut bytes = std::fs::read(path).unwrap();
    bytes[at] ^= 0x01;
    std::fs::write(path, bytes).unwrap();
}

fn fold_record(acc: u64, rec: &PdfRecord) -> u64 {
    acc.rotate_left(7)
        .wrapping_add(rec.point.0)
        .wrapping_add((rec.dist.id() as u64) << 48)
        .wrapping_add(rec.error.to_bits() as u64)
        .wrapping_add((rec.params[0].to_bits() as u64) << 16)
        .wrapping_add((rec.params[1].to_bits() as u64) << 24)
        .wrapping_add((rec.params[2].to_bits() as u64) << 32)
}

/// Fallible bit-exact fingerprint over the query surface of one slice:
/// record scan, region summary, quantile surface, spatial box and kNN.
/// Equal u64 ⇔ every answer is bit-identical to the pristine store.
fn try_fingerprint(engine: &QueryEngine, z: usize) -> Result<u64> {
    let dims = engine.dims();
    let full = RegionQuery::slice(&dims, z);
    let mut acc = 0x9e37_79b9_7f4a_7c15u64;
    for rec in engine.region(&full)? {
        acc = fold_record(acc, &rec);
    }
    let s = engine.region_summary(&full)?;
    acc = acc.rotate_left(9).wrapping_add(s.avg_error.to_bits());
    acc = acc.rotate_left(9).wrapping_add(s.max_error.to_bits());
    let q = RegionQuery {
        z,
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
    };
    let m = engine.region_quantile_mean(&q, 0.5)?;
    acc = acc.rotate_left(9).wrapping_add(m.to_bits());
    let bx = BoxQuery {
        x0: 1,
        x1: dims.nx - 2,
        y0: 1,
        y1: dims.ny - 2,
        z0: z.saturating_sub(1),
        z1: (z + 1).min(dims.nz - 1),
    };
    for rec in engine.box_records(&bx)? {
        acc = fold_record(acc, &rec);
    }
    let near = KnnQuery {
        x: 1,
        y: 2,
        z,
        k: 9,
    };
    for rec in engine.knn(&near)? {
        acc = fold_record(acc, &rec);
    }
    Ok(acc)
}

/// The torture contract for one damaged store copy: open or query may
/// fail with a typed error, or every answer must be bit-identical to
/// the pristine store and flagged degraded — never silent garbage.
fn expect_flagged_or_typed(dir: &Path, name: &str, pristine: u64) {
    match QueryEngine::open(dir, QueryOptions::default()) {
        Err(e) => assert!(!e.to_string().is_empty(), "{name}: untyped open error"),
        Ok(engine) => match try_fingerprint(&engine, 1) {
            Ok(fp) => {
                assert_eq!(fp, pristine, "{name}: silent corruption in a query answer");
                assert!(
                    engine.store().is_degraded() || engine.store().n_quarantined() > 0,
                    "{name}: fallback answer was not flagged"
                );
                assert!(engine.store().verify_report().n_bad() >= 1, "{name}");
            }
            Err(e) => assert!(!e.to_string().is_empty(), "{name}: untyped query error"),
        },
    }
}

fn pristine_fingerprint(store: &Path) -> u64 {
    let engine = QueryEngine::open(store, QueryOptions::default()).unwrap();
    try_fingerprint(&engine, 1).expect("pristine store must answer")
}

#[test]
fn transient_read_faults_retry_to_bit_identical_answers() {
    let _g = gate();
    let root = root_dir("retry");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);

    let _disarm = Disarm;
    fault::install("seed=1,segment.read=io:1:2,retry=4:0").unwrap();
    let attempts0 = counter(fault::RETRY_ATTEMPTS);
    let injected0 = counter(fault::INJECTED);
    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let fp = try_fingerprint(&engine, 1).expect("retries must absorb transient faults");
    assert_eq!(fp, pristine, "retried reads changed query answers");
    assert!(!engine.store().is_degraded(), "transient faults are not degradation");
    assert!(
        counter(fault::RETRY_ATTEMPTS) - attempts0 >= 2,
        "both injected faults should have been retried"
    );
    assert!(counter(fault::INJECTED) - injected0 >= 2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn checksum_quarantine_falls_back_to_prior_generation() {
    let _g = gate();
    let root = root_dir("quarantine");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);

    // Flip one payload byte of the newest generation on disk. Open
    // succeeds (the payload is not rescanned), so the damage must be
    // caught by the per-window checksum at read time.
    let g1 = store.join(NEWEST_GEN);
    let len = std::fs::metadata(&g1).unwrap().len() as usize;
    flip_byte(&g1, len / 3);

    telemetry::set_enabled(true);
    let _events = flight::take_events();
    let quarantined0 = counter(QUARANTINED);
    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let fp = try_fingerprint(&engine, 1).expect("prior generation must cover the slice");
    telemetry::set_enabled(false);

    assert_eq!(fp, pristine, "generation fallback changed query answers");
    assert!(engine.store().is_degraded(), "fallback answers must be flagged");
    assert_eq!(engine.store().n_quarantined(), 1);
    assert!(counter(QUARANTINED) - quarantined0 >= 1);
    let report = engine.store().verify_report();
    assert_eq!(report.n_bad(), 1);
    let bad = report.segments.iter().find(|s| s.error.is_some()).unwrap();
    assert_eq!(bad.file, NEWEST_GEN);
    let events = flight::take_events();
    assert!(
        events.iter().any(|e| e.name == "store.quarantine"),
        "quarantine must land in the flight recorder"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn corruption_matrix_never_returns_silent_garbage() {
    let _g = gate();
    let root = root_dir("matrix");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);
    let len = std::fs::metadata(store.join(NEWEST_GEN)).unwrap().len() as usize;

    // One flip per structural region of the newest-generation segment
    // (header, payload, footer index, trailer checksum), plus a
    // truncation. Detection points differ (open-time vs read-time);
    // the contract does not.
    let flips = [
        ("header", 4),
        ("payload", len / 3),
        ("footer", len - 28 - 8),
        ("trailer", len - 10),
    ];
    for (name, at) in flips {
        let dir = root.join(name);
        copy_store(&store, &dir);
        flip_byte(&dir.join(NEWEST_GEN), at);
        expect_flagged_or_typed(&dir, name, pristine);
    }
    let dir = root.join("truncate");
    copy_store(&store, &dir);
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(dir.join(NEWEST_GEN))
        .unwrap();
    f.set_len(len as u64 - 10).unwrap();
    drop(f);
    expect_flagged_or_typed(&dir, "truncate", pristine);

    // With no prior generation to fall back to, payload damage must be
    // a typed error — lost coverage is never a shrunken answer.
    let single = root.join("single");
    let ds2 = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data2")).unwrap();
    let backend = backend();
    let mut pipe = Pipeline::new(
        &ds2,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&single),
    );
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    let g0 = single.join("slice1_baseline_4_t_g0.seg");
    let single_len = std::fs::metadata(&g0).unwrap().len() as usize;
    flip_byte(&g0, single_len / 3);
    let engine = QueryEngine::open(&single, QueryOptions::default()).unwrap();
    let dims = engine.dims();
    let err = match engine.region(&RegionQuery::slice(&dims, 1)) {
        Ok(_) => panic!("single-generation corruption served an answer"),
        Err(e) => e,
    };
    assert!(matches!(err, PdfflowError::Format(_)), "want typed Format error, got {err}");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn write_faults_abort_typed_and_corrupt_writes_are_flagged() {
    let _g = gate();
    let root = root_dir("write");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let backend = backend();
    let _disarm = Disarm;

    // An injected finish() failure aborts the run with a transient
    // typed error and leaves the store openable; a clean rerun lands.
    let store_a = root.join("store-a");
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store_a),
    );
    fault::install("seed=1,segment.finish=io:1:1").unwrap();
    let err = match pipe.run_slice(Method::Baseline, 1, TypeSet::Four) {
        Ok(_) => panic!("injected finish fault did not abort the run"),
        Err(e) => e,
    };
    assert!(err.is_transient(), "finish fault should surface as transient: {err}");
    fault::clear();
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    let engine = QueryEngine::open(&store_a, QueryOptions::default()).unwrap();
    engine.store().verify().unwrap();
    drop(engine);

    // Corruption injected *while writing* hashes the original bytes,
    // so the damage stays detectable: the run completes, verify flags
    // the segment, and the query path refuses to serve from it.
    let store_b = root.join("store-b");
    let mut pipe_b = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store_b),
    );
    fault::install("seed=1,segment.write=corrupt:1:1").unwrap();
    pipe_b.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    fault::clear();
    let engine = QueryEngine::open(&store_b, QueryOptions::default()).unwrap();
    let report = engine.store().verify_report();
    assert_eq!(report.n_bad(), 1, "corrupt write must fail verification");
    let dims = engine.dims();
    let err = match engine.region(&RegionQuery::slice(&dims, 1)) {
        Ok(_) => panic!("corrupt-on-write segment served an answer"),
        Err(e) => e,
    };
    assert!(matches!(err, PdfflowError::Format(_)));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn transient_catalog_and_loader_faults_recover_bit_identically() {
    let _g = gate();
    let root = root_dir("transient");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let backend = backend();
    let store = root.join("store");
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store),
    );
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    drop(pipe);
    let pristine_bytes = std::fs::read(store.join("slice1_baseline_4_t_g0.seg")).unwrap();

    let _disarm = Disarm;

    // Transient NFS blips during loading retry through to a run whose
    // output is byte-identical to the unfaulted one.
    let store2 = root.join("store2");
    fault::install("seed=2,loader.read=io:1:2,retry=4:0").unwrap();
    let attempts0 = counter(fault::RETRY_ATTEMPTS);
    let mut pipe2 = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store2),
    );
    pipe2.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    assert!(counter(fault::RETRY_ATTEMPTS) - attempts0 >= 2);
    assert_eq!(
        std::fs::read(store2.join("slice1_baseline_4_t_g0.seg")).unwrap(),
        pristine_bytes,
        "loader retries changed the persisted output"
    );
    fault::clear();

    // Transient catalog-read faults retry through a cold store open.
    fault::install("seed=2,catalog.load=io:1:2,retry=4:0").unwrap();
    let attempts1 = counter(fault::RETRY_ATTEMPTS);
    let opened = PdfStore::open(&store).unwrap();
    opened.verify().unwrap();
    assert!(counter(fault::RETRY_ATTEMPTS) - attempts1 >= 2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn scrub_finds_then_repairs_every_quarantined_segment() {
    let _g = gate();
    let root = root_dir("scrub");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);
    let g1 = store.join(NEWEST_GEN);
    let len = std::fs::metadata(&g1).unwrap().len() as usize;
    flip_byte(&g1, len / 3);

    // Read-only scrub: reports the damage, changes nothing on disk.
    let report = scrub_store(&store, false).unwrap();
    assert_eq!(report.total_bad(), 1);
    assert!(report.needs_attention());
    assert!(!report.runs[0].repaired);
    assert!(store.join(NEWEST_GEN).exists());

    // Repair: the surviving generation is rewritten as a fresh dense
    // generation and the damaged files are retired.
    let repaired = scrub_store(&store, true).unwrap();
    assert_eq!(repaired.total_bad(), 1);
    assert!(!repaired.needs_attention(), "repair left damage behind");
    assert!(repaired.runs[0].repaired);
    assert_eq!(repaired.runs[0].repaired_gen, Some(2));
    assert_eq!(repaired.runs[0].retired_files, 2);

    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    engine.store().verify().unwrap();
    assert!(!engine.store().is_degraded());
    assert_eq!(engine.store().n_segments(), 1);
    assert_eq!(
        try_fingerprint(&engine, 1).unwrap(),
        pristine,
        "scrub repair changed query answers"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn serve_front_flags_degraded_answers_per_request() {
    let _g = gate();
    let root = root_dir("serve");
    let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), root.join("data")).unwrap();
    let backend = backend();
    let store = root.join("store");
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        pipeline_cfg(&store),
    );
    // Slice 1 gets two generations (fallback target), slice 2 one.
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    pipe.run_slice(Method::Baseline, 1, TypeSet::Four).unwrap();
    pipe.run_slice(Method::Baseline, 2, TypeSet::Four).unwrap();
    drop(pipe);

    let n = ds.spec.dims.slice_points() as u64;
    let id_z1 = PointId(n);
    let id_z2 = PointId(2 * n);
    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let direct_z1 = engine.point_by_id(id_z1).unwrap();
    drop(engine);

    let g1 = store.join(NEWEST_GEN);
    let len = std::fs::metadata(&g1).unwrap().len() as usize;
    flip_byte(&g1, len / 3);

    let engine = QueryEngine::open(&store, QueryOptions::default()).unwrap();
    let front = ServeFront::new(
        engine,
        ServeOptions {
            max_in_flight: 4,
            queue_depth: 4,
        },
    );
    // Healthy slice before any damage is discovered: not degraded, and
    // the reply lands in the result cache.
    let served = front.submit(Request::Point(id_z2)).unwrap();
    assert!(!served.degraded);
    let stats = front.result_cache().unwrap().stats();
    assert_eq!((stats.entries, stats.invalidations), (1, 0));
    // The damaged slice quarantines mid-query and answers from the
    // prior generation — same bits, flagged, and never cached.
    let served = front.submit(Request::Point(id_z1)).unwrap();
    assert!(served.degraded, "fallback answer must be flagged degraded");
    match &served.reply {
        Reply::Point(rec) => assert_eq!(*rec, direct_z1),
        other => panic!("unexpected reply {other:?}"),
    }
    assert_eq!(
        front.result_cache().unwrap().stats().entries,
        1,
        "degraded reply must not enter the result cache"
    );
    // The quarantine bumped the resolve epoch, so the next lookup sees
    // a moved generation stamp and flushes the pre-quarantine entry
    // instead of serving it. The healthy slice stays unflagged even
    // with the store degraded.
    let served = front.submit(Request::Point(id_z2)).unwrap();
    assert!(!served.degraded, "degradation must not bleed into healthy slices");
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.invalidations, 1, "quarantine must flush the result cache");
    assert_eq!(stats.hits, 0, "a pre-quarantine entry must never be served");
    // Repeats of the degraded request recompute every time — bit-equal,
    // still flagged, still uncached.
    let again = front.submit(Request::Point(id_z1)).unwrap();
    assert!(again.degraded);
    match &again.reply {
        Reply::Point(rec) => assert_eq!(*rec, direct_z1),
        other => panic!("unexpected reply {other:?}"),
    }
    let stats = front.result_cache().unwrap().stats();
    assert_eq!(stats.hits, 0, "degraded replies must never be served from cache");
    assert_eq!(front.metrics().class(Class::Point).degraded, 2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn mmap_and_cached_read_paths_answer_bit_identically() {
    let _g = gate();
    let root = root_dir("readpath");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);

    // The full query surface must fingerprint identically on both
    // physical read paths at every fan-out width.
    for &workers in &[1usize, 2, 8] {
        for &read_path in &[ReadPath::Cached, ReadPath::Mmap] {
            let engine = QueryEngine::open(
                &store,
                QueryOptions {
                    workers,
                    read_path,
                    ..QueryOptions::default()
                },
            )
            .unwrap();
            let fp = try_fingerprint(&engine, 1).unwrap();
            assert_eq!(
                fp, pristine,
                "read path {read_path:?} at {workers} workers changed query answers"
            );
            assert!(!engine.store().is_degraded());
        }
    }

    // When the mmap machinery is compiled in, the mmap engine must
    // actually serve zero-copy reads (not silently fall back).
    if cfg!(all(unix, feature = "mmap")) {
        let mmap0 = counter("store.read_path.mmap");
        let engine = QueryEngine::open(
            &store,
            QueryOptions {
                read_path: ReadPath::Mmap,
                ..QueryOptions::default()
            },
        )
        .unwrap();
        let _ = try_fingerprint(&engine, 1).unwrap();
        assert!(
            counter("store.read_path.mmap") > mmap0,
            "ReadPath::Mmap served no reads through the mapping"
        );
    }

    // Tamper with the newest generation: the mmap path must catch the
    // damage via the per-window checksum on first touch, quarantine,
    // and fall back to the prior generation — bit-identical answers,
    // flagged degraded, exactly like the block-cache path.
    let damaged = root.join("damaged");
    copy_store(&store, &damaged);
    let g1 = damaged.join(NEWEST_GEN);
    let len = std::fs::metadata(&g1).unwrap().len() as usize;
    flip_byte(&g1, len / 3);
    for &read_path in &[ReadPath::Mmap, ReadPath::Cached] {
        let engine = QueryEngine::open(
            &damaged,
            QueryOptions {
                read_path,
                ..QueryOptions::default()
            },
        )
        .unwrap();
        let fp = try_fingerprint(&engine, 1)
            .unwrap_or_else(|e| panic!("{read_path:?}: fallback must cover the slice: {e}"));
        assert_eq!(fp, pristine, "{read_path:?}: generation fallback changed answers");
        assert!(engine.store().is_degraded(), "{read_path:?}: fallback unflagged");
        assert_eq!(engine.store().n_quarantined(), 1);
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn randomized_fault_rounds_never_silently_corrupt() {
    let _g = gate();
    let seed = torture_seed();
    let root = root_dir("rand");
    let (_ds, store) = build_two_gen(&root);
    let pristine = pristine_fingerprint(&store);

    let _disarm = Disarm;
    let mut rng = Rng::new(seed ^ 0x7042_7042_7042_7042);
    for round in 0..4 {
        // Derive an arbitrary fault cocktail from the torture seed: any
        // combination is legal, the invariant is universal.
        let sites = ["segment.read=io", "segment.read=corrupt", "catalog.load=io"];
        let site = sites[rng.below(3)];
        let prob = [0.4, 0.8, 1.0][rng.below(3)];
        let max = 1 + rng.below(3);
        let spec = format!("seed={},{site}:{prob}:{max},retry=2:0", rng.next_u64() & 0xffff);
        fault::install(&spec).unwrap();
        match QueryEngine::open(&store, QueryOptions::default()) {
            Err(e) => {
                assert!(!e.to_string().is_empty(), "round {round}: untyped open ({spec})");
            }
            Ok(engine) => match try_fingerprint(&engine, 1) {
                Ok(fp) => {
                    assert_eq!(fp, pristine, "round {round}: silent corruption ({spec})");
                }
                Err(e) => {
                    assert!(!e.to_string().is_empty(), "round {round}: untyped error ({spec})");
                }
            },
        }
        fault::clear();
    }
    std::fs::remove_dir_all(&root).unwrap();
}
