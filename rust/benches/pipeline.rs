//! Window-pipeline scaling harness (criterion substitute; harness =
//! false): windows/second of a whole-slice run at 1/2/4/8 executor
//! threads, on one backend worker so the speedup isolates the *driver*
//! scheduling layer (the executor refactor's contribution) from the
//! backend's inner batch parallelism.
//!
//! ```text
//! cargo bench --bench pipeline             # table on stdout
//! cargo bench --bench pipeline -- --json   # also write BENCH_pipeline.json
//! ```
//!
//! The JSON report (also triggered by PDFFLOW_BENCH_JSON=1) lands at
//! the **repo root** in the shared cross-bench schema
//! `{bench, config, rows: [{threads, throughput}]}` — the
//! machine-readable perf trajectory CI and EXPERIMENTS.md track — plus
//! the invariance fingerprint (avg_error bits, fits) proving the runs
//! were identical. `PDFFLOW_BENCH_SMOKE=1` shrinks the dataset to a CI
//! smoke profile (recorded in `config.profile`).

use std::time::Instant;

use pdfflow::bench::{write_bench_json, BenchRow};
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, SliceReport, TypeSet};
use pdfflow::cube::CubeDims;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;

const SLICE: usize = 2;
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn run_once(ds: &SyntheticDataset, threads: usize) -> (SliceReport, f64) {
    // One backend worker: the only parallelism in play is window-level.
    let backend = make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions {
            batch: 64,
            workers: 1,
            ..BackendOptions::default()
        },
    )
    .expect("backend");
    let cfg = PipelineConfig {
        batch: 64,
        window_lines: 4,
        executor_threads: threads,
        // Cold loads every run: cache off so each window pays real I/O.
        cache_bytes: 0,
        ..PipelineConfig::default()
    };
    let mut pipe = Pipeline::new(
        ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    let t0 = Instant::now();
    let report = pipe
        .run_slice(Method::Baseline, SLICE, TypeSet::Four)
        .expect("slice run");
    let secs = t0.elapsed().as_secs_f64();
    (report, secs)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let want_json = argv.iter().any(|a| a == "--json")
        || std::env::var("PDFFLOW_BENCH_JSON").is_ok();
    let smoke = std::env::var("PDFFLOW_BENCH_SMOKE").is_ok();

    let root = std::env::temp_dir().join(format!("pdfflow-pipebench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    // Enough windows and observations to keep every thread fed; the
    // smoke profile trades fidelity for CI wall-clock.
    let mut spec = DatasetSpec::tiny();
    spec.dims = if smoke {
        CubeDims::new(48, 16, 4)
    } else {
        CubeDims::new(96, 64, 4)
    };
    spec.n_sims = if smoke { 120 } else { 400 };
    spec.seed = 20180601;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let n_windows = spec.dims.ny.div_ceil(4);
    println!(
        "== pipeline scaling bench: {} windows of {} points, {} observations ==",
        n_windows,
        4 * spec.dims.nx,
        spec.n_sims
    );
    println!(
        "{:<10} {:>10} {:>12} {:>10}",
        "threads", "secs", "windows/s", "speedup"
    );

    // Warm-up run (page cache, allocator, host pool) outside measurement.
    let _ = run_once(&ds, 1);

    let mut rows = Vec::new();
    let mut base_wps = 0.0;
    let mut fingerprint: Option<(u64, usize)> = None;
    for threads in THREADS {
        let (report, secs) = run_once(&ds, threads);
        let wps = n_windows as f64 / secs;
        if threads == 1 {
            base_wps = wps;
        }
        let speedup = wps / base_wps.max(1e-12);
        println!("{threads:<10} {secs:>10.3} {wps:>12.1} {speedup:>9.2}x");
        // Scaling must never change results: same error bits, same fits.
        let fp = (report.avg_error.to_bits(), report.fits);
        match fingerprint {
            None => fingerprint = Some(fp),
            Some(base) => assert_eq!(fp, base, "results diverged at {threads} threads"),
        }
        rows.push((threads, secs, wps, speedup));
    }
    println!("(reports identical across all thread counts)");

    // Kernel micro-bench: fused run_fit_all over an in-memory batch (no
    // I/O, no window machinery), so kernel-only changes are visible
    // separately from end-to-end windows/s. Full shared-pool width —
    // this row measures the kernel + backend fan-out, not the driver.
    let kern_points = if smoke { 2048usize } else { 8192 };
    let kern_obs = spec.n_sims;
    let kern_types = 10usize;
    let kernel_fps = {
        let mut rng = Rng::new(20180602);
        let values: Vec<f32> = (0..kern_points * kern_obs)
            .map(|_| rng.gamma(3.0, 2.0) as f32)
            .collect();
        let backend = make_backend(BackendKind::Native, "artifacts", &BackendOptions::default())
            .expect("backend");
        backend
            .run_fit_all(&values, kern_points, kern_obs, kern_types)
            .expect("warm-up");
        let reps = if smoke { 3usize } else { 5 };
        let t0 = Instant::now();
        for _ in 0..reps {
            backend
                .run_fit_all(&values, kern_points, kern_obs, kern_types)
                .expect("fit");
        }
        (reps * kern_points) as f64 / t0.elapsed().as_secs_f64()
    };
    println!(
        "kernel: {kernel_fps:.0} fit points/s ({kern_points} points x {kern_obs} obs, \
         {kern_types} types)"
    );

    if want_json {
        let mut bench_rows: Vec<BenchRow> = rows
            .iter()
            .map(|(threads, secs, wps, speedup)| BenchRow {
                threads: *threads,
                throughput: *wps,
                extra: vec![
                    ("secs", Json::Num(*secs)),
                    ("speedup_vs_1", Json::Num(*speedup)),
                ],
            })
            .collect();
        bench_rows.push(BenchRow {
            threads: pdfflow::runtime::hostpool::default_budget(),
            throughput: kernel_fps,
            extra: vec![
                ("mode", Json::Str("kernel".into())),
                ("unit", Json::Str("fit_points_per_s".into())),
                ("points", Json::Num(kern_points as f64)),
                ("obs", Json::Num(kern_obs as f64)),
                ("types", Json::Num(kern_types as f64)),
            ],
        });
        let (err_bits, fits) = fingerprint.expect("at least one run");
        let path = write_bench_json(
            "pipeline",
            vec![
                (
                    "note",
                    Json::Str(format!(
                        "recorded by `cargo bench --bench pipeline -- --json`{}; the tier-1 \
                         smoke test (tests/bench_smoke.rs) rewrites this file with a \
                         tier1-smoke profile on every `cargo test` run",
                        if smoke { " (PDFFLOW_BENCH_SMOKE=1)" } else { "" }
                    )),
                ),
                ("profile", Json::Str(String::from(if smoke { "smoke" } else { "full" }))),
                ("unit", Json::Str("windows_per_s".into())),
                ("windows", Json::Num(n_windows as f64)),
                ("observations", Json::Num(spec.n_sims as f64)),
                ("backend_workers", Json::Num(1.0)),
                ("window_lines", Json::Num(4.0)),
            ],
            bench_rows,
            vec![(
                "fingerprint",
                Json::obj(vec![
                    ("avg_error_bits", Json::Str(format!("{err_bits:016x}"))),
                    ("fits", Json::Num(fits as f64)),
                ]),
            )],
        )
        .expect("write BENCH_pipeline.json");
        println!("wrote {}", path.display());
    }

    let _ = std::fs::remove_dir_all(&root);
}
