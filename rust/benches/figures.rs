//! Paper-figure bench harness (criterion substitute; harness = false).
//!
//! ```text
//! cargo bench --bench figures                        # all figures, quick scale
//! cargo bench --bench figures -- fig08               # one figure
//! cargo bench --bench figures -- all --full          # full-scale datasets
//! cargo bench --bench figures -- fig08 --backend xla # PJRT (xla builds)
//! ```

use pdfflow::bench::BenchEnv;
use pdfflow::runtime::BackendKind;
use pdfflow::util::cli::Args;

fn main() {
    // cargo passes a `--bench` flag through; ignore it.
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench")
        .collect();
    let args = Args::parse(argv, &["full"]).unwrap_or_default();
    let full = args.flag("full") || std::env::var("PDFFLOW_BENCH_FULL").is_ok();
    let id = args
        .subcommand
        .clone()
        .unwrap_or_else(|| "all".to_string());
    let kind = BackendKind::resolve(args.opt("backend")).expect("--backend / PDFFLOW_BACKEND");
    let env = BenchEnv::new(
        kind,
        &args.opt_or("artifacts", "artifacts"),
        &args.opt_or("data-dir", "data"),
        !full,
    )
    .expect("backend construction (xla needs `make artifacts`)");
    if let Err(e) = env.run(&id) {
        eprintln!("figure bench failed: {e}");
        std::process::exit(1);
    }
}
