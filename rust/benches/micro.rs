//! Substrate micro-benchmarks (criterion substitute; harness = false).
//!
//! Measures the L3 hot-path building blocks in isolation: PRNG draw
//! throughput, per-point statistics, Eq.5 fitting oracle, grouping hash,
//! decision-tree prediction, JSON parsing, RDD aggregation, and backend
//! execute latency per batch shape (native always; PJRT with the `xla`
//! feature + artifacts). Prints mean/p50/p95 per op.

use std::time::Instant;

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::coordinator::methods::quantize;
use pdfflow::mltree::{DecisionTree, Sample, TreeParams};
use pdfflow::rdd::Rdd;
use pdfflow::runtime::{Backend, NativeBackend};
use pdfflow::stats::{self, DistType, PointStats, DEFAULT_BINS};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;
use pdfflow::util::timing::Summary;

/// Run `f` repeatedly for ~`budget_s` seconds after warmup; report per-op stats.
fn bench<F: FnMut()>(name: &str, ops_per_iter: usize, budget_s: f64, mut f: F) {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_s || samples.len() < 10 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() / ops_per_iter as f64);
        if samples.len() >= 2000 {
            break;
        }
    }
    let s = Summary::of(&samples);
    println!(
        "{name:<34} {:>10.0} ns/op  p50 {:>10.0}  p95 {:>10.0}  (n={})",
        s.mean * 1e9,
        s.p50 * 1e9,
        s.p95 * 1e9,
        s.n
    );
}

fn main() {
    println!("== micro benches (ns per operation) ==");
    let mut rng = Rng::new(42);

    bench("prng::normal", 1000, 0.3, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += rng.normal(0.0, 1.0);
        }
        std::hint::black_box(acc);
    });

    let obs: Vec<f32> = (0..1000).map(|_| rng.gamma(3.0, 2.0) as f32).collect();
    bench("stats::PointStats::of (1000 obs)", 1, 0.3, || {
        std::hint::black_box(PointStats::of(&obs));
    });

    bench("stats::fit_best 10 types (1000 obs)", 1, 0.5, || {
        std::hint::black_box(stats::fit_best(&obs, &DistType::ALL, DEFAULT_BINS));
    });

    bench("methods::quantize", 1000, 0.2, || {
        let mut acc = 0i64;
        for i in 0..1000 {
            acc ^= quantize(1234.5678 + i as f64, 1e-6);
        }
        std::hint::black_box(acc);
    });

    // Decision tree prediction.
    let samples: Vec<Sample> = (0..2000)
        .map(|i| Sample {
            features: vec![(i % 7) as f64 + rng.std_normal() * 0.1, rng.std_normal()],
            label: i % 7,
        })
        .collect();
    let tree = DecisionTree::train(&samples, TreeParams::default()).unwrap();
    bench("mltree::predict", 1000, 0.2, || {
        let mut acc = 0usize;
        for s in samples.iter().take(1000) {
            acc ^= tree.predict(&s.features);
        }
        std::hint::black_box(acc);
    });

    // JSON parse of a manifest-sized document.
    let manifest = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_default();
    if !manifest.is_empty() {
        bench("json::parse manifest", 1, 0.3, || {
            std::hint::black_box(Json::parse(&manifest).unwrap());
        });
    }

    // RDD aggregate-by-key over 10k items (driver executor, 4 tasks wide).
    let exec = pdfflow::executor::Executor::new(4);
    bench("rdd::aggregate_by_key 10k items", 1, 0.5, || {
        let items: Vec<(u32, u32)> = (0..10_000u32).map(|i| (i % 700, i)).collect();
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let (g, _) = Rdd::from_vec(items, 16).aggregate_by_key(
            16,
            &exec,
            &cluster,
            "s",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_, c| c.len() as u64 * 4,
        );
        std::hint::black_box(g.count(&exec));
    });

    // Backend execute latency per batch shape (the L3<->L2 boundary).
    // Native always runs; the PJRT engine joins when built with the xla
    // feature and artifacts exist — the per-shape rows are the
    // apples-to-apples native-vs-XLA comparison.
    let shapes = [
        ("stats 64x100", 64usize, 100usize, "stats"),
        ("fit_all4 64x100", 64, 100, "fit_all4"),
        ("fit_all10 64x100", 64, 100, "fit_all10"),
        ("fit_single_normal 64x100", 64, 100, "fit_single"),
        ("stats 256x1000", 256, 1000, "stats"),
        ("fit_all10 256x1000", 256, 1000, "fit_all10"),
    ];
    #[cfg_attr(not(feature = "xla"), allow(unused_mut))]
    let mut backends: Vec<(&str, Box<dyn Backend>)> =
        vec![("native", Box::new(NativeBackend::new()))];
    #[cfg(feature = "xla")]
    if let Ok(engine) = pdfflow::runtime::Engine::load_default("artifacts") {
        backends.push(("pjrt", Box::new(engine)));
    }
    // Shapes outer, backends inner: every backend measures the SAME
    // draws for a given shape, keeping the comparison apples-to-apples.
    for (name, b, n, kind) in shapes {
        let values: Vec<f32> = (0..b * n).map(|_| rng.gamma(3.0, 2.0) as f32).collect();
        for (label, backend) in &backends {
            let run = |backend: &dyn Backend| match kind {
                "stats" => backend.run_stats(&values, b, n).unwrap(),
                "fit_all4" => backend.run_fit_all(&values, b, n, 4).unwrap(),
                "fit_all10" => backend.run_fit_all(&values, b, n, 10).unwrap(),
                _ => backend
                    .run_fit_single(&values, b, n, DistType::Normal)
                    .unwrap(),
            };
            run(backend.as_ref()); // warm-up / compile outside measurement
            bench(&format!("{label}::{name} (per point)"), b, 0.5, || {
                std::hint::black_box(run(backend.as_ref()).n_rows);
            });
        }
    }
}
