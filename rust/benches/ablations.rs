//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * `quantum`  — grouping-key quantization sweep (§5.2: exact-equality
//!   grouping vs clustering similar points "with an acceptable error");
//! * `bins`     — Eq.5 interval count L (the paper says L is configurable
//!   but never reports its effect);
//! * `forest`   — random forest vs the paper's single decision tree for
//!   the ML method's type prediction;
//! * `batch`    — backend batching policy (points per execute call) on
//!   the runtime hot path.
//!
//! Runs on the backend selected by `PDFFLOW_BACKEND` (default native).

use std::time::Instant;

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::CubeDims;
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::mltree::forest::{ForestParams, RandomForest};
use pdfflow::mltree::{DecisionTree, Sample, TreeParams};
use pdfflow::runtime::{make_backend, BackendKind, BackendOptions};
use pdfflow::stats::{self, DistType};
use pdfflow::util::prng::Rng;

fn dataset() -> SyntheticDataset {
    let mut spec = DatasetSpec::set1_analog();
    spec.dims = CubeDims::new(256, 64, 64);
    spec.n_sims = 100;
    SyntheticDataset::generate(&spec, "data/set1-quick").expect("dataset")
}

fn main() {
    let kind = BackendKind::resolve(None).expect("PDFFLOW_BACKEND");
    let backend = make_backend(kind, "artifacts", &BackendOptions::default())
        .expect("backend construction");
    println!("backend: {}", backend.name());
    let ds = dataset();
    let slice = ds.spec.dims.nz * 201 / 501;

    // ---- quantum: grouping-key granularity ---------------------------
    println!("== ablation: grouping quantum (Grouping, 4-types, slice) ==");
    println!("{:<12} {:>8} {:>12} {:>10}", "quantum", "groups", "fit(sim)", "E");
    for quantum in [1e-9, 1e-6, 1e-3, 1.0, 10.0] {
        let mut cfg = PipelineConfig {
            batch: 64,
            window_lines: 25,
            group_quantum: quantum,
            ..PipelineConfig::default()
        };
        cfg.cache_bytes = 512 << 20;
        let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), cfg);
        let r = pipe.run_slice(Method::Grouping, slice, TypeSet::Four).unwrap();
        println!(
            "{:<12} {:>8} {:>11.2}s {:>10.4}",
            quantum, r.groups, r.fit_sim_s, r.avg_error
        );
    }
    println!("(coarser keys -> fewer fits but clustered points may share a wrong PDF)");

    // ---- bins: Eq.5 interval count (rust oracle) ---------------------
    println!("\n== ablation: Eq.5 interval count L (oracle, 2000-obs normal) ==");
    let mut rng = Rng::new(7);
    let v: Vec<f32> = (0..2000).map(|_| rng.normal(10.0, 2.0) as f32).collect();
    println!("{:<8} {:>12} {:>14}", "L", "E(normal)", "E(uniform)");
    for bins in [4, 8, 16, 32, 64, 128] {
        let en = stats::fit_single(&v, DistType::Normal, bins).error;
        let eu = stats::fit_single(&v, DistType::Uniform, bins).error;
        println!("{:<8} {:>12.4} {:>14.4}", bins, en, eu);
    }
    println!("(more intervals -> finer discrepancy but noisier Freq_k; 32 is the default)");

    // ---- forest vs tree ----------------------------------------------
    println!("\n== ablation: random forest vs single tree (type prediction) ==");
    let mut rng = Rng::new(11);
    let train: Vec<Sample> = (0..4000)
        .map(|i| {
            let label = i % 4;
            let (cx, cy) = ((label % 2) as f64 * 3.0, (label / 2) as f64 * 3.0);
            // Overlapping classes: the regime where ensembles help.
            Sample {
                features: vec![cx + rng.std_normal() * 1.2, cy + rng.std_normal() * 1.2],
                label,
            }
        })
        .collect();
    let test: Vec<Sample> = (0..2000)
        .map(|i| {
            let label = i % 4;
            let (cx, cy) = ((label % 2) as f64 * 3.0, (label / 2) as f64 * 3.0);
            Sample {
                features: vec![cx + rng.std_normal() * 1.2, cy + rng.std_normal() * 1.2],
                label,
            }
        })
        .collect();
    let t0 = Instant::now();
    let tree = DecisionTree::train(&train, TreeParams::default()).unwrap();
    let tree_train = t0.elapsed().as_secs_f64();
    println!(
        "single tree : err {:.4}  train {:.2}s  broadcast {}B",
        tree.error_rate(&test),
        tree_train,
        tree.broadcast_bytes()
    );
    for n_trees in [5, 10, 20] {
        let t0 = Instant::now();
        let forest = RandomForest::train(
            &train,
            ForestParams {
                n_trees,
                ..ForestParams::default()
            },
            42,
        )
        .unwrap();
        println!(
            "forest x{:<3}: err {:.4}  train {:.2}s  broadcast {}B",
            n_trees,
            forest.error_rate(&test),
            t0.elapsed().as_secs_f64(),
            forest.broadcast_bytes()
        );
    }

    // ---- batch: backend batching policy -------------------------------
    println!("\n== ablation: runtime batching (fit_all4, 1536 points x 100 obs) ==");
    let mut rng = Rng::new(13);
    let n_points = 1536;
    let values: Vec<f32> = (0..n_points * 100)
        .map(|_| rng.gamma(3.0, 2.0) as f32)
        .collect();
    backend.warm_all_for(100).unwrap();
    backend.run_fit_all(&values[..100 * 64], 64, 100, 4).unwrap(); // warm-up
    println!("{:<22} {:>12} {:>14}", "points per call", "total", "per point");
    for chunk in [64, 256, 512, 1536] {
        let t0 = Instant::now();
        let mut at = 0;
        while at < n_points {
            let take = chunk.min(n_points - at);
            backend
                .run_fit_all(&values[at * 100..(at + take) * 100], take, 100, 4)
                .unwrap();
            at += take;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<22} {:>10.1}ms {:>12.1}us",
            chunk,
            dt * 1e3,
            dt / n_points as f64 * 1e6
        );
    }
    println!("(XLA pads to the fixed artifact batch, native splits into thread chunks;\n larger call chunks amortize dispatch overhead either way)");
}
