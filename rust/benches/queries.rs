//! Query-throughput harness for the pdfstore serving layer (criterion
//! substitute; harness = false).
//!
//! Builds a store by running the pipeline's persist phase over two
//! slices, then measures queries/sec against the `QueryEngine` under
//! 1..N threads, cold cache (cleared before each pass) vs warm cache
//! (second pass over the same keys), plus region-summary and
//! quantile-surface analytics throughput. This is the north-star
//! workload: many concurrent readers asking for served PDFs.
//!
//! Two more paths are exercised on every run (so the CI bench-smoke
//! step covers them on every push): a slice is **rerun and compacted**
//! (`pdfstore::compact`) and the same queries must answer bit-identical
//! against the compacted store; and a **closed-loop serving pass**
//! drives the admission-controlled `ServeFront`, asserting its
//! in-flight / queue-depth caps and recording the serving row.
//!
//! `--json` (or PDFFLOW_BENCH_JSON=1) writes `BENCH_queries.json` at
//! the repo root in the shared cross-bench schema
//! `{bench, config, rows: [{threads, throughput}]}` (throughput =
//! warm-cache queries/s; the cold rate and the `mode: "serve"` row ride
//! along). `PDFFLOW_BENCH_SMOKE=1` shrinks the workload to a CI smoke
//! profile.

use std::time::Instant;

use pdfflow::bench::{write_bench_json, BenchRow};
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::{CubeDims, PointId};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::executor::Executor;
use pdfflow::pdfstore::{compact_run, QueryEngine, QueryOptions, RegionQuery};
use pdfflow::runtime::{hostpool, make_backend, BackendKind, BackendOptions};
use pdfflow::serve::{closed_loop, ServeFront, ServeOptions};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;
use pdfflow::util::timing::fmt_bytes;

const SLICES: [usize; 2] = [2, 3];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let want_json = argv.iter().any(|a| a == "--json")
        || std::env::var("PDFFLOW_BENCH_JSON").is_ok();
    let smoke = std::env::var("PDFFLOW_BENCH_SMOKE").is_ok();

    let root = std::env::temp_dir().join(format!("pdfflow-querybench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store_dir = root.join("store");

    // A mid-size cube: 64 x 48 lines x 6 slices, 100 observations
    // (smoke: 32 x 16 x 6).
    let mut spec = DatasetSpec::tiny();
    spec.dims = if smoke {
        CubeDims::new(32, 16, 6)
    } else {
        CubeDims::new(64, 48, 6)
    };
    spec.seed = 20180599;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let backend = make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions { batch: 64, ..BackendOptions::default() },
    )
    .expect("backend");
    let mut cfg = PipelineConfig { batch: 64, window_lines: 8, ..PipelineConfig::default() };
    cfg.store_dir = Some(store_dir.to_string_lossy().into_owned());
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    let t0 = Instant::now();
    for z in SLICES {
        pipe.run_slice(Method::Baseline, z, TypeSet::Four).expect("persist slice");
    }
    println!(
        "== query benches: store of {} points x {} slices built in {:.2}s ==",
        spec.dims.slice_points(),
        SLICES.len(),
        t0.elapsed().as_secs_f64()
    );

    let engine = QueryEngine::open(
        &store_dir,
        QueryOptions { cache_bytes: 32 << 20, ..QueryOptions::default() },
    )
    .expect("open store");
    println!(
        "store: {} records, {} on disk",
        engine.store().n_records(),
        fmt_bytes(engine.store().total_bytes())
    );

    // Deterministic random point workload across both slices.
    let mut rng = Rng::new(7);
    let slice_pts = spec.dims.slice_points() as u64;
    let n_queries = if smoke { 4_000usize } else { 20_000usize };
    let ids: Vec<PointId> = (0..n_queries)
        .map(|_| {
            let z = SLICES[rng.below(SLICES.len())] as u64;
            PointId(z * slice_pts + rng.below(slice_pts as usize) as u64)
        })
        .collect();

    println!(
        "\n{:<10} {:>14} {:>14}  ({} point queries)",
        "threads", "cold q/s", "warm q/s", n_queries
    );
    let max_threads = hostpool::default_budget().max(4);
    let mut rows: Vec<BenchRow> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        if threads > max_threads {
            break;
        }
        let run = |label_cold: bool| -> f64 {
            if label_cold {
                engine.clear_cache();
            }
            let t = Instant::now();
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<PointId>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            let exec = Executor::new(threads);
            let results = exec.run(chunks, |chunk| {
                let mut acc = 0u64;
                for id in chunk {
                    acc ^= engine.point_by_id(id).expect("point").point.0;
                }
                acc
            });
            std::hint::black_box(results);
            n_queries as f64 / t.elapsed().as_secs_f64()
        };
        let cold = run(true);
        let warm = run(false);
        println!("{threads:<10} {cold:>14.0} {warm:>14.0}");
        rows.push(BenchRow {
            threads,
            throughput: warm,
            extra: vec![("cold_qps", Json::Num(cold))],
        });
    }
    let m = engine.meters();
    println!(
        "cache meters: {} hits / {} misses / {} evictions, {} resident",
        m.hits,
        m.misses,
        m.evictions,
        fmt_bytes(m.bytes)
    );

    // Analytical throughput: region summaries and quantile surfaces over
    // random sub-rectangles of one slice.
    let mut regions = Vec::new();
    for _ in 0..200 {
        let x0 = rng.below(spec.dims.nx / 2);
        let y0 = rng.below(spec.dims.ny / 2);
        regions.push(RegionQuery {
            z: SLICES[rng.below(SLICES.len())],
            x0,
            x1: x0 + spec.dims.nx / 2 - 1,
            y0,
            y1: y0 + spec.dims.ny / 2 - 1,
        });
    }
    let t = Instant::now();
    let mut pts = 0usize;
    for q in &regions {
        pts += engine.region_summary(q).expect("summary").n_points;
    }
    let dt = t.elapsed().as_secs_f64();
    let regions_per_s = regions.len() as f64 / dt;
    println!(
        "\nregion_summary: {:.0} regions/s ({:.2}M points/s scanned)",
        regions_per_s,
        pts as f64 / dt / 1e6
    );
    let t = Instant::now();
    let mut acc = 0.0;
    for q in regions.iter().take(20) {
        acc += engine.region_quantile_mean(q, 0.5).expect("quantile");
    }
    let dt = t.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    println!("region_quantile_mean(P50): {:.1} regions/s", 20.0 / dt);

    // --- Compaction read path (exercised by the CI bench-smoke step on
    // every push): rerun one slice so the run really holds two
    // generations, compact, and require bit-identical answers from the
    // compacted store before measuring it.
    let fingerprint = |e: &QueryEngine| -> u64 {
        let mut acc = 0u64;
        for id in ids.iter().take(2_000) {
            let rec = e.point_by_id(*id).expect("point");
            acc = acc
                .rotate_left(1)
                .wrapping_add(rec.error.to_bits() as u64 ^ ((rec.dist.id() as u64) << 32));
        }
        for q in regions.iter().take(20) {
            let s = e.region_summary(q).expect("summary");
            acc = acc.rotate_left(1).wrapping_add(s.avg_error.to_bits());
        }
        acc
    };
    let before = fingerprint(&engine);
    pipe.run_slice(Method::Baseline, SLICES[0], TypeSet::Four)
        .expect("rerun slice (appends a generation)");
    let rep = compact_run(&store_dir, None).expect("compact");
    assert!(!rep.already_compact, "rerun should have left generations to compact");
    println!(
        "\ncompacted run {} → gen {}: {} → {} segments, {} → {} bytes, {} files retired",
        rep.run.label(),
        rep.gen,
        rep.segments_before,
        rep.segments_after,
        rep.bytes_before,
        rep.bytes_after,
        rep.retired_files
    );
    let compacted = QueryEngine::open(
        &store_dir,
        QueryOptions { cache_bytes: 32 << 20, ..QueryOptions::default() },
    )
    .expect("open compacted store");
    assert_eq!(
        fingerprint(&compacted),
        before,
        "query results diverged across compaction"
    );
    let t = Instant::now();
    let mut acc = 0u64;
    for id in &ids {
        acc ^= compacted.point_by_id(*id).expect("point").point.0;
    }
    std::hint::black_box(acc);
    let compacted_qps = n_queries as f64 / t.elapsed().as_secs_f64();
    println!("compacted store: {compacted_qps:.0} q/s (single-threaded, warmable cache)");

    // --- Serving tier: closed-loop clients through the admission-
    // controlled front door (the north-star shape: bounded concurrency,
    // overflow shed, not queued without bound).
    let clients = 8usize;
    let serve_opts = ServeOptions {
        max_in_flight: 4,
        queue_depth: 8,
    };
    let front = ServeFront::new(
        QueryEngine::open(
            &store_dir,
            QueryOptions { cache_bytes: 32 << 20, ..QueryOptions::default() },
        )
        .expect("open store for serving"),
        serve_opts,
    );
    let load = closed_loop(&front, clients, if smoke { 200 } else { 1_000 }, 11);
    let sm = &load.metrics;
    println!(
        "serve: {} clients closed-loop → {:.0} q/s, {} completed / {} shed, peaks {} in-flight / {} queued",
        clients,
        load.throughput,
        sm.total_completed(),
        sm.total_shed(),
        sm.peak_in_flight,
        sm.peak_queued
    );
    assert!(sm.peak_in_flight <= serve_opts.max_in_flight, "in-flight cap violated");
    assert!(sm.peak_queued <= serve_opts.queue_depth, "queue-depth cap violated");
    rows.push(BenchRow {
        threads: clients,
        throughput: load.throughput,
        extra: vec![
            ("mode", Json::Str("serve".into())),
            ("shed", Json::Num(sm.total_shed() as f64)),
            ("max_in_flight", Json::Num(serve_opts.max_in_flight as f64)),
            ("queue_depth", Json::Num(serve_opts.queue_depth as f64)),
        ],
    });

    if want_json {
        let path = write_bench_json(
            "queries",
            vec![
                ("profile", Json::Str(String::from(if smoke { "smoke" } else { "full" }))),
                ("unit", Json::Str("warm_queries_per_s".into())),
                ("n_queries", Json::Num(n_queries as f64)),
                ("records", Json::Num(engine.store().n_records() as f64)),
                ("cache_mb", Json::Num(32.0)),
            ],
            rows,
            vec![
                ("region_summary_per_s", Json::Num(regions_per_s)),
                ("compacted_qps", Json::Num(compacted_qps)),
            ],
        )
        .expect("write BENCH_queries.json");
        println!("wrote {}", path.display());
    }

    let _ = std::fs::remove_dir_all(&root);
}
