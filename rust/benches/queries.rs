//! Query-throughput harness for the pdfstore serving layer (criterion
//! substitute; harness = false).
//!
//! Builds **one** store through the shared
//! [`pdfflow::bench::QueryStoreFixture`] (the pipeline's persist phase
//! over two slices) and reuses it across every mode: point queries/sec
//! against the `QueryEngine` under 1..N threads, cold cache (cleared
//! before each pass) vs warm cache (second pass over the same keys),
//! region-summary and quantile-surface analytics, and the spatial tier
//! (grid-index-pruned box / radius / kNN sweeps plus one per-cell
//! aggregation). This is the north-star workload: many concurrent
//! readers asking for served PDFs.
//!
//! Two more paths are exercised on every run (so the CI bench-smoke
//! step covers them on every push): a slice is **rerun and compacted**
//! (`pdfstore::compact`) and the same queries must answer bit-identical
//! against the compacted store; and **two closed-loop serving passes**
//! drive the admission-controlled `ServeFront` — once in-process
//! (`mode: "serve_inproc"`) and once through the socket front over real
//! loopback TCP (`mode: "serve"`, the row CI asserts on) — each
//! asserting the in-flight / queue-depth caps.
//!
//! `--json` (or PDFFLOW_BENCH_JSON=1) writes `BENCH_queries.json` at
//! the repo root in the shared cross-bench schema
//! `{bench, config, rows: [{threads, throughput}]}` (throughput =
//! warm-cache queries/s; the cold rate, the `mode: "serve"` row and the
//! `mode: "spatial_*"` rows ride along). `PDFFLOW_BENCH_SMOKE=1`
//! shrinks the workload to a CI smoke profile.

use std::sync::Arc;
use std::time::Instant;

use pdfflow::bench::{write_bench_json, BenchRow, QueryStoreFixture};
use pdfflow::cube::CubeDims;
use pdfflow::executor::Executor;
use pdfflow::pdfstore::{compact_run, QueryEngine, RegionQuery};
use pdfflow::runtime::hostpool;
use pdfflow::serve::net::{closed_loop_net, NetOptions, NetServer};
use pdfflow::serve::{closed_loop, ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};
use pdfflow::util::json::Json;
use pdfflow::util::prng::Rng;
use pdfflow::util::timing::fmt_bytes;

const SLICES: [usize; 2] = [2, 3];
const CACHE_BYTES: u64 = 32 << 20;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let want_json = argv.iter().any(|a| a == "--json")
        || std::env::var("PDFFLOW_BENCH_JSON").is_ok();
    let smoke = std::env::var("PDFFLOW_BENCH_SMOKE").is_ok();

    // A mid-size cube: 64 x 48 lines x 6 slices, 100 observations
    // (smoke: 32 x 16 x 6). One build feeds every mode below.
    let dims = if smoke {
        CubeDims::new(32, 16, 6)
    } else {
        CubeDims::new(64, 48, 6)
    };
    let t0 = Instant::now();
    let fixture =
        QueryStoreFixture::build("querybench", dims, 20180599, 8, &SLICES).expect("store build");
    println!(
        "== query benches: store of {} points x {} slices built in {:.2}s ==",
        dims.slice_points(),
        SLICES.len(),
        t0.elapsed().as_secs_f64()
    );

    let engine = fixture.engine(CACHE_BYTES).expect("open store");
    println!(
        "store: {} records, {} on disk",
        engine.store().n_records(),
        fmt_bytes(engine.store().total_bytes())
    );

    // Deterministic random point workload across both slices.
    let n_queries = if smoke { 4_000usize } else { 20_000usize };
    let ids = fixture.point_ids(n_queries, 7);

    println!(
        "\n{:<10} {:>14} {:>14}  ({} point queries)",
        "threads", "cold q/s", "warm q/s", n_queries
    );
    let max_threads = hostpool::default_budget().max(4);
    let mut rows: Vec<BenchRow> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        if threads > max_threads {
            break;
        }
        let run = |label_cold: bool| -> f64 {
            if label_cold {
                engine.clear_cache();
            }
            let t = Instant::now();
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<_>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            let exec = Executor::new(threads);
            let results = exec.run(chunks, |chunk| {
                let mut acc = 0u64;
                for id in chunk {
                    acc ^= engine.point_by_id(id).expect("point").point.0;
                }
                acc
            });
            std::hint::black_box(results);
            n_queries as f64 / t.elapsed().as_secs_f64()
        };
        let cold = run(true);
        let warm = run(false);
        println!("{threads:<10} {cold:>14.0} {warm:>14.0}");
        rows.push(BenchRow {
            threads,
            throughput: warm,
            extra: vec![("cold_qps", Json::Num(cold))],
        });
    }
    let m = engine.meters();
    println!(
        "cache meters: {} hits / {} misses / {} evictions, {} resident",
        m.hits,
        m.misses,
        m.evictions,
        fmt_bytes(m.bytes)
    );

    // Analytical throughput: region summaries and quantile surfaces over
    // random sub-rectangles of one slice.
    let mut rng = Rng::new(9);
    let mut regions = Vec::new();
    for _ in 0..200 {
        let x0 = rng.below(dims.nx / 2);
        let y0 = rng.below(dims.ny / 2);
        regions.push(RegionQuery {
            z: SLICES[rng.below(SLICES.len())],
            x0,
            x1: x0 + dims.nx / 2 - 1,
            y0,
            y1: y0 + dims.ny / 2 - 1,
        });
    }
    let t = Instant::now();
    let mut pts = 0usize;
    for q in &regions {
        pts += engine.region_summary(q).expect("summary").n_points;
    }
    let dt = t.elapsed().as_secs_f64();
    let regions_per_s = regions.len() as f64 / dt;
    println!(
        "\nregion_summary: {:.0} regions/s ({:.2}M points/s scanned)",
        regions_per_s,
        pts as f64 / dt / 1e6
    );
    let t = Instant::now();
    let mut acc = 0.0;
    for q in regions.iter().take(20) {
        acc += engine.region_quantile_mean(q, 0.5).expect("quantile");
    }
    let dt = t.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    println!("region_quantile_mean(P50): {:.1} regions/s", 20.0 / dt);

    // --- Spatial tier over the same store build: grid-index-pruned 3D
    // box summaries, radius scans and kNN lookups, plus one per-cell
    // aggregation pass. The engine fans window scans out on the host
    // pool internally, so the rows record that width.
    let spatial_threads = hostpool::default_budget().max(1);
    let n_spatial = if smoke { 400usize } else { 2_000usize };
    let mut srng = Rng::new(23);
    let rand_point = |rng: &mut Rng| (rng.below(dims.nx), rng.below(dims.ny), rng.below(dims.nz));
    let boxes: Vec<BoxQuery> = (0..n_spatial)
        .map(|_| {
            let c = rand_point(&mut srng);
            BoxQuery::around(&dims, c, 1 + srng.below(8))
        })
        .collect();
    let t = Instant::now();
    let mut pts = 0usize;
    for q in &boxes {
        pts += engine.box_summary(q).expect("box").n_points;
    }
    let dt = t.elapsed().as_secs_f64();
    let box_per_s = boxes.len() as f64 / dt;
    println!(
        "\nspatial box_summary: {:.0} boxes/s ({:.2}M points/s summarized)",
        box_per_s,
        pts as f64 / dt / 1e6
    );

    let radii: Vec<RadiusQuery> = (0..n_spatial)
        .map(|_| {
            let (x, y, z) = rand_point(&mut srng);
            RadiusQuery {
                x,
                y,
                z,
                radius: 1.0 + srng.below(5) as f64,
            }
        })
        .collect();
    let t = Instant::now();
    let mut hits = 0usize;
    for q in &radii {
        hits += engine.radius_records(q).expect("radius").len();
    }
    let radius_per_s = radii.len() as f64 / t.elapsed().as_secs_f64();
    println!(
        "spatial radius_records: {:.0} queries/s ({:.1} records/query)",
        radius_per_s,
        hits as f64 / radii.len() as f64
    );

    let knns: Vec<KnnQuery> = (0..n_spatial)
        .map(|_| {
            let (x, y, z) = rand_point(&mut srng);
            KnnQuery {
                x,
                y,
                z,
                k: 1 + srng.below(16),
            }
        })
        .collect();
    let t = Instant::now();
    let mut acc = 0u64;
    for q in &knns {
        acc ^= engine.knn(q).expect("knn").last().expect("k >= 1").point.0;
    }
    std::hint::black_box(acc);
    let knn_per_s = knns.len() as f64 / t.elapsed().as_secs_f64();
    println!("spatial knn: {knn_per_s:.0} queries/s");

    let agg_passes = if smoke { 5usize } else { 20usize };
    let t = Instant::now();
    let mut cells = 0usize;
    for _ in 0..agg_passes {
        cells = engine
            .cell_aggregate(&BoxQuery::whole(&dims))
            .expect("aggregate")
            .cells
            .len();
    }
    let agg_per_s = agg_passes as f64 / t.elapsed().as_secs_f64();
    println!(
        "spatial cell_aggregate(whole cube): {agg_per_s:.1} passes/s ({cells} occupied cells)"
    );
    for (mode, throughput, n) in [
        ("spatial_box", box_per_s, n_spatial),
        ("spatial_radius", radius_per_s, n_spatial),
        ("spatial_knn", knn_per_s, n_spatial),
        ("spatial_agg", agg_per_s, agg_passes),
    ] {
        rows.push(BenchRow {
            threads: spatial_threads,
            throughput,
            extra: vec![
                ("mode", Json::Str(mode.into())),
                ("queries", Json::Num(n as f64)),
            ],
        });
    }

    // --- Compaction read path (exercised by the CI bench-smoke step on
    // every push): rerun one slice so the run really holds two
    // generations, compact, and require bit-identical answers from the
    // compacted store before measuring it. The fingerprint folds point,
    // region AND spatial answers, so compaction cannot silently change
    // any tier.
    let fingerprint = |e: &QueryEngine| -> u64 {
        let mut acc = 0u64;
        for id in ids.iter().take(2_000) {
            let rec = e.point_by_id(*id).expect("point");
            acc = acc
                .rotate_left(1)
                .wrapping_add(rec.error.to_bits() as u64 ^ ((rec.dist.id() as u64) << 32));
        }
        for q in regions.iter().take(20) {
            let s = e.region_summary(q).expect("summary");
            acc = acc.rotate_left(1).wrapping_add(s.avg_error.to_bits());
        }
        for q in boxes.iter().take(20) {
            let s = e.box_summary(q).expect("box");
            acc = acc.rotate_left(1).wrapping_add(s.err_sum.to_bits());
        }
        acc
    };
    let before = fingerprint(&engine);
    fixture
        .persist_slice(SLICES[0])
        .expect("rerun slice (appends a generation)");
    let rep = compact_run(fixture.store_dir(), None).expect("compact");
    assert!(!rep.already_compact, "rerun should have left generations to compact");
    println!(
        "\ncompacted run {} → gen {}: {} → {} segments, {} → {} bytes, {} files retired",
        rep.run.label(),
        rep.gen,
        rep.segments_before,
        rep.segments_after,
        rep.bytes_before,
        rep.bytes_after,
        rep.retired_files
    );
    let compacted = fixture.engine(CACHE_BYTES).expect("open compacted store");
    assert_eq!(
        fingerprint(&compacted),
        before,
        "query results diverged across compaction"
    );
    let t = Instant::now();
    let mut acc = 0u64;
    for id in &ids {
        acc ^= compacted.point_by_id(*id).expect("point").point.0;
    }
    std::hint::black_box(acc);
    let compacted_qps = n_queries as f64 / t.elapsed().as_secs_f64();
    println!("compacted store: {compacted_qps:.0} q/s (single-threaded, warmable cache)");

    // --- Serving tier: closed-loop clients through the admission-
    // controlled front door (the north-star shape: bounded concurrency,
    // overflow shed, not queued without bound). The request mix now
    // includes spatial box / radius / kNN classes. Two rows land: the
    // in-process pass (`serve_inproc`, pure front-door cost) and the
    // socket pass (`serve`, the full wire stack: loopback TCP, frame
    // codec, dispatch queue), so transport overhead stays visible.
    let clients = 8usize;
    let requests_per_client = if smoke { 200 } else { 1_000 };
    let serve_opts = ServeOptions {
        max_in_flight: 4,
        queue_depth: 8,
    };
    let front = ServeFront::new(
        fixture.engine(CACHE_BYTES).expect("open store for serving"),
        serve_opts,
    );
    let load = closed_loop(&front, clients, requests_per_client, 11);
    let sm = &load.metrics;
    println!(
        "serve(inproc): {} clients closed-loop → {:.0} q/s, {} completed / {} shed, peaks {} in-flight / {} queued",
        clients,
        load.throughput,
        sm.total_completed(),
        sm.total_shed(),
        sm.peak_in_flight,
        sm.peak_queued
    );
    assert!(sm.peak_in_flight <= serve_opts.max_in_flight, "in-flight cap violated");
    assert!(sm.peak_queued <= serve_opts.queue_depth, "queue-depth cap violated");
    rows.push(BenchRow {
        threads: clients,
        throughput: load.throughput,
        extra: vec![
            ("mode", Json::Str("serve_inproc".into())),
            ("transport", Json::Str("inproc".into())),
            ("shed", Json::Num(sm.total_shed() as f64)),
            ("max_in_flight", Json::Num(serve_opts.max_in_flight as f64)),
            ("queue_depth", Json::Num(serve_opts.queue_depth as f64)),
        ],
    });

    let front = Arc::new(ServeFront::new(
        fixture.engine(CACHE_BYTES).expect("open store for socket serving"),
        serve_opts,
    ));
    let server = NetServer::start(
        Arc::clone(&front),
        "127.0.0.1:0",
        NetOptions {
            workers: serve_opts.max_in_flight,
            queue_depth: serve_opts.queue_depth,
        },
    )
    .expect("socket front");
    let net_load = closed_loop_net(&server.addr().to_string(), clients, requests_per_client, 11)
        .expect("socket closed loop");
    server.join();
    assert_eq!(
        net_load.completed + net_load.shed + net_load.errors,
        net_load.requests,
        "socket closed loop lost requests: {net_load:?}"
    );
    let nm = front.metrics();
    println!(
        "serve(socket): {} clients closed-loop → {:.0} q/s, {} completed / {} shed, peaks {} in-flight / {} queued",
        clients,
        net_load.throughput,
        net_load.completed,
        net_load.shed,
        nm.peak_in_flight,
        nm.peak_queued
    );
    assert!(nm.peak_in_flight <= serve_opts.max_in_flight, "in-flight cap violated");
    rows.push(BenchRow {
        threads: clients,
        throughput: net_load.throughput,
        extra: vec![
            ("mode", Json::Str("serve".into())),
            ("transport", Json::Str("socket".into())),
            ("shed", Json::Num(net_load.shed as f64)),
            ("max_in_flight", Json::Num(serve_opts.max_in_flight as f64)),
            ("queue_depth", Json::Num(serve_opts.queue_depth as f64)),
        ],
    });

    // --- Telemetry overhead: the identical warm point-query pass with
    // span tracing enabled vs disabled. The point hot path carries only
    // always-on relaxed counters (spans sit at stage / segment-I/O
    // granularity), so the delta must stay inside the 3% budget the
    // telemetry layer promises. Interleaved best-of passes with a few
    // retries keep scheduler noise from failing the assertion.
    let overhead_ids = fixture.point_ids(if smoke { 2_000 } else { 10_000 }, 31);
    let pass = |e: &QueryEngine| -> f64 {
        let t = Instant::now();
        let mut acc = 0u64;
        for id in &overhead_ids {
            acc ^= e.point_by_id(*id).expect("point").point.0;
        }
        std::hint::black_box(acc);
        overhead_ids.len() as f64 / t.elapsed().as_secs_f64()
    };
    pass(&compacted); // warm the block cache so both states read memory
    let (mut qps_on, mut qps_off, mut delta_pct) = (0.0f64, 0.0f64, f64::INFINITY);
    for _attempt in 0..5 {
        let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
        for _ in 0..3 {
            pdfflow::telemetry::set_enabled(true);
            best_on = best_on.max(pass(&compacted));
            pdfflow::telemetry::set_enabled(false);
            best_off = best_off.max(pass(&compacted));
        }
        (qps_on, qps_off) = (best_on, best_off);
        delta_pct = (best_off - best_on) / best_off * 100.0;
        if delta_pct <= 3.0 {
            break;
        }
    }
    pdfflow::telemetry::set_enabled(true);
    println!(
        "telemetry overhead: enabled {qps_on:.0} q/s vs disabled {qps_off:.0} q/s ({delta_pct:+.2}%)"
    );
    assert!(
        delta_pct <= 3.0,
        "telemetry overhead {delta_pct:.2}% exceeds the 3% budget"
    );
    rows.push(BenchRow {
        threads: 1,
        throughput: qps_on,
        extra: vec![
            ("mode", Json::Str("telemetry_overhead".into())),
            ("disabled_qps", Json::Num(qps_off)),
            ("delta_pct", Json::Num(delta_pct)),
        ],
    });

    if want_json {
        let path = write_bench_json(
            "queries",
            vec![
                (
                    "note",
                    Json::Str(format!(
                        "recorded by `cargo bench --bench queries -- --json`{}; the tier-1 \
                         smoke test (tests/bench_smoke.rs) rewrites this file with a \
                         tier1-smoke profile on every `cargo test` run",
                        if smoke { " (PDFFLOW_BENCH_SMOKE=1)" } else { "" }
                    )),
                ),
                ("profile", Json::Str(String::from(if smoke { "smoke" } else { "full" }))),
                ("unit", Json::Str("warm_queries_per_s".into())),
                ("n_queries", Json::Num(n_queries as f64)),
                ("records", Json::Num(engine.store().n_records() as f64)),
                ("cache_mb", Json::Num(32.0)),
            ],
            rows,
            vec![
                ("region_summary_per_s", Json::Num(regions_per_s)),
                ("compacted_qps", Json::Num(compacted_qps)),
            ],
        )
        .expect("write BENCH_queries.json");
        println!("wrote {}", path.display());
    }
}
