//! Query-throughput harness for the pdfstore serving layer (criterion
//! substitute; harness = false).
//!
//! Builds a store by running the pipeline's persist phase over two
//! slices, then measures queries/sec against the `QueryEngine` under
//! 1..N threads, cold cache (cleared before each pass) vs warm cache
//! (second pass over the same keys), plus region-summary and
//! quantile-surface analytics throughput. This is the north-star
//! workload: many concurrent readers asking for served PDFs.

use std::time::Instant;

use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::PipelineConfig;
use pdfflow::coordinator::{Method, Pipeline, TypeSet};
use pdfflow::cube::{CubeDims, PointId};
use pdfflow::datagen::{DatasetSpec, SyntheticDataset};
use pdfflow::pdfstore::{QueryEngine, QueryOptions, RegionQuery};
use pdfflow::runtime::{make_backend, BackendKind, BackendOptions};
use pdfflow::util::pool;
use pdfflow::util::prng::Rng;
use pdfflow::util::timing::fmt_bytes;

const SLICES: [usize; 2] = [2, 3];

fn main() {
    let root = std::env::temp_dir().join(format!("pdfflow-querybench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store_dir = root.join("store");

    // A mid-size cube: 64 x 48 lines x 6 slices, 100 observations.
    let mut spec = DatasetSpec::tiny();
    spec.dims = CubeDims::new(64, 48, 6);
    spec.seed = 20180599;
    let ds = SyntheticDataset::generate(&spec, root.join("data")).expect("dataset");
    let backend = make_backend(
        BackendKind::Native,
        "artifacts",
        &BackendOptions { batch: 64, ..BackendOptions::default() },
    )
    .expect("backend");
    let mut cfg = PipelineConfig { batch: 64, window_lines: 8, ..PipelineConfig::default() };
    cfg.store_dir = Some(store_dir.to_string_lossy().into_owned());
    let mut pipe = Pipeline::new(
        &ds,
        backend.as_ref(),
        SimCluster::new(ClusterSpec::lncc()),
        cfg,
    );
    let t0 = Instant::now();
    for z in SLICES {
        pipe.run_slice(Method::Baseline, z, TypeSet::Four).expect("persist slice");
    }
    println!(
        "== query benches: store of {} points x {} slices built in {:.2}s ==",
        spec.dims.slice_points(),
        SLICES.len(),
        t0.elapsed().as_secs_f64()
    );

    let engine = QueryEngine::open(
        &store_dir,
        QueryOptions { cache_bytes: 32 << 20, ..QueryOptions::default() },
    )
    .expect("open store");
    println!(
        "store: {} records, {} on disk",
        engine.store().n_records(),
        fmt_bytes(engine.store().total_bytes())
    );

    // Deterministic random point workload across both slices.
    let mut rng = Rng::new(7);
    let slice_pts = spec.dims.slice_points() as u64;
    let n_queries = 20_000usize;
    let ids: Vec<PointId> = (0..n_queries)
        .map(|_| {
            let z = SLICES[rng.below(SLICES.len())] as u64;
            PointId(z * slice_pts + rng.below(slice_pts as usize) as u64)
        })
        .collect();

    println!(
        "\n{:<10} {:>14} {:>14}  ({} point queries)",
        "threads", "cold q/s", "warm q/s", n_queries
    );
    let max_threads = pool::default_workers().max(4);
    for threads in [1usize, 2, 4, 8] {
        if threads > max_threads {
            break;
        }
        let run = |label_cold: bool| -> f64 {
            if label_cold {
                engine.clear_cache();
            }
            let t = Instant::now();
            let chunk = ids.len().div_ceil(threads);
            let chunks: Vec<Vec<PointId>> = ids.chunks(chunk).map(|c| c.to_vec()).collect();
            let results = pool::parallel_map(chunks, threads, |chunk| {
                let mut acc = 0u64;
                for id in chunk {
                    acc ^= engine.point_by_id(id).expect("point").point.0;
                }
                acc
            });
            std::hint::black_box(results);
            n_queries as f64 / t.elapsed().as_secs_f64()
        };
        let cold = run(true);
        let warm = run(false);
        println!("{threads:<10} {cold:>14.0} {warm:>14.0}");
    }
    let m = engine.meters();
    println!(
        "cache meters: {} hits / {} misses / {} evictions, {} resident",
        m.hits,
        m.misses,
        m.evictions,
        fmt_bytes(m.bytes)
    );

    // Analytical throughput: region summaries and quantile surfaces over
    // random sub-rectangles of one slice.
    let mut regions = Vec::new();
    for _ in 0..200 {
        let x0 = rng.below(spec.dims.nx / 2);
        let y0 = rng.below(spec.dims.ny / 2);
        regions.push(RegionQuery {
            z: SLICES[rng.below(SLICES.len())],
            x0,
            x1: x0 + spec.dims.nx / 2 - 1,
            y0,
            y1: y0 + spec.dims.ny / 2 - 1,
        });
    }
    let t = Instant::now();
    let mut pts = 0usize;
    for q in &regions {
        pts += engine.region_summary(q).expect("summary").n_points;
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "\nregion_summary: {:.0} regions/s ({:.2}M points/s scanned)",
        regions.len() as f64 / dt,
        pts as f64 / dt / 1e6
    );
    let t = Instant::now();
    let mut acc = 0.0;
    for q in regions.iter().take(20) {
        acc += engine.region_quantile_mean(q, 0.5).expect("quantile");
    }
    let dt = t.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    println!("region_quantile_mean(P50): {:.1} regions/s", 20.0 / dt);

    let _ = std::fs::remove_dir_all(&root);
}
