//! Simulated shared-nothing Spark cluster (DESIGN.md §3 substitution 1).
//!
//! The paper runs on two real clusters (LNCC: 6×32 cores; Grid5000:
//! up to 64×16 cores). This image has a single CPU, so cluster-level
//! behaviour is *modeled*: task compute costs are the **real measured**
//! PJRT/loader wall-clock times on this machine, and the simulator
//! computes the stage makespan a cluster of `n` nodes × `c` cores would
//! achieve (LPT scheduling + per-task overhead), plus explicit cost models
//! for the two data paths the paper's evaluation turns on:
//!
//! * **NFS loading** — one shared server: aggregate-bandwidth bound plus
//!   per-positioned-read latency amortized over concurrent streams
//!   (paper Fig. 12: loading scales with nodes until the server saturates);
//! * **shuffle** — pairwise exchange: a volume term that *shrinks* with
//!   aggregate bandwidth and a coordination term that *grows* with node
//!   count (paper Figs. 13–14/18–19: Grouping's aggregation becomes the
//!   bottleneck at high node counts or big observation vectors).
//!
//! Every charge is recorded in a named ledger so reports can show the
//! simulated-time breakdown next to real wall-clock. The ledger is
//! internally synchronized (a mutexed map behind `&self` methods), so
//! one `SimCluster` can be shared by every parallel task of a stage —
//! loader, methods, RDD shuffles and persist sinks all charge the same
//! session without threading `&mut` through the call graph.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Static description of a cluster (paper §6.1 testbeds).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: usize,
    pub cores_per_node: usize,
    /// Per-node NIC bandwidth, bytes/s.
    pub link_bandwidth: f64,
    /// Per-node effective shuffle throughput, bytes/s. Spark shuffles
    /// serialize JVM objects (boxed doubles for observation vectors) —
    /// the effective rate is orders of magnitude below the NIC and is
    /// what makes Grouping collapse on big observation vectors
    /// (paper §6.3.2 / Fig. 19).
    pub shuffle_throughput: f64,
    /// NFS server aggregate read bandwidth, bytes/s.
    pub nfs_bandwidth: f64,
    /// Per positioned-read service latency at the NFS server, seconds.
    pub nfs_latency: f64,
    /// Spark task launch/management overhead, seconds per task.
    pub task_overhead: f64,
    /// Per-node coordination cost of one shuffle round, seconds.
    pub shuffle_latency: f64,
    /// Per-node shuffle spill threshold, bytes: beyond it the effective
    /// throughput degrades linearly (Spark's in-memory aggregation
    /// spilling to disk). This is what turns the window-size curve back
    /// up past the optimum (paper Fig. 8).
    pub shuffle_spill_bytes: f64,
    /// Emulated per-value load cost, seconds per (point, observation)
    /// loaded: the paper's Algorithm-2 loading Map calls an external Java
    /// program doing one positioned NFS read per (point, simulation file)
    /// — that client-side cost dominates loading and is what makes Fig. 12
    /// scale with nodes until the NFS server floor.
    pub load_cost_per_value: f64,
    /// Emulated external-fitter cost, seconds per (point, candidate
    /// type). The paper computes each PDF by launching an R process per
    /// point inside a Spark Map (§4.2 principle 5) — that cost, not the
    /// arithmetic, dominates its figures. Our AOT/PJRT path is orders of
    /// magnitude faster (reported as "real" time); the simulated clock
    /// charges this per-point cost so the paper's compute regime — and
    /// therefore every crossover its figures show — is preserved.
    pub fit_cost_per_point_type: f64,
}

impl ClusterSpec {
    /// LNCC cluster: 6 nodes × 32 cores (paper §6.1).
    pub fn lncc() -> ClusterSpec {
        ClusterSpec {
            name: "lncc".into(),
            nodes: 6,
            cores_per_node: 32,
            link_bandwidth: 125e6,  // 1 GbE
            shuffle_throughput: 8e6,
            nfs_bandwidth: 1.0e9,   // 10 GbE server, ~8 Gb/s effective
            nfs_latency: 200e-6,
            task_overhead: 4e-3,
            shuffle_latency: 10e-3,
            shuffle_spill_bytes: 4e6,
            load_cost_per_value: 50e-6,
            fit_cost_per_point_type: 0.1,
        }
    }

    /// Grid5000 cluster: `nodes` × 16 cores (paper §6.1, 10–64 nodes).
    pub fn g5k(nodes: usize) -> ClusterSpec {
        ClusterSpec {
            name: format!("g5k-{nodes}"),
            nodes,
            cores_per_node: 16,
            link_bandwidth: 1.25e9, // 10 GbE
            shuffle_throughput: 8e6,
            nfs_bandwidth: 2.5e9,
            nfs_latency: 150e-6,
            task_overhead: 4e-3,
            shuffle_latency: 10e-3,
            shuffle_spill_bytes: 4e6,
            load_cost_per_value: 50e-6,
            fit_cost_per_point_type: 0.1,
        }
    }

    /// Single-node "cluster" (used by tests: simulated == measured-ish).
    pub fn local(cores: usize) -> ClusterSpec {
        ClusterSpec {
            name: "local".into(),
            nodes: 1,
            cores_per_node: cores,
            link_bandwidth: f64::INFINITY,
            shuffle_throughput: f64::INFINITY,
            nfs_bandwidth: 4e9,
            nfs_latency: 20e-6,
            task_overhead: 0.0,
            shuffle_latency: 0.0,
            shuffle_spill_bytes: f64::INFINITY,
            load_cost_per_value: 0.0,
            fit_cost_per_point_type: 0.0,
        }
    }

    pub fn total_slots(&self) -> usize {
        self.nodes * self.cores_per_node
    }
}

/// A cluster simulation session: spec + simulated-time ledger. The
/// ledger is an internally synchronized accounts map: every charge
/// method takes `&self`, so a shared `&SimCluster` serves concurrent
/// tasks (accounts are commutative sums).
#[derive(Debug)]
pub struct SimCluster {
    pub spec: ClusterSpec,
    ledger: Mutex<BTreeMap<String, f64>>,
}

impl Clone for SimCluster {
    fn clone(&self) -> SimCluster {
        SimCluster {
            spec: self.spec.clone(),
            ledger: Mutex::new(self.ledger.lock().unwrap().clone()),
        }
    }
}

impl SimCluster {
    pub fn new(spec: ClusterSpec) -> SimCluster {
        SimCluster {
            spec,
            ledger: Mutex::new(BTreeMap::new()),
        }
    }

    fn charge(&self, account: &str, seconds: f64) -> f64 {
        *self
            .ledger
            .lock()
            .unwrap()
            .entry(account.to_string())
            .or_insert(0.0) += seconds;
        seconds
    }

    /// Fold another session's ledger into this one (account-wise sums).
    /// The window pipeline charges each window against a private scratch
    /// cluster and merges the scratches in window order, which keeps the
    /// shared ledger identical at any executor thread count.
    pub fn merge(&self, other: &SimCluster) {
        let other = other.ledger.lock().unwrap().clone();
        let mut g = self.ledger.lock().unwrap();
        for (k, v) in other {
            *g.entry(k).or_insert(0.0) += v;
        }
    }

    /// Simulated makespan of running `task_costs` (seconds each, as
    /// measured on this machine per task) on the cluster: LPT greedy onto
    /// `nodes*cores` slots plus per-task overhead. Returns stage seconds.
    pub fn run_stage(&self, account: &str, task_costs: &[f64]) -> f64 {
        if task_costs.is_empty() {
            return 0.0;
        }
        let slots = self.spec.total_slots();
        let mut heap: Vec<f64> = vec![0.0; slots.min(task_costs.len())];
        let mut sorted: Vec<f64> = task_costs
            .iter()
            .map(|t| t + self.spec.task_overhead)
            .collect();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for t in sorted {
            // Assign to the least-loaded slot (linear scan is fine: slot
            // count is ≤ 1024 and stages run once per window).
            let (i, _) = heap
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            heap[i] += t;
        }
        let makespan = heap.iter().cloned().fold(0.0, f64::max);
        self.charge(account, makespan)
    }

    /// Simulated time to read `bytes` in `reads` positioned reads from the
    /// NFS server with all cluster slots streaming concurrently.
    pub fn charge_nfs(&self, account: &str, bytes: u64, reads: u64) -> f64 {
        let streams = self.spec.total_slots().max(1) as f64;
        let t = bytes as f64 / self.spec.nfs_bandwidth
            + (reads as f64 / streams) * self.spec.nfs_latency;
        self.charge(account, t)
    }

    /// Simulated time to shuffle `bytes` across the cluster (aggregate-
    /// bandwidth volume term + per-node coordination term).
    pub fn charge_shuffle(&self, account: &str, bytes: u64) -> f64 {
        let n = self.spec.nodes as f64;
        if self.spec.nodes <= 1 {
            return self.charge(account, 0.0);
        }
        let crossing = bytes as f64 * (n - 1.0) / n;
        // Effective serdes throughput scales with nodes but is capped by
        // the aggregate NIC bandwidth.
        let agg_bw = (self.spec.shuffle_throughput * n).min(self.spec.link_bandwidth * n);
        // Spill degradation: past the aggregate spill threshold the
        // effective time grows quadratically in volume (memory pressure +
        // disk spill), which is the superlinear term behind Fig. 8.
        let spill = self.spec.shuffle_spill_bytes * n;
        let degrade = 1.0 + crossing / spill;
        let t = crossing * degrade / agg_bw + self.spec.shuffle_latency * n;
        self.charge(account, t)
    }

    /// Simulated time to persist `bytes` of fitted-PDF output in `writes`
    /// append batches back to the shared store (Algorithm 1 line 11). The
    /// paper writes results to the same NFS-side storage the inputs came
    /// from, so the persist path is charged with the same server model as
    /// [`Self::charge_nfs`]: aggregate-bandwidth volume term plus
    /// per-append latency amortized over concurrent writer streams.
    pub fn charge_persist(&self, account: &str, bytes: u64, writes: u64) -> f64 {
        self.charge_nfs(account, bytes, writes)
    }

    /// Simulated time to broadcast `bytes` to every node (tree broadcast).
    pub fn charge_broadcast(&self, account: &str, bytes: u64) -> f64 {
        let rounds = (self.spec.nodes as f64).log2().ceil().max(0.0);
        let t = rounds * (bytes as f64 / self.spec.link_bandwidth + 1e-3);
        self.charge(account, t)
    }

    /// Simulated seconds accumulated on one account.
    pub fn account(&self, account: &str) -> f64 {
        self.ledger.lock().unwrap().get(account).copied().unwrap_or(0.0)
    }

    /// Total simulated seconds across accounts.
    pub fn total(&self) -> f64 {
        self.ledger.lock().unwrap().values().sum()
    }

    /// (account, seconds) pairs, sorted by account name.
    pub fn breakdown(&self) -> Vec<(String, f64)> {
        self.ledger
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    pub fn reset(&self) {
        self.ledger.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lpt_parallelizes_perfectly_divisible_load() {
        let c = SimCluster::new(ClusterSpec::local(4));
        let t = c.run_stage("compute", &[1.0; 8]);
        assert!((t - 2.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn lpt_single_big_task_dominates() {
        let c = SimCluster::new(ClusterSpec::local(4));
        let t = c.run_stage("compute", &[10.0, 0.1, 0.1, 0.1]);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn task_overhead_is_charged() {
        let mut spec = ClusterSpec::local(1);
        spec.task_overhead = 0.5;
        let c = SimCluster::new(spec);
        let t = c.run_stage("compute", &[1.0, 1.0]);
        assert!((t - 3.0).abs() < 1e-9);
    }

    #[test]
    fn more_nodes_speed_up_compute() {
        let costs: Vec<f64> = (0..960).map(|_| 0.1).collect();
        let t10 = SimCluster::new(ClusterSpec::g5k(10)).run_stage("c", &costs);
        let t60 = SimCluster::new(ClusterSpec::g5k(60)).run_stage("c", &costs);
        assert!(t60 < t10, "{t60} !< {t10}");
    }

    #[test]
    fn shuffle_latency_grows_with_nodes() {
        // Small payload: coordination term dominates → more nodes = slower
        // (the paper's Grouping bottleneck).
        let bytes = 1 << 20;
        let t10 = SimCluster::new(ClusterSpec::g5k(10)).charge_shuffle("s", bytes);
        let t60 = SimCluster::new(ClusterSpec::g5k(60)).charge_shuffle("s", bytes);
        assert!(t60 > t10, "{t60} !> {t10}");
    }

    #[test]
    fn shuffle_volume_term_matters_for_big_payloads() {
        // Same node count, 10x the bytes ⇒ strictly more time (Set3 case).
        let c = SimCluster::new(ClusterSpec::g5k(30));
        let t1 = c.charge_shuffle("s1", 1 << 30);
        let t10 = c.charge_shuffle("s2", 10 * (1 << 30) as u64);
        assert!(t10 > t1 * 3.0);
    }

    #[test]
    fn single_node_shuffle_is_free() {
        let c = SimCluster::new(ClusterSpec::local(8));
        assert_eq!(c.charge_shuffle("s", 1 << 30), 0.0);
    }

    #[test]
    fn nfs_time_scales_with_bytes_and_reads() {
        let c = SimCluster::new(ClusterSpec::lncc());
        let t_small = c.charge_nfs("a", 1 << 20, 100);
        let t_big = c.charge_nfs("b", 1 << 30, 100_000);
        assert!(t_big > t_small * 100.0);
    }

    #[test]
    fn persist_time_scales_with_bytes_like_nfs() {
        let c = SimCluster::new(ClusterSpec::lncc());
        let t_small = c.charge_persist("p1", 1 << 20, 10);
        let t_big = c.charge_persist("p2", 1 << 30, 10);
        assert!(t_big > t_small * 100.0, "{t_big} vs {t_small}");
        assert!(c.account("p1") > 0.0 && c.account("p2") > 0.0);
        // Same server model as reads: identical bytes/reads cost the same.
        let c2 = SimCluster::new(ClusterSpec::lncc());
        let read = c2.charge_nfs("r", 1 << 20, 10);
        assert!((read - t_small).abs() < 1e-15);
    }

    #[test]
    fn ledger_accumulates_and_resets() {
        let c = SimCluster::new(ClusterSpec::lncc());
        c.run_stage("compute", &[1.0]);
        c.charge_nfs("load", 1 << 20, 10);
        assert!(c.account("compute") > 0.0);
        assert!(c.account("load") > 0.0);
        assert!((c.total() - c.account("compute") - c.account("load")).abs() < 1e-12);
        assert_eq!(c.breakdown().len(), 2);
        c.reset();
        assert_eq!(c.total(), 0.0);
    }

    #[test]
    fn merge_folds_accounts_additively() {
        let a = SimCluster::new(ClusterSpec::local(2));
        let b = SimCluster::new(ClusterSpec::local(2));
        a.run_stage("compute", &[1.0]);
        b.run_stage("compute", &[2.0]);
        b.charge_nfs("load", 1 << 20, 4);
        a.merge(&b);
        assert!((a.account("compute") - 3.0).abs() < 1e-12);
        assert_eq!(a.account("load"), b.account("load"));
        // b is untouched by the merge.
        assert!((b.account("compute") - 2.0).abs() < 1e-12);
    }

    #[test]
    fn shared_reference_charges_from_many_threads() {
        let c = SimCluster::new(ClusterSpec::local(4));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..100 {
                        c.charge_nfs("load", 1 << 10, 1);
                    }
                });
            }
        });
        // 800 identical charges, each a pure function of (bytes, reads).
        let one = SimCluster::new(ClusterSpec::local(4)).charge_nfs("load", 1 << 10, 1);
        assert!((c.account("load") - 800.0 * one).abs() < 1e-9);
    }

    #[test]
    fn presets_match_paper_testbeds() {
        let lncc = ClusterSpec::lncc();
        assert_eq!((lncc.nodes, lncc.cores_per_node), (6, 32));
        let g5k = ClusterSpec::g5k(64);
        assert_eq!((g5k.nodes, g5k.cores_per_node), (64, 16));
        assert_eq!(g5k.total_slots(), 1024);
    }
}
