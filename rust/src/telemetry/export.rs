//! Exporters: JSON snapshot (`pdfflow.telemetry.v1`) and Prometheus
//! text format, plus the provenance block (git rev, build profile)
//! that makes snapshots joinable with `BENCH_*.json` rows.
//!
//! `pdfflow run|serve --metrics-out PATH` writes the JSON snapshot at
//! `PATH` and the Prometheus rendering at `PATH.prom`;
//! `pdfflow telemetry validate PATH` re-parses a snapshot against
//! [`validate_snapshot`] (the CI step).

use std::path::Path;

use crate::util::json::Json;
use crate::{PdfflowError, Result};

use super::{hist, Metric, Registry};

/// Schema tag stamped into every snapshot.
pub const SCHEMA: &str = "pdfflow.telemetry.v1";

/// Current git revision, read from `.git` with plain file I/O (no
/// subprocess): walks up from the current directory to the repo root,
/// resolves `HEAD` through refs and `packed-refs`. "unknown" when not
/// in a checkout (e.g. an unpacked release tarball).
pub fn git_rev() -> String {
    fn resolve(dir: &Path) -> Option<String> {
        let head = std::fs::read_to_string(dir.join(".git/HEAD")).ok()?;
        let head = head.trim();
        let Some(refname) = head.strip_prefix("ref: ") else {
            return Some(head.to_string()); // detached HEAD: raw hash
        };
        if let Ok(h) = std::fs::read_to_string(dir.join(".git").join(refname)) {
            return Some(h.trim().to_string());
        }
        let packed = std::fs::read_to_string(dir.join(".git/packed-refs")).ok()?;
        packed.lines().find_map(|l| {
            let (hash, name) = l.split_once(' ')?;
            (name.trim() == refname).then(|| hash.to_string())
        })
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| Path::new(".").to_path_buf());
    loop {
        if let Some(rev) = resolve(&dir) {
            return rev;
        }
        if !dir.pop() {
            return "unknown".into();
        }
    }
}

/// Build profile this binary was compiled with.
pub fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Report fingerprint published into snapshot provenance, when the
/// driving command produced one (see
/// [`set_report_fingerprint`]). `u64::MAX` sentinel = unset; the real
/// value is an FNV-64 so any collision with the sentinel is harmless
/// (the field is merely omitted).
static REPORT_FINGERPRINT: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(u64::MAX);

/// Record the run's deterministic report fingerprint
/// (`SliceReport::fingerprint`) so the next snapshot carries it as
/// `provenance.report_fingerprint` (16-hex-digit string). Perf
/// before/after snapshot pairs use this to prove "same results, less
/// time" from the committed artifacts alone.
pub fn set_report_fingerprint(fp: u64) {
    REPORT_FINGERPRINT.store(fp, std::sync::atomic::Ordering::Relaxed);
}

/// Provenance block shared by telemetry snapshots, flight-recorder
/// dumps, and (via [`crate::bench`]) the BENCH JSON configs.
pub fn provenance() -> Json {
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut pairs = vec![
        ("git_rev", Json::Str(git_rev())),
        ("profile", Json::Str(build_profile().into())),
        ("unix_ts", Json::Num(ts as f64)),
    ];
    let fp = REPORT_FINGERPRINT.load(std::sync::atomic::Ordering::Relaxed);
    if fp != u64::MAX {
        pairs.push(("report_fingerprint", Json::Str(format!("{fp:016x}"))));
    }
    Json::obj(pairs)
}

fn histogram_json(h: &super::Histogram) -> Json {
    let buckets: Vec<Json> = h
        .nonzero_buckets()
        .into_iter()
        .map(|(idx, c)| {
            let (lo, hi) = hist::bucket_bounds(idx);
            Json::Arr(vec![
                Json::Num(lo as f64),
                Json::Num(hi as f64),
                Json::Num(c as f64),
            ])
        })
        .collect();
    Json::obj(vec![
        ("type", Json::Str("histogram".into())),
        ("count", Json::Num(h.count() as f64)),
        ("sum", Json::Num(h.sum() as f64)),
        ("min", Json::Num(h.min().unwrap_or(0) as f64)),
        ("max", Json::Num(h.max() as f64)),
        ("mean", Json::Num(h.mean())),
        ("p50", Json::Num(h.quantile(0.50) as f64)),
        ("p95", Json::Num(h.quantile(0.95) as f64)),
        ("p99", Json::Num(h.quantile(0.99) as f64)),
        ("buckets", Json::Arr(buckets)),
    ])
}

/// The `metrics` object: every registered metric, rendered by type.
pub fn metrics_json() -> Json {
    super::publish_process_metrics();
    let mut pairs = Vec::new();
    for (name, metric) in Registry::global().snapshot() {
        let v = match &metric {
            Metric::Counter(c) => Json::obj(vec![
                ("type", Json::Str("counter".into())),
                ("value", Json::Num(c.get() as f64)),
            ]),
            Metric::Gauge(g) => Json::obj(vec![
                ("type", Json::Str("gauge".into())),
                ("value", Json::Num(g.get())),
            ]),
            Metric::Histogram(h) => histogram_json(h),
        };
        pairs.push((name, v));
    }
    Json::Obj(pairs.into_iter().collect())
}

/// Full snapshot document (schema + provenance + metrics).
pub fn snapshot() -> Json {
    Json::obj(vec![
        ("schema", Json::Str(SCHEMA.into())),
        ("provenance", provenance()),
        ("metrics", metrics_json()),
    ])
}

/// Sanitize a dotted metric name into a Prometheus identifier.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 8);
    out.push_str("pdfflow_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    out
}

/// Render the registry in the Prometheus text exposition format.
pub fn prometheus() -> String {
    super::publish_process_metrics();
    let mut out = String::new();
    for (name, metric) in Registry::global().snapshot() {
        let p = prom_name(&name);
        match &metric {
            Metric::Counter(c) => {
                out.push_str(&format!("# TYPE {p} counter\n{p} {}\n", c.get()));
            }
            Metric::Gauge(g) => {
                out.push_str(&format!("# TYPE {p} gauge\n{p} {}\n", g.get()));
            }
            Metric::Histogram(h) => {
                out.push_str(&format!("# TYPE {p} histogram\n"));
                let mut cum = 0u64;
                for (idx, c) in h.nonzero_buckets() {
                    cum += c;
                    let (_, hi) = hist::bucket_bounds(idx);
                    out.push_str(&format!("{p}_bucket{{le=\"{hi}\"}} {cum}\n"));
                }
                out.push_str(&format!("{p}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
                out.push_str(&format!("{p}_sum {}\n", h.sum()));
                out.push_str(&format!("{p}_count {}\n", h.count()));
            }
        }
    }
    out
}

fn need<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| PdfflowError::Format(format!("{what}: missing key {key:?}")))
}

fn need_num(j: &Json, key: &str, what: &str) -> Result<f64> {
    need(j, key, what)?
        .as_f64()
        .ok_or_else(|| PdfflowError::Format(format!("{what}: {key:?} is not a number")))
}

/// Validate a parsed snapshot against the `pdfflow.telemetry.v1`
/// schema: schema tag, provenance (git_rev + profile), and every
/// metric well-formed for its declared type. Returns the metric count.
pub fn validate_snapshot(j: &Json) -> Result<usize> {
    match need(j, "schema", "snapshot")?.as_str() {
        Some(SCHEMA) => {}
        other => {
            return Err(PdfflowError::Format(format!(
                "snapshot: schema {other:?}, expected {SCHEMA:?}"
            )))
        }
    }
    let prov = need(j, "provenance", "snapshot")?;
    for key in ["git_rev", "profile"] {
        if need(prov, key, "provenance")?.as_str().is_none() {
            return Err(PdfflowError::Format(format!(
                "provenance: {key:?} is not a string"
            )));
        }
    }
    need_num(prov, "unix_ts", "provenance")?;
    let Json::Obj(metrics) = need(j, "metrics", "snapshot")? else {
        return Err(PdfflowError::Format("snapshot: metrics is not an object".into()));
    };
    for (name, m) in metrics {
        let what = format!("metric {name:?}");
        match need(m, "type", &what)?.as_str() {
            Some("counter") | Some("gauge") => {
                need_num(m, "value", &what)?;
            }
            Some("histogram") => {
                let count = need_num(m, "count", &what)?;
                for key in ["sum", "min", "max", "mean", "p50", "p95", "p99"] {
                    need_num(m, key, &what)?;
                }
                let buckets = need(m, "buckets", &what)?
                    .as_arr()
                    .ok_or_else(|| PdfflowError::Format(format!("{what}: buckets not an array")))?;
                let mut total = 0.0;
                for b in buckets {
                    let t = b.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
                        PdfflowError::Format(format!("{what}: bucket is not [low,high,count]"))
                    })?;
                    total += t[2].as_f64().unwrap_or(f64::NAN);
                }
                if total != count {
                    return Err(PdfflowError::Format(format!(
                        "{what}: bucket counts sum to {total}, count says {count}"
                    )));
                }
            }
            other => {
                return Err(PdfflowError::Format(format!(
                    "{what}: unknown type {other:?}"
                )))
            }
        }
    }
    Ok(metrics.len())
}

/// Write the JSON snapshot at `path` and the Prometheus text at
/// `path.prom`. Returns the two paths.
pub fn write_metrics(path: impl AsRef<Path>) -> Result<(std::path::PathBuf, std::path::PathBuf)> {
    let json_path = path.as_ref().to_path_buf();
    if let Some(parent) = json_path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&json_path, format!("{}\n", snapshot()))?;
    let mut prom_path = json_path.clone().into_os_string();
    prom_path.push(".prom");
    let prom_path = std::path::PathBuf::from(prom_path);
    std::fs::write(&prom_path, prometheus())?;
    Ok((json_path, prom_path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn git_rev_resolves_in_this_checkout() {
        // The repo this crate lives in is a git checkout; the rev must
        // be a 40-hex hash there. Elsewhere, "unknown" is acceptable.
        let rev = git_rev();
        assert!(
            rev == "unknown" || (rev.len() == 40 && rev.chars().all(|c| c.is_ascii_hexdigit())),
            "unexpected git rev {rev:?}"
        );
    }

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("cache.window.hits"), "pdfflow_cache_window_hits");
        assert_eq!(prom_name("span.serve.point.ns"), "pdfflow_span_serve_point_ns");
    }

    #[test]
    fn report_fingerprint_lands_in_provenance_and_validates() {
        set_report_fingerprint(0x0123_4567_89ab_cdef);
        let prov = provenance();
        assert_eq!(
            prov.get("report_fingerprint").and_then(|f| f.as_str()),
            Some("0123456789abcdef")
        );
        // The extra provenance key must not break the v1 validator.
        // (Built by hand rather than via snapshot(): the live registry
        // is shared with concurrently-running tests.)
        let doc = Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("provenance", prov),
            ("metrics", Json::obj(Vec::new())),
        ]);
        validate_snapshot(&doc).expect("snapshot with fingerprint validates");
    }
}
