//! Flight recorder: a bounded ring of recent span events, dumped to
//! `<dump_dir>/flightrec-<ts>.json` on panic or error exit.
//!
//! A TB-scale pipeline that dies hours in leaves nothing behind unless
//! something was continuously recording. The ring keeps the last
//! `PDFFLOW_FLIGHTREC_CAP` (default 8192) begin/end/mark events —
//! enough to reconstruct what every thread was inside when the process
//! died — and the dump includes a full metrics snapshot, so the one
//! JSON file answers both "where was it" and "how far had it got".
//!
//! The recorder is armed by [`install_crash_hook`] (the CLI does this
//! at startup); library users can also call [`dump`] directly. Pushes
//! are gated by [`crate::telemetry::enabled`], so the ring costs
//! nothing when tracing is off.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// What an [`Event`] marks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Begin,
    End,
    Mark,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Begin => "begin",
            Kind::End => "end",
            Kind::Mark => "mark",
        }
    }
}

/// One recorded span boundary or marker.
#[derive(Clone, Debug)]
pub struct Event {
    /// Global order (monotone across threads).
    pub seq: u64,
    /// Nanoseconds since process telemetry epoch.
    pub t_ns: u64,
    /// Dense per-process thread id.
    pub thread: u64,
    /// Span nesting depth on that thread at event time.
    pub depth: u32,
    pub kind: Kind,
    pub name: &'static str,
    pub detail: Option<String>,
}

fn ring_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("PDFFLOW_FLIGHTREC_CAP")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(8192)
    })
}

struct Ring {
    events: Mutex<VecDeque<Event>>,
    dropped: AtomicU64,
}

fn ring() -> &'static Ring {
    static RING: OnceLock<Ring> = OnceLock::new();
    RING.get_or_init(|| Ring {
        events: Mutex::new(VecDeque::with_capacity(ring_cap().min(1024))),
        dropped: AtomicU64::new(0),
    })
}

/// Append an event, evicting the oldest past capacity.
pub(crate) fn push(ev: Event) {
    let r = ring();
    let mut q = r.events.lock().unwrap();
    if q.len() >= ring_cap() {
        q.pop_front();
        r.dropped.fetch_add(1, Relaxed);
    }
    q.push_back(ev);
}

/// Drain and return every buffered event (test hook; resets the ring).
pub fn take_events() -> Vec<Event> {
    let r = ring();
    let mut q = r.events.lock().unwrap();
    q.drain(..).collect()
}

/// Events evicted from the ring since process start.
pub fn dropped() -> u64 {
    ring().dropped.load(Relaxed)
}

static DUMP_DIR: OnceLock<Mutex<PathBuf>> = OnceLock::new();

fn dump_dir_lock() -> &'static Mutex<PathBuf> {
    DUMP_DIR.get_or_init(|| Mutex::new(PathBuf::from(".")))
}

/// Where crash dumps land — the CLI points this at the store dir as
/// soon as one is known, so the dump sits next to the data it
/// describes.
pub fn set_dump_dir(dir: impl AsRef<Path>) {
    *dump_dir_lock().lock().unwrap() = dir.as_ref().to_path_buf();
}

fn event_json(ev: &Event) -> Json {
    let mut pairs = vec![
        ("seq", Json::Num(ev.seq as f64)),
        ("t_ns", Json::Num(ev.t_ns as f64)),
        ("thread", Json::Num(ev.thread as f64)),
        ("depth", Json::Num(ev.depth as f64)),
        ("kind", Json::Str(ev.kind.name().into())),
        ("name", Json::Str(ev.name.into())),
    ];
    if let Some(d) = &ev.detail {
        pairs.push(("detail", Json::Str(d.clone())));
    }
    Json::obj(pairs)
}

/// Serialize the current ring + metrics snapshot (without clearing).
pub fn dump_json(reason: &str) -> Json {
    let r = ring();
    let events: Vec<Json> = r.events.lock().unwrap().iter().map(event_json).collect();
    Json::obj(vec![
        ("schema", Json::Str("pdfflow.flightrec.v1".into())),
        ("reason", Json::Str(reason.into())),
        ("provenance", super::export::provenance()),
        ("dropped", Json::Num(r.dropped.load(Relaxed) as f64)),
        ("events", Json::Arr(events)),
        ("metrics", super::export::metrics_json()),
    ])
}

/// Write `flightrec-<unix_ts>.json` into the configured dump dir.
/// Returns the path written. Never panics (a crash hook must not).
pub fn dump(reason: &str) -> std::io::Result<PathBuf> {
    let dir = dump_dir_lock().lock().unwrap().clone();
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut path = dir.join(format!("flightrec-{ts}.json"));
    // Two crashes in one second must not clobber each other.
    let mut k = 0;
    while path.exists() {
        k += 1;
        path = dir.join(format!("flightrec-{ts}-{k}.json"));
    }
    std::fs::create_dir_all(&dir)?;
    std::fs::write(&path, format!("{}\n", dump_json(reason)))?;
    Ok(path)
}

static ARMED: AtomicBool = AtomicBool::new(false);

/// Arm (or disarm) crash dumping without reinstalling the hook.
pub fn arm(on: bool) {
    ARMED.store(on, Relaxed);
}

/// Install a panic hook that dumps the flight recorder, chaining the
/// previously-installed hook. Idempotent; the hook only fires while
/// armed (see [`arm`]).
pub fn install_crash_hook() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        arm(true);
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if ARMED.load(Relaxed) && crate::telemetry::enabled() {
                match dump("panic") {
                    Ok(p) => eprintln!("flight recorder dumped to {}", p.display()),
                    Err(e) => eprintln!("flight recorder dump failed: {e}"),
                }
            }
            prev(info);
        }));
    });
}
