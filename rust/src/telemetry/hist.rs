//! Log-linear (HDR-style) concurrent histogram.
//!
//! Values are bucketed with 5 sub-bucket bits per power of two: buckets
//! 0..32 hold the exact values 0..32, and every octave above that is
//! split into 32 geometrically-placed sub-buckets, so any recorded
//! value is off by at most 1/32 (~3%) of itself. The full `u64` range
//! fits in [`NBUCKETS`] buckets, recording is a handful of relaxed
//! atomic ops (no locks, no allocation), and histograms merge
//! associatively — the properties that let one histogram sit on the
//! serve hot path and still answer p50/p95/p99 at export time.
//!
//! The running `sum` saturates instead of wrapping: a long-lived
//! nanosecond sum overflows `u64` after ~584 years of *recorded* time,
//! but a wrapped sum silently corrupts derived means, which is exactly
//! the `serve::ClassMetrics::latency_nanos` hazard this type replaces.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave.
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per octave.
pub const SUBS: usize = 1 << SUB_BITS;
/// Total buckets covering all of `u64` (octaves 0..=59, 32 subs each).
pub const NBUCKETS: usize = SUBS * 60;

/// Bucket index of a value (total order preserving).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let octave = (msb - SUB_BITS + 1) as usize;
    let sub = ((v >> (msb - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    octave * SUBS + sub
}

/// Inclusive `[low, high]` value range covered by bucket `idx`.
#[inline]
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < SUBS {
        return (idx as u64, idx as u64);
    }
    let octave = (idx / SUBS) as u32;
    let sub = (idx % SUBS) as u64;
    let msb = octave + SUB_BITS - 1;
    let low = (1u64 << msb) + (sub << (msb - SUB_BITS));
    let high = low + (1u64 << (msb - SUB_BITS)) - 1;
    (low, high)
}

fn saturating_fetch_add(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Relaxed);
    loop {
        let next = cur.saturating_add(v);
        match cell.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Concurrent log-linear histogram. All recording ops are lock-free
/// relaxed atomics; reads are racy-but-consistent-enough snapshots
/// (exact once writers quiesce).
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Saturating sum of recorded values (never wraps).
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets: Vec<AtomicU64> = (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        saturating_fetch_add(&self.sum, v);
        self.min.fetch_min(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Smallest recorded value (`None` while empty).
    pub fn min(&self) -> Option<u64> {
        let m = self.min.load(Relaxed);
        if m == u64::MAX && self.count() == 0 {
            None
        } else {
            Some(m)
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Value at quantile `q` in [0, 1] — the upper bound of the bucket
    /// holding the ceil(q·count)-th recorded value, clamped to the
    /// exact observed max (so `quantile(1.0) == max()`).
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Relaxed);
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= rank {
                return bucket_bounds(idx).1.min(self.max());
            }
        }
        self.max()
    }

    /// Fold `other` into `self`. Associative and commutative: merging
    /// per-shard histograms in any grouping yields the same totals.
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let c = theirs.load(Relaxed);
            if c > 0 {
                mine.fetch_add(c, Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Relaxed);
        saturating_fetch_add(&self.sum, other.sum());
        if let Some(m) = other.min() {
            self.min.fetch_min(m, Relaxed);
        }
        self.max.fetch_max(other.max(), Relaxed);
    }

    /// Zero every counter (bench harness use; racy under writers).
    pub fn reset(&self) {
        for b in self.buckets.iter() {
            b.store(0, Relaxed);
        }
        self.count.store(0, Relaxed);
        self.sum.store(0, Relaxed);
        self.min.store(u64::MAX, Relaxed);
        self.max.store(0, Relaxed);
    }

    /// Non-empty buckets as `(index, count)` in index order.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        for v in 0..SUBS as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v));
        }
    }

    #[test]
    fn buckets_tile_u64_without_gaps() {
        // Bounds must be contiguous: high(i) + 1 == low(i+1).
        for idx in 0..NBUCKETS - 1 {
            let (_, high) = bucket_bounds(idx);
            let (low_next, _) = bucket_bounds(idx + 1);
            assert_eq!(high.wrapping_add(1), low_next, "gap after bucket {idx}");
        }
        assert_eq!(bucket_bounds(NBUCKETS - 1).1, u64::MAX);
    }

    #[test]
    fn index_matches_bounds() {
        for &v in &[0u64, 1, 31, 32, 33, 63, 64, 100, 1000, 1 << 20, u64::MAX] {
            let idx = bucket_index(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && v <= hi, "value {v} outside bucket {idx} [{lo},{hi}]");
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            // Bucket width ≤ low/32 for v ≥ 32; exact below.
            assert!(hi - lo <= lo.max(1) / SUBS as u64 + 1);
            v = v.wrapping_mul(3) + 7;
        }
    }
}
