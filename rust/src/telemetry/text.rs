//! Human-readable metric rendering — the one place the CLI's verbose
//! blocks (pool, stage, cache, serve) are formatted, replacing the
//! copy-pasted `println!` runs each subcommand used to carry.

use std::fmt::Write as _;

use crate::executor::StageMetrics;
use crate::runtime::hostpool::PoolMetrics;
use crate::serve::{Class, ServeMetrics};
use crate::util::timing::{fmt_bytes, fmt_secs};

/// Cache meter line data (both `storage::CacheStats` and
/// `pdfstore::CacheMeters` convert into this).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheLine {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: u64,
    pub entries: usize,
}

/// One renderable block of the verbose report.
pub enum Section<'a> {
    /// Host-pool occupancy + per-worker busy histogram.
    Pool(&'a PoolMetrics),
    /// One executor stage's counters, labeled.
    Stage(&'a str, &'a StageMetrics),
    /// One cache's meters, labeled.
    Cache(&'a str, CacheLine),
    /// The serving tier's per-class table.
    Serve(&'a ServeMetrics),
}

/// Render the given sections as the CLI's indented verbose text.
pub fn render_text(sections: &[Section]) -> String {
    let mut out = String::new();
    for s in sections {
        match s {
            Section::Pool(p) => {
                let _ = writeln!(
                    out,
                    "  host pool: budget {} ({} workers), {} tickets, busy {}, peak busy {}, peak queue {}",
                    p.budget,
                    p.workers,
                    p.tickets_run,
                    fmt_secs(p.busy_seconds),
                    p.peak_busy,
                    p.peak_queue_depth
                );
                let _ = writeln!(
                    out,
                    "  pool items: {} stolen by workers / {} drained by helping callers",
                    p.items_stolen, p.items_helped
                );
                let hist: Vec<String> = p
                    .per_worker
                    .iter()
                    .enumerate()
                    .map(|(k, w)| format!("w{k} {} ({} tickets)", fmt_secs(w.busy_s), w.tickets))
                    .collect();
                if !hist.is_empty() {
                    let _ = writeln!(out, "  worker busy histogram: {}", hist.join(", "));
                }
            }
            Section::Stage(label, e) => {
                let _ = writeln!(
                    out,
                    "  stage {label}: {} tasks, busy {}, peak in-flight {}, peak reorder {}",
                    e.tasks,
                    fmt_secs(e.busy_s),
                    e.peak_in_flight,
                    e.peak_pending
                );
            }
            Section::Cache(label, m) => {
                let _ = writeln!(
                    out,
                    "{label}: {} hits / {} misses / {} evictions, {} resident in {} blocks",
                    m.hits,
                    m.misses,
                    m.evictions,
                    fmt_bytes(m.bytes),
                    m.entries
                );
            }
            Section::Serve(m) => {
                for c in Class::ALL {
                    let cm = m.class(c);
                    if cm.admitted + cm.shed == 0 {
                        continue;
                    }
                    let _ = writeln!(
                        out,
                        "  {:<9} admitted {:>7}  completed {:>7}  shed {:>6}  errors {:>4}  \
                         p50 {}  p95 {}  p99 {}  max {}  queued {}",
                        c.name(),
                        cm.admitted,
                        cm.completed,
                        cm.shed,
                        cm.errors,
                        fmt_secs(cm.latency_p50_s),
                        fmt_secs(cm.latency_p95_s),
                        fmt_secs(cm.latency_p99_s),
                        fmt_secs(cm.latency_s_max),
                        fmt_secs(cm.queue_s_sum),
                    );
                }
            }
        }
    }
    out
}
