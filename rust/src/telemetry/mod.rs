//! Unified telemetry: metrics registry, span tracing, flight recorder.
//!
//! The source paper's whole contribution is an execution-time argument;
//! this module is the one surface every perf claim in this repo reports
//! against. Three layers:
//!
//! * **Registry** — a process-wide map of named [`Counter`]s,
//!   [`Gauge`]s, and log-linear [`Histogram`]s. Handles are `Arc`s:
//!   registration takes a mutex once, after which the hot path is
//!   relaxed atomics only. Names are dotted (`pool.ticket_ns`,
//!   `cache.window.hits`); exporters map them to Prometheus /
//!   JSON identifiers.
//! * **Spans** — RAII timers ([`Span::enter`], the [`span!`] macro)
//!   at pipeline-stage granularity (load → fit → persist, segment
//!   I/O, serve requests). Each closed span records its duration into
//!   the `span.<name>.ns` histogram and pushes begin/end events into
//!   the flight recorder. Spans are gated: compile-time by the
//!   `telemetry` cargo feature (on by default), run-time by
//!   `PDFFLOW_TRACE` (`0`/`off`/`false` disables) or
//!   [`set_enabled`]. Disabled spans cost one relaxed load.
//! * **Flight recorder** ([`flight`]) — a bounded ring of recent span
//!   events dumped to `flightrec-<ts>.json` on panic or error exit, so
//!   a killed TB-scale run is diagnosable post-mortem.
//!
//! Always-on meters (cache hit/miss counters, pool/backend totals) stay
//! live regardless of the trace gate — they are cheap and the existing
//! metrics structs' accessors are derived from them.

pub mod export;
pub mod flight;
pub mod hist;
pub mod text;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

pub use hist::Histogram;

/// Monotonic counter (relaxed atomics; never decreases).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-write-wins `f64` gauge (bits stored in an `AtomicU64`).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// One registered metric (shared handle).
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    pub fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// Named-metric registry. Get-or-create returns shared handles;
/// the map mutex is only held during registration and snapshot.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry every instrumented subsystem feeds.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Get-or-create a counter. A name already registered as another
    /// type yields a fresh detached handle (recorded values are then
    /// invisible to exporters rather than corrupting the other metric).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match entry {
            Metric::Counter(c) => Arc::clone(c),
            _ => {
                debug_assert!(false, "metric {name:?} registered as {}", entry.kind());
                Arc::new(Counter::new())
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())));
        match entry {
            Metric::Gauge(g) => Arc::clone(g),
            _ => {
                debug_assert!(false, "metric {name:?} registered as {}", entry.kind());
                Arc::new(Gauge::new())
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match entry {
            Metric::Histogram(h) => Arc::clone(h),
            _ => {
                debug_assert!(false, "metric {name:?} registered as {}", entry.kind());
                Arc::new(Histogram::new())
            }
        }
    }

    /// Register (or replace) `name` with an externally-owned histogram
    /// — how per-instance metrics (serve class latencies) surface in
    /// the process snapshot without giving up instance-exact accessors.
    pub fn register_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.metrics
            .lock()
            .unwrap()
            .insert(name.to_string(), Metric::Histogram(h));
    }

    /// Convenience: point gauge write without keeping the handle.
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauge(name).set(v);
    }

    /// Stable-ordered snapshot of every registered metric.
    pub fn snapshot(&self) -> Vec<(String, Metric)> {
        self.metrics
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

// ---------------------------------------------------------------------
// Trace gate
// ---------------------------------------------------------------------

/// 0 = unresolved, 1 = off, 2 = on.
static TRACE_STATE: AtomicU8 = AtomicU8::new(0);

fn env_trace_default() -> bool {
    // Tracing defaults ON; PDFFLOW_TRACE=0|off|false disables it.
    match std::env::var("PDFFLOW_TRACE") {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "off" | "false"),
        Err(_) => true,
    }
}

/// Is span tracing / flight recording live? One relaxed load after the
/// first call; compiled to `false` without the `telemetry` feature.
#[inline]
pub fn enabled() -> bool {
    #[cfg(not(feature = "telemetry"))]
    {
        false
    }
    #[cfg(feature = "telemetry")]
    {
        match TRACE_STATE.load(Relaxed) {
            0 => {
                let on = env_trace_default();
                TRACE_STATE.store(if on { 2 } else { 1 }, Relaxed);
                on
            }
            1 => false,
            _ => true,
        }
    }
}

/// Programmatic override of the trace gate (benches, tests).
pub fn set_enabled(on: bool) {
    TRACE_STATE.store(if on { 2 } else { 1 }, Relaxed);
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// Nanoseconds since the first telemetry event in this process.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(0);
static NEXT_SEQ: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ID: u64 = NEXT_THREAD_ID.fetch_add(1, Relaxed);
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Small dense id of the calling thread (assigned on first use).
pub fn thread_id() -> u64 {
    THREAD_ID.with(|t| *t)
}

fn next_seq() -> u64 {
    NEXT_SEQ.fetch_add(1, Relaxed)
}

/// RAII span: times a region, records `span.<name>.ns` on drop, and
/// books begin/end events into the flight recorder. Construct via
/// [`Span::enter`] / [`Span::enter_with`] / the [`span!`] macro.
/// When tracing is disabled this is a no-op (no clock read).
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
}

impl Span {
    #[inline]
    pub fn enter(name: &'static str) -> Span {
        Span::begin(name, None)
    }

    /// Like [`Span::enter`], but attaches a detail string — the closure
    /// only runs (and allocates) when tracing is live.
    #[inline]
    pub fn enter_with(name: &'static str, detail: impl FnOnce() -> String) -> Span {
        if !enabled() {
            return Span { name, start: None };
        }
        Span::begin(name, Some(detail()))
    }

    fn begin(name: &'static str, detail: Option<String>) -> Span {
        if !enabled() {
            return Span { name, start: None };
        }
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        flight::push(flight::Event {
            seq: next_seq(),
            t_ns: now_ns(),
            thread: thread_id(),
            depth,
            kind: flight::Kind::Begin,
            name,
            detail,
        });
        Span {
            name,
            start: Some(Instant::now()),
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(t0) = self.start else { return };
        let elapsed = t0.elapsed();
        let depth = DEPTH.with(|d| {
            let v = d.get().saturating_sub(1);
            d.set(v);
            v
        });
        span_hist(self.name).record_duration(elapsed);
        flight::push(flight::Event {
            seq: next_seq(),
            t_ns: now_ns(),
            thread: thread_id(),
            depth,
            kind: flight::Kind::End,
            name: self.name,
            detail: None,
        });
    }
}

/// Cached `span.<name>.ns` histogram handles, keyed by the static span
/// name — closing a span never allocates a registry key string twice.
fn span_hist(name: &'static str) -> Arc<Histogram> {
    static CACHE: OnceLock<Mutex<BTreeMap<&'static str, Arc<Histogram>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = cache.lock().unwrap();
    Arc::clone(
        map.entry(name)
            .or_insert_with(|| Registry::global().histogram(&format!("span.{name}.ns"))),
    )
}

/// Drop a point-in-time marker event into the flight recorder.
pub fn mark(name: &'static str, detail: impl FnOnce() -> String) {
    if !enabled() {
        return;
    }
    flight::push(flight::Event {
        seq: next_seq(),
        t_ns: now_ns(),
        thread: thread_id(),
        depth: DEPTH.with(|d| d.get()),
        kind: flight::Kind::Mark,
        name,
        detail: Some(detail()),
    });
}

/// Time a region until end of scope:
/// `let _s = span!("fit");` or `let _s = span!("fit", "slice {z} window {w}");`
/// The detail format arguments are only evaluated when tracing is live.
#[macro_export]
macro_rules! span {
    ($name:literal) => {
        $crate::telemetry::Span::enter($name)
    };
    ($name:literal, $($fmt:tt)+) => {
        $crate::telemetry::Span::enter_with($name, || format!($($fmt)+))
    };
}

// ---------------------------------------------------------------------
// Process-level publication
// ---------------------------------------------------------------------

/// Copy point-in-time process metrics (host-pool occupancy) into the
/// registry so exports carry them. Called by exporters right before a
/// snapshot; cheap and idempotent.
pub fn publish_process_metrics() {
    let p = crate::runtime::hostpool::HostPool::global().metrics();
    let r = Registry::global();
    r.set_gauge("pool.budget", p.budget as f64);
    r.set_gauge("pool.workers", p.workers as f64);
    r.set_gauge("pool.tickets_run", p.tickets_run as f64);
    r.set_gauge("pool.busy_seconds", p.busy_seconds);
    r.set_gauge("pool.peak_busy", p.peak_busy as f64);
    r.set_gauge("pool.peak_queue_depth", p.peak_queue_depth as f64);
    r.set_gauge("pool.items_stolen", p.items_stolen as f64);
    r.set_gauge("pool.items_helped", p.items_helped as f64);
}
