//! Generic sharded stamp-LRU: the one cache core behind both
//! [`crate::storage::WindowCache`] (loaded observation windows) and
//! [`crate::pdfstore::query::ShardedLru`] (decoded segment blocks).
//!
//! Entries carry a monotonically increasing access stamp per shard;
//! eviction removes the minimum stamp until the shard is back under its
//! budget (capacity is split evenly across shards). Shard count is a
//! contention knob, not a capacity one: one shard gives exact global
//! LRU, many shards let concurrent readers hit disjoint mutexes. Hit /
//! miss / eviction meters are atomic and always-on — the shared
//! observability contract both wrappers re-export.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::{Counter, Registry};

/// Aggregated observability counters of a sharded LRU.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LruStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Resident bytes (sum over shards).
    pub bytes: u64,
    /// Resident entries (sum over shards).
    pub entries: usize,
}

struct Shard<K, V> {
    map: HashMap<K, (u64, V)>, // key -> (stamp, value)
    clock: u64,
    bytes: u64,
}

/// Sharded LRU with a global byte budget split evenly across shards.
/// Values are returned by clone — store `Arc`s for large payloads.
pub struct ShardedStampLru<K, V> {
    shards: Vec<Mutex<Shard<K, V>>>,
    shard_budget: u64,
    /// Sizes a value for budget accounting (a plain `fn`, so both cache
    /// fronts can supply capture-free weighers).
    weigh: fn(&V) -> u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Shared process-registry counters (`cache.<label>.hits` / misses
    /// / evictions), bumped alongside the instance meters when the
    /// cache was built [`Self::with_label`]. Labelless caches (unit
    /// tests, scratch caches) stay invisible to exporters.
    published: Option<[Arc<Counter>; 3]>,
}

impl<K: Hash + Eq + Clone, V: Clone> ShardedStampLru<K, V> {
    pub fn new(capacity_bytes: u64, n_shards: usize, weigh: fn(&V) -> u64) -> Self {
        let n = n_shards.max(1);
        ShardedStampLru {
            shards: (0..n)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        clock: 0,
                        bytes: 0,
                    })
                })
                .collect(),
            shard_budget: capacity_bytes / n as u64,
            weigh,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            published: None,
        }
    }

    /// Like [`Self::new`], but also mirrors the meters into the process
    /// registry under `cache.<label>.{hits,misses,evictions}`. Several
    /// instances may share one label; the registry counters then sum
    /// their traffic while each instance's `stats()` stays exact.
    pub fn with_label(
        capacity_bytes: u64,
        n_shards: usize,
        weigh: fn(&V) -> u64,
        label: &str,
    ) -> Self {
        let r = Registry::global();
        let mut lru = Self::new(capacity_bytes, n_shards, weigh);
        lru.published = Some([
            r.counter(&format!("cache.{label}.hits")),
            r.counter(&format!("cache.{label}.misses")),
            r.counter(&format!("cache.{label}.evictions")),
        ]);
        lru
    }

    fn shard_of(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    /// Look up and refresh the access stamp; meters the hit or miss.
    pub fn get(&self, key: &K) -> Option<V> {
        let mut g = self.shards[self.shard_of(key)].lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let found = g.map.get_mut(key).map(|(stamp, v)| {
            *stamp = clock;
            v.clone()
        });
        match found {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if let Some([h, _, _]) = &self.published {
                    h.inc();
                }
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                if let Some([_, m, _]) = &self.published {
                    m.inc();
                }
                None
            }
        }
    }

    /// Insert (or replace), then evict least-recently-used entries until
    /// the shard is back under budget. Values bigger than one shard's
    /// budget are not cached at all (streamed, like input data).
    pub fn put(&self, key: K, value: V) {
        let bytes = (self.weigh)(&value);
        if bytes > self.shard_budget {
            return;
        }
        let mut g = self.shards[self.shard_of(&key)].lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        if let Some((_, old)) = g.map.insert(key, (clock, value)) {
            g.bytes -= (self.weigh)(&old);
        }
        g.bytes += bytes;
        while g.bytes > self.shard_budget {
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k.clone())
                .expect("over budget implies non-empty");
            let (_, evicted) = g.map.remove(&victim).unwrap();
            g.bytes -= (self.weigh)(&evicted);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            if let Some([_, _, e]) = &self.published {
                e.inc();
            }
        }
    }

    pub fn stats(&self) -> LruStats {
        let mut s = LruStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            ..LruStats::default()
        };
        for shard in &self.shards {
            let g = shard.lock().unwrap();
            s.bytes += g.bytes;
            s.entries += g.map.len();
        }
        s
    }

    /// Drop every entry; the hit/miss/eviction meters survive (they
    /// describe the session, not the current residency).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut g = shard.lock().unwrap();
            g.map.clear();
            g.bytes = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn blob(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0u8; n])
    }

    fn weigh(v: &Arc<Vec<u8>>) -> u64 {
        v.len() as u64
    }

    #[test]
    fn single_shard_is_exact_global_lru() {
        let c: ShardedStampLru<u32, Arc<Vec<u8>>> = ShardedStampLru::new(250, 1, weigh);
        c.put(0, blob(100));
        c.put(1, blob(100));
        assert!(c.get(&0).is_some()); // refresh 0 → 1 becomes LRU
        c.put(2, blob(100)); // evicts 1
        assert!(c.get(&1).is_none());
        assert!(c.get(&0).is_some() && c.get(&2).is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!((s.bytes, s.entries), (200, 2));
    }

    #[test]
    fn oversized_values_are_not_cached() {
        let c: ShardedStampLru<u32, Arc<Vec<u8>>> = ShardedStampLru::new(100, 4, weigh); // 25/shard
        c.put(7, blob(30));
        assert!(c.get(&7).is_none());
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn replace_same_key_updates_bytes() {
        let c: ShardedStampLru<u32, Arc<Vec<u8>>> = ShardedStampLru::new(10_000, 2, weigh);
        c.put(1, blob(100));
        c.put(1, blob(300));
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (300, 1));
    }

    #[test]
    fn clear_keeps_meters() {
        let c: ShardedStampLru<u32, Arc<Vec<u8>>> = ShardedStampLru::new(10_000, 4, weigh);
        c.put(1, blob(10));
        assert!(c.get(&1).is_some());
        assert!(c.get(&2).is_none());
        c.clear();
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn shards_partition_the_key_space() {
        let c: ShardedStampLru<u64, Arc<Vec<u8>>> = ShardedStampLru::new(64 << 10, 8, weigh);
        for k in 0..256u64 {
            c.put(k, blob(16));
        }
        for k in 0..256u64 {
            assert!(c.get(&k).is_some(), "key {k} lost without budget pressure");
        }
        assert_eq!(c.stats().entries, 256);
    }
}
