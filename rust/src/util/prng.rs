//! Deterministic PRNG + distribution samplers (offline `rand` substitute).
//!
//! Xoshiro256++ seeded via SplitMix64, with samplers for every
//! distribution family the paper's data generator and tests need. All
//! samplers are reproducible given the seed, which the experiment harness
//! relies on (EXPERIMENTS.md records seeds next to every figure).

/// Xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-point / per-simulation rngs).
    pub fn fork(&self, stream: u64) -> Self {
        // Mix the current state with the stream id through SplitMix64.
        Rng::new(
            self.s[0]
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(stream.wrapping_mul(0xD1B54A32D192ED03)),
        )
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (polar rejection-free variant).
    pub fn std_normal(&mut self) -> f64 {
        // Marsaglia polar method with loop (expected < 1.3 iterations).
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.std_normal()
    }

    pub fn lognormal(&mut self, mulog: f64, sigmalog: f64) -> f64 {
        self.normal(mulog, sigmalog).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang (k >= 1 squeeze,
    /// boost for k < 1).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.std_normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        scale * (-(1.0 - self.f64()).ln()).powf(1.0 / shape)
    }

    pub fn cauchy(&mut self, loc: f64, scale: f64) -> f64 {
        loc + scale * (std::f64::consts::PI * (self.f64() - 0.5)).tan()
    }

    pub fn logistic(&mut self, loc: f64, scale: f64) -> f64 {
        let u = self.f64().clamp(1e-12, 1.0 - 1e-12);
        loc + scale * (u / (1.0 - u)).ln()
    }

    /// Student's t with nu degrees of freedom (ratio of normal / chi).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        let z = self.std_normal();
        let g = self.gamma(nu / 2.0, 2.0); // chi^2_nu
        z / (g / nu).sqrt()
    }

    /// Geometric on {0, 1, 2, ...} with success probability p.
    pub fn geometric(&mut self, p: f64) -> f64 {
        let u = self.f64().max(1e-300);
        (u.ln() / (1.0 - p).ln()).floor()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1.0);
        (m, v.sqrt())
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20000).map(|_| r.uniform(2.0, 8.0)).collect();
        assert!(xs.iter().all(|&x| (2.0..8.0).contains(&x)));
        let (m, _) = moments(&xs);
        assert!((m - 5.0).abs() < 0.05, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..40000).map(|_| r.normal(10.0, 3.0)).collect();
        let (m, s) = moments(&xs);
        assert!((m - 10.0).abs() < 0.06, "mean {m}");
        assert!((s - 3.0).abs() < 0.06, "std {s}");
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..40000).map(|_| r.exponential(0.5)).collect();
        let (m, s) = moments(&xs);
        assert!((m - 2.0).abs() < 0.06, "mean {m}");
        assert!((s - 2.0).abs() < 0.1, "std {s}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(6);
        let (k, th) = (4.0, 2.5);
        let xs: Vec<f64> = (0..40000).map(|_| r.gamma(k, th)).collect();
        let (m, s) = moments(&xs);
        assert!((m - k * th).abs() < 0.2, "mean {m}");
        assert!((s - (k).sqrt() * th).abs() < 0.2, "std {s}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..40000).map(|_| r.gamma(0.5, 1.0)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 0.5).abs() < 0.03, "mean {m}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn weibull_moments() {
        let mut r = Rng::new(8);
        // k=2, lambda=1: mean = Gamma(1.5) = sqrt(pi)/2
        let xs: Vec<f64> = (0..40000).map(|_| r.weibull(2.0, 1.0)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 0.8862).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn lognormal_log_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..40000).map(|_| r.lognormal(1.0, 0.5).ln()).collect();
        let (m, s) = moments(&xs);
        assert!((m - 1.0).abs() < 0.02);
        assert!((s - 0.5).abs() < 0.02);
    }

    #[test]
    fn student_t_symmetric() {
        let mut r = Rng::new(10);
        let xs: Vec<f64> = (0..40000).map(|_| r.student_t(8.0)).collect();
        let (m, _) = moments(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
    }

    #[test]
    fn geometric_support_and_mean() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..40000).map(|_| r.geometric(0.3)).collect();
        assert!(xs.iter().all(|&x| x >= 0.0 && x.fract() == 0.0));
        let (m, _) = moments(&xs);
        assert!((m - 0.7 / 0.3).abs() < 0.1, "mean {m}"); // (1-p)/p
    }

    #[test]
    fn logistic_moments() {
        let mut r = Rng::new(12);
        let xs: Vec<f64> = (0..40000).map(|_| r.logistic(3.0, 1.5)).collect();
        let (m, s) = moments(&xs);
        assert!((m - 3.0).abs() < 0.1);
        let expect = 1.5 * std::f64::consts::PI / 3f64.sqrt();
        assert!((s - expect).abs() < 0.1, "std {s} want {expect}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(14);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(15);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
