//! TOML-subset parser for experiment configs (offline `toml` substitute).
//!
//! Supports: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / homogeneous-array values, `#`
//! comments. Keys are exposed as flat `section.key` paths. This covers
//! everything `configs/*.toml` uses; unknown syntax is a hard error so
//! config typos fail loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML document: flat `section.key` -> value map.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if map.insert(path.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key {path}", lineno + 1));
            }
        }
        Ok(TomlDoc { map })
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.map.get(path)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.i64_or(path, default as i64) as usize
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)?;
        return Ok(TomlValue::Arr(
            items
                .into_iter()
                .map(|it| parse_value(it.trim()))
                .collect::<Result<_, _>>()?,
        ));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1).ok_or("unbalanced ]")?,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
name = "set1"            # inline comment
[dataset]
cube = [64, 96, 96]
simulations = 1000
noise = 0.05
grouped = true
path = "/tmp/data # not a comment"
[cluster.lncc]
nodes = 6
cores = 32
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("name", ""), "set1");
        assert_eq!(d.i64_or("dataset.simulations", 0), 1000);
        assert_eq!(d.f64_or("dataset.noise", 0.0), 0.05);
        assert!(d.bool_or("dataset.grouped", false));
        assert_eq!(d.i64_or("cluster.lncc.nodes", 0), 6);
        assert_eq!(d.i64_or("cluster.lncc.cores", 0), 32);
    }

    #[test]
    fn hash_inside_string_kept() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("dataset.path", ""), "/tmp/data # not a comment");
    }

    #[test]
    fn arrays() {
        let d = TomlDoc::parse(DOC).unwrap();
        let arr = match d.get("dataset.cube").unwrap() {
            TomlValue::Arr(a) => a.clone(),
            other => panic!("{other:?}"),
        };
        assert_eq!(
            arr,
            vec![TomlValue::Int(64), TomlValue::Int(96), TomlValue::Int(96)]
        );
    }

    #[test]
    fn nested_arrays() {
        let d = TomlDoc::parse("m = [[1,2],[3,4]]").unwrap();
        match d.get("m").unwrap() {
            TomlValue::Arr(rows) => assert_eq!(rows.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("missing.key", 7), 7);
    }

    #[test]
    fn errors_are_loud() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("junk line").is_err());
        assert!(TomlDoc::parse("s = \"unterminated").is_err());
    }

    #[test]
    fn negative_and_underscored_numbers() {
        let d = TomlDoc::parse("a = -42\nb = 1_000_000\nc = -2.5e-3").unwrap();
        assert_eq!(d.i64_or("a", 0), -42);
        assert_eq!(d.i64_or("b", 0), 1_000_000);
        assert!((d.f64_or("c", 0.0) + 0.0025).abs() < 1e-12);
    }
}
