//! Mini property-testing harness (offline `proptest` substitute).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs derived from a deterministic master seed (overridable via the
//! `PDFFLOW_TEST_SEED` env var). On failure it reports the failing case
//! seed so the case can be replayed exactly:
//!
//! ```text
//! property 'grouping_partitions' failed at case 17 (seed 0x12ab..): <msg>
//! ```

use crate::util::prng::Rng;

/// Outcome of a single property case; use `fail!`-style early returns.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` deterministic pseudo-random cases.
/// Panics (test failure) on the first failing case, reporting its seed.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    let master = master_seed();
    for case in 0..cases {
        let seed = master
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay: PDFFLOW_TEST_SEED={master} (master)"
            );
        }
    }
}

fn master_seed() -> u64 {
    std::env::var("PDFFLOW_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Randomized-case count for property suites: the
/// `PDFFLOW_PROPTEST_CASES` env var when set (CI cranks it up), the
/// suite's `default` otherwise.
pub fn cases(default: usize) -> usize {
    std::env::var("PDFFLOW_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Assert helper returning CaseResult instead of panicking, so `check`
/// can attach the case seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        check("trivial", 10, |_rng| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 3, |_rng| Err("boom".into()));
    }

    #[test]
    fn deterministic_inputs() {
        let record = |out: &std::cell::RefCell<Vec<u64>>| {
            check("record", 5, |rng| {
                out.borrow_mut().push(rng.next_u64());
                Ok(())
            });
        };
        let first = std::cell::RefCell::new(Vec::new());
        let second = std::cell::RefCell::new(Vec::new());
        record(&first);
        record(&second);
        assert_eq!(first.into_inner(), second.into_inner());
    }

    #[test]
    fn prop_assert_macro() {
        check("macro", 4, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }
}
