//! Timing + summary statistics helpers (offline `criterion` substrate).

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile on a pre-sorted slice, q in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Human-readable duration: "1.23s", "45.6ms", "789us".
pub fn fmt_secs(s: f64) -> String {
    if s >= 60.0 {
        format!("{:.1}min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[2.5]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 2.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&sorted, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(120.0), "2.0min");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(0.000_5), "500us");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0MiB");
    }

    #[test]
    fn timed_measures() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
