//! Minimal JSON codec (offline `serde_json` substitute).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes experiment reports. Supports the full JSON value model;
//! numbers are kept as f64 (sufficient for both uses).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("eof in \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad utf8 in \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad hex in \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // Consume the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| "bad utf8 in string")?;
                    out.push_str(s);
                }
            }
        }
        Err("eof in string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo ≤\"").unwrap(),
            Json::Str("héllo ≤".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_escapes() {
        let j = Json::Str("line\nquote\"tab\t".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest() {
        let text = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json"),
        );
        if let Ok(text) = text {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("artifacts").unwrap().as_arr().unwrap().len() >= 13);
        }
    }
}
