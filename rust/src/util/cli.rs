//! Tiny CLI argument parser (offline `clap` substitute).
//!
//! Model: `prog <subcommand> [--flag] [--opt value] [positional...]`.
//! Options may be given as `--opt value` or `--opt=value`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding argv[0]). `known_flags` lists boolean
    /// switches (they consume no value); everything else starting with
    /// `--` is treated as an option expecting a value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} {v:?}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} {v:?}: {e}")),
        }
    }

    /// Comma-separated list option, e.g. `--nodes 10,20,30`.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.opt(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = Args::parse(
            argv("run --method grouping --window 25 --verbose slice201"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("method"), Some("grouping"));
        assert_eq!(a.usize_or("window", 0).unwrap(), 25);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["slice201"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("x --rate=0.25"), &[]).unwrap();
        assert!((a.f64_or("rate", 0.0).unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("x --opt"), &[]).is_err());
    }

    #[test]
    fn list_option() {
        let a = Args::parse(argv("x --nodes 10,20,30"), &[]).unwrap();
        assert_eq!(a.list_or("nodes", &[]), vec!["10", "20", "30"]);
        assert_eq!(a.list_or("absent", &["1"]), vec!["1"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), &[]).unwrap();
        assert!(a.subcommand.is_none());
        assert_eq!(a.usize_or("w", 5).unwrap(), 5);
        assert!(!a.flag("anything"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv("x --n abc"), &[]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }
}
