//! In-tree substrates for crates unavailable in this offline image
//! (tokio / clap / criterion / serde / rand): a PRNG with distribution
//! samplers, JSON and TOML-subset codecs, a CLI argument parser,
//! timing/statistics helpers, and a mini property-testing harness. See
//! DESIGN.md §Substrates. (The scoped thread pool that used to live at
//! `util::pool` is gone — all host parallelism now routes through the
//! persistent shared-budget pool in [`crate::runtime::hostpool`].)

pub mod cli;
pub mod json;
pub mod lru;
pub mod prng;
pub mod testkit;
pub mod timing;
pub mod toml;
