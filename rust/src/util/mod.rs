//! In-tree substrates for crates unavailable in this offline image
//! (tokio / clap / criterion / serde / rand): a PRNG with distribution
//! samplers, JSON and TOML-subset codecs, a CLI argument parser, a scoped
//! thread pool, timing/statistics helpers, and a mini property-testing
//! harness. See DESIGN.md §Substrates.

pub mod cli;
pub mod json;
pub mod lru;
pub mod pool;
pub mod prng;
pub mod testkit;
pub mod timing;
pub mod toml;
