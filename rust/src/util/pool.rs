//! Scoped thread pool for parallel map (offline `tokio`/`rayon` substitute).
//!
//! The coordinator's host-level parallelism (loading windows, running
//! batches) goes through `parallel_map`; simulated-cluster parallelism is
//! handled separately by [`crate::cluster`] (it *models* many nodes, the
//! host only has the cores it has).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (host parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item, using up to `workers` threads, preserving
/// input order in the output. Panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    // Work-stealing by index over a shared Vec<Option<T>>.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("slot taken twice");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

/// Run `f` over index range [0, n) in parallel, collecting results in order.
pub fn parallel_for<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    parallel_map((0..n).collect(), workers, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = parallel_for(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = parallel_map(Vec::<u32>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], 4, |x| x + 1), vec![8]);
    }

    #[test]
    fn single_worker_is_serial() {
        let out = parallel_for(10, 1, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn non_copy_items() {
        let items: Vec<String> = (0..20).map(|i| format!("s{i}")).collect();
        let out = parallel_map(items, 3, |s| s.len());
        assert_eq!(out.len(), 20);
    }
}
