//! Mini-RDD: partitioned in-memory collections with the Spark operations
//! the paper's pipeline uses (Map, aggregateByKey, Cache → here: owned
//! partitions, broadcast) and shuffle-byte accounting wired into the
//! simulated cluster.
//!
//! This is deliberately *not* a lazy DAG engine — the paper's pipeline is
//! a straight line (load → group → fit → persist), so eager partitioned
//! collections keep the dataflow vocabulary without Spark's machinery.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use crate::cluster::SimCluster;

/// A partitioned collection. Partition `i` is conceptually resident on
/// node `i % nodes`.
#[derive(Clone, Debug)]
pub struct Rdd<T> {
    pub partitions: Vec<Vec<T>>,
}

impl<T> Rdd<T> {
    /// Evenly distribute items over `n_partitions` (paper: "the
    /// identifications of points are stored in an RDD, which is evenly
    /// distributed on multiple cluster nodes").
    pub fn from_vec(items: Vec<T>, n_partitions: usize) -> Rdd<T> {
        let n_partitions = n_partitions.max(1);
        let n = items.len();
        let base = n / n_partitions;
        let extra = n % n_partitions;
        let mut partitions = Vec::with_capacity(n_partitions);
        let mut it = items.into_iter();
        for p in 0..n_partitions {
            let take = base + usize::from(p < extra);
            partitions.push(it.by_ref().take(take).collect());
        }
        Rdd { partitions }
    }

    /// Spark `coalesce`: shrink to at most `n_partitions` partitions
    /// (no shuffle is charged — in-memory merge). Edge cases follow
    /// `from_vec`: `n_partitions == 0` is clamped to 1, and a target at
    /// or above the current partition count is a no-op. Unlike Spark's
    /// adjacent-merge, the in-memory rebuild re-balances exactly
    /// (partition sizes differ by at most one) while preserving item
    /// order.
    pub fn coalesce(self, n_partitions: usize) -> Rdd<T> {
        let n = n_partitions.max(1);
        if n >= self.partitions.len() {
            return self;
        }
        Self::from_vec(self.collect(), n)
    }

    pub fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn n_items(&self) -> usize {
        self.partitions.iter().map(|p| p.len()).sum()
    }

    /// Spark `map` (no shuffle).
    pub fn map<U>(self, f: impl Fn(T) -> U) -> Rdd<U> {
        Rdd {
            partitions: self
                .partitions
                .into_iter()
                .map(|p| p.into_iter().map(&f).collect())
                .collect(),
        }
    }

    /// Spark `mapPartitions` (no shuffle).
    pub fn map_partitions<U>(self, f: impl Fn(Vec<T>) -> Vec<U>) -> Rdd<U> {
        Rdd {
            partitions: self.partitions.into_iter().map(f).collect(),
        }
    }

    /// Spark `collect` action.
    pub fn collect(self) -> Vec<T> {
        self.partitions.into_iter().flatten().collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.partitions.iter().flatten()
    }
}

fn key_partition<K: Hash>(k: &K, n: usize) -> usize {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    k.hash(&mut h);
    (h.finish() % n as u64) as usize
}

impl<K: Hash + Eq + Clone, V> Rdd<(K, V)> {
    /// Spark `aggregateByKey` with map-side combine.
    ///
    /// * `create` makes a combiner from the first value of a key;
    /// * `merge_value` folds another value into a combiner (map side);
    /// * `merge_combiners` folds combiners from different partitions
    ///   (reduce side, after the shuffle);
    /// * `combiner_bytes` sizes a combiner for shuffle accounting — only
    ///   combiners that change partition are charged to the cluster.
    pub fn aggregate_by_key<C>(
        self,
        n_partitions: usize,
        cluster: &mut SimCluster,
        account: &str,
        create: impl Fn(V) -> C,
        merge_value: impl Fn(&mut C, V),
        merge_combiners: impl Fn(&mut C, C),
        combiner_bytes: impl Fn(&K, &C) -> u64,
    ) -> (Rdd<(K, C)>, u64) {
        let n_out = n_partitions.max(1);
        // Map-side combine within each source partition.
        let mut shuffled_bytes = 0u64;
        let mut targets: Vec<HashMap<K, C>> = (0..n_out).map(|_| HashMap::new()).collect();
        for (src_idx, part) in self.partitions.into_iter().enumerate() {
            let mut local: HashMap<K, C> = HashMap::new();
            for (k, v) in part {
                match local.get_mut(&k) {
                    Some(c) => merge_value(c, v),
                    None => {
                        local.insert(k, create(v));
                    }
                }
            }
            // Shuffle: each combiner travels to its hash partition.
            for (k, c) in local {
                let dst = key_partition(&k, n_out);
                if dst != src_idx % n_out {
                    shuffled_bytes += combiner_bytes(&k, &c);
                }
                match targets[dst].get_mut(&k) {
                    Some(existing) => merge_combiners(existing, c),
                    None => {
                        targets[dst].insert(k, c);
                    }
                }
            }
        }
        cluster.charge_shuffle(account, shuffled_bytes);
        let rdd = Rdd {
            partitions: targets
                .into_iter()
                .map(|m| m.into_iter().collect())
                .collect(),
        };
        (rdd, shuffled_bytes)
    }
}

/// Spark broadcast variable: one read-only copy per node (the paper
/// broadcasts the decision-tree model, §5.3.1).
#[derive(Clone, Debug)]
pub struct Broadcast<T> {
    value: Arc<T>,
}

impl<T> Broadcast<T> {
    pub fn new(cluster: &mut SimCluster, account: &str, value: T, bytes: u64) -> Broadcast<T> {
        cluster.charge_broadcast(account, bytes);
        Broadcast {
            value: Arc::new(value),
        }
    }

    pub fn get(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    #[test]
    fn from_vec_distributes_evenly() {
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 3);
        let sizes: Vec<usize> = r.partitions.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(r.n_items(), 10);
    }

    #[test]
    fn from_vec_more_partitions_than_items() {
        let r = Rdd::from_vec(vec![1, 2], 5);
        assert_eq!(r.n_partitions(), 5);
        assert_eq!(r.n_items(), 2);
    }

    #[test]
    fn coalesce_shrinks_rebalances_and_preserves_order() {
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 5).coalesce(2);
        assert_eq!(r.n_partitions(), 2);
        let sizes: Vec<usize> = r.partitions.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![5, 5]);
        assert_eq!(r.collect(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn coalesce_edge_cases() {
        // Target above current count: no-op.
        let r = Rdd::from_vec((0..4).collect::<Vec<_>>(), 2).coalesce(9);
        assert_eq!(r.n_partitions(), 2);
        // Zero target clamps to one partition.
        let r = Rdd::from_vec((0..4).collect::<Vec<_>>(), 4).coalesce(0);
        assert_eq!(r.n_partitions(), 1);
        assert_eq!(r.collect(), (0..4).collect::<Vec<_>>());
        // Empty RDD coalesces without panicking.
        let r = Rdd::from_vec(Vec::<u8>::new(), 6).coalesce(2);
        assert_eq!(r.n_partitions(), 2);
        assert_eq!(r.n_items(), 0);
    }

    #[test]
    fn map_preserves_partitioning() {
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 3).map(|x| x * 2);
        assert_eq!(r.n_partitions(), 3);
        assert_eq!(r.collect(), (0..10).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn aggregate_by_key_groups_all_values() {
        let items: Vec<(u32, u32)> = (0..100).map(|i| (i % 7, i)).collect();
        let r = Rdd::from_vec(items, 4);
        let mut cluster = SimCluster::new(ClusterSpec::lncc());
        let (grouped, bytes) = r.aggregate_by_key(
            4,
            &mut cluster,
            "shuffle",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_k, c| (c.len() * 4) as u64,
        );
        let mut all: Vec<(u32, Vec<u32>)> = grouped.collect();
        all.sort();
        assert_eq!(all.len(), 7);
        let total: usize = all.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 100);
        for (k, vs) in &all {
            assert!(vs.iter().all(|v| v % 7 == *k));
        }
        assert!(bytes > 0);
        assert!(cluster.account("shuffle") > 0.0);
    }

    #[test]
    fn aggregate_by_key_same_key_lands_in_one_partition() {
        let items: Vec<(u8, u8)> = (0..50).map(|i| (i % 5, i)).collect();
        let mut cluster = SimCluster::new(ClusterSpec::lncc());
        let (grouped, _) = Rdd::from_vec(items, 8).aggregate_by_key(
            8,
            &mut cluster,
            "s",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_, _| 1,
        );
        // No key may appear in two partitions.
        let mut seen = std::collections::HashSet::new();
        for part in &grouped.partitions {
            let keys: std::collections::HashSet<u8> = part.iter().map(|(k, _)| *k).collect();
            for k in keys {
                assert!(seen.insert(k), "key {k} in two partitions");
            }
        }
    }

    #[test]
    fn map_side_combine_reduces_shuffle() {
        // All values share one key: combine collapses each partition to a
        // single combiner before the shuffle.
        let items: Vec<(u8, u64)> = (0..1000).map(|i| (0u8, i)).collect();
        let mut cluster = SimCluster::new(ClusterSpec::lncc());
        let (_, bytes) = Rdd::from_vec(items, 4).aggregate_by_key(
            4,
            &mut cluster,
            "s",
            |_v| 1u64,          // combiner = count
            |c, _v| *c += 1,
            |c, o| *c += o,
            |_k, _c| 8,
        );
        // At most 4 combiners cross partitions (one per source partition),
        // not 1000 values.
        assert!(bytes <= 4 * 8, "bytes={bytes}");
    }

    #[test]
    fn broadcast_provides_value_and_charges() {
        let mut cluster = SimCluster::new(ClusterSpec::g5k(16));
        let b = Broadcast::new(&mut cluster, "bcast", vec![1, 2, 3], 12);
        assert_eq!(b.get(), &vec![1, 2, 3]);
        assert!(cluster.account("bcast") > 0.0);
    }
}
