//! Mini-RDD: lazily evaluated partitioned collections with the Spark
//! operations the paper's pipeline uses (map, aggregateByKey, coalesce,
//! broadcast) and shuffle-byte accounting wired into the simulated
//! cluster.
//!
//! Transformations (`map`, `map_partitions`, `coalesce`) build a small
//! plan: each partition is a deferred thunk, and every narrow op wraps
//! the thunk of its parent partition — narrow stages fuse into one pass
//! per partition, exactly like Spark pipelining inside a stage. Nothing
//! runs until an **action** (`collect`, `count`, `aggregate_by_key`)
//! submits one task per partition to a driver [`Executor`]; results come
//! back in deterministic partition order at any thread count. Wide
//! operations (`aggregate_by_key`) run as two stages — a parallel
//! map-side combine that routes combiners to hash partitions, then a
//! parallel reduce that merges each target's inbox in source-partition
//! order — with the crossing bytes charged to the [`SimCluster`].

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use crate::cluster::SimCluster;
use crate::executor::Executor;

/// A deferred partition: evaluates to the partition's items when its
/// task runs.
type PartitionFn<T> = Box<dyn FnOnce() -> Vec<T> + Send>;

/// A lazily evaluated partitioned collection. Partition `i` is
/// conceptually resident on node `i % nodes`.
pub struct Rdd<T> {
    parts: Vec<PartitionFn<T>>,
}

impl<T: Send + 'static> Rdd<T> {
    /// Evenly distribute items over `n_partitions` (paper: "the
    /// identifications of points are stored in an RDD, which is evenly
    /// distributed on multiple cluster nodes").
    pub fn from_vec(items: Vec<T>, n_partitions: usize) -> Rdd<T> {
        let n_partitions = n_partitions.max(1);
        let n = items.len();
        let base = n / n_partitions;
        let extra = n % n_partitions;
        let mut partitions = Vec::with_capacity(n_partitions);
        let mut it = items.into_iter();
        for p in 0..n_partitions {
            let take = base + usize::from(p < extra);
            partitions.push(it.by_ref().take(take).collect());
        }
        Self::from_partitions(partitions)
    }

    /// Wrap already-materialized partitions (a shuffle output).
    pub fn from_partitions(partitions: Vec<Vec<T>>) -> Rdd<T> {
        Rdd {
            parts: partitions
                .into_iter()
                .map(|p| Box::new(move || p) as PartitionFn<T>)
                .collect(),
        }
    }

    pub fn n_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Spark `map` (narrow: fuses into the partition task, no shuffle).
    pub fn map<U, F>(self, f: F) -> Rdd<U>
    where
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        Rdd {
            parts: self
                .parts
                .into_iter()
                .map(|p| {
                    let f = Arc::clone(&f);
                    Box::new(move || p().into_iter().map(|t| (*f)(t)).collect())
                        as PartitionFn<U>
                })
                .collect(),
        }
    }

    /// Spark `mapPartitions` (narrow, no shuffle).
    pub fn map_partitions<U, F>(self, f: F) -> Rdd<U>
    where
        U: Send + 'static,
        F: Fn(Vec<T>) -> Vec<U> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        Rdd {
            parts: self
                .parts
                .into_iter()
                .map(|p| {
                    let f = Arc::clone(&f);
                    Box::new(move || (*f)(p())) as PartitionFn<U>
                })
                .collect(),
        }
    }

    /// Spark `coalesce`: shrink to at most `n_partitions` partitions by
    /// merging contiguous runs of source partitions (no shuffle, item
    /// order preserved — Spark's adjacent-merge semantics). A target at
    /// or above the current count is a no-op; `0` clamps to 1.
    pub fn coalesce(self, n_partitions: usize) -> Rdd<T> {
        let n_out = n_partitions.max(1);
        let n_in = self.parts.len();
        if n_out >= n_in {
            return self;
        }
        let base = n_in / n_out;
        let extra = n_in % n_out;
        let mut it = self.parts.into_iter();
        let mut merged = Vec::with_capacity(n_out);
        for g in 0..n_out {
            let take = base + usize::from(g < extra);
            let group: Vec<PartitionFn<T>> = it.by_ref().take(take).collect();
            merged.push(Box::new(move || {
                let mut out = Vec::new();
                for p in group {
                    out.extend(p());
                }
                out
            }) as PartitionFn<T>);
        }
        Rdd { parts: merged }
    }

    /// Spark `collect` action: evaluate every partition as an executor
    /// task, concatenate in partition order.
    pub fn collect(self, exec: &Executor) -> Vec<T> {
        self.collect_partitions(exec).into_iter().flatten().collect()
    }

    /// Evaluate and return the partitions themselves (tests and shuffle
    /// consumers that care about placement).
    pub fn collect_partitions(self, exec: &Executor) -> Vec<Vec<T>> {
        exec.run(self.parts, |p| p())
    }

    /// Spark `count` action.
    pub fn count(self, exec: &Executor) -> usize {
        exec.run(self.parts, |p| p().len()).into_iter().sum()
    }
}

fn key_partition<K: Hash>(k: &K, n: usize) -> usize {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    k.hash(&mut h);
    (h.finish() % n as u64) as usize
}

impl<K: Hash + Eq + Send + 'static, V: Send + 'static> Rdd<(K, V)> {
    /// Spark `aggregateByKey` with map-side combine — the wide action.
    ///
    /// * `create` makes a combiner from the first value of a key;
    /// * `merge_value` folds another value into a combiner (map side);
    /// * `merge_combiners` folds combiners from different partitions
    ///   (reduce side, after the shuffle);
    /// * `combiner_bytes` sizes a combiner for shuffle accounting — only
    ///   combiners that change partition are charged to the cluster.
    ///
    /// Stage 1 runs one task per source partition (combine + route);
    /// stage 2 runs one task per target partition, merging its inbox in
    /// source-partition order — so the result and the charged bytes are
    /// identical at any executor thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn aggregate_by_key<C: Send + 'static>(
        self,
        n_partitions: usize,
        exec: &Executor,
        cluster: &SimCluster,
        account: &str,
        create: impl Fn(V) -> C + Sync,
        merge_value: impl Fn(&mut C, V) + Sync,
        merge_combiners: impl Fn(&mut C, C) + Sync,
        combiner_bytes: impl Fn(&K, &C) -> u64 + Sync,
    ) -> (Rdd<(K, C)>, u64) {
        let n_out = n_partitions.max(1);
        // Stage 1 (map side): combine within each source partition, then
        // route each combiner to its hash partition.
        let tasks: Vec<(usize, PartitionFn<(K, V)>)> =
            self.parts.into_iter().enumerate().collect();
        let routed: Vec<(Vec<Vec<(K, C)>>, u64)> = exec.run(tasks, |(src_idx, part)| {
            let mut local: HashMap<K, C> = HashMap::new();
            for (k, v) in part() {
                match local.get_mut(&k) {
                    Some(c) => merge_value(c, v),
                    None => {
                        local.insert(k, create(v));
                    }
                }
            }
            let mut outgoing: Vec<Vec<(K, C)>> = (0..n_out).map(|_| Vec::new()).collect();
            let mut bytes = 0u64;
            for (k, c) in local {
                let dst = key_partition(&k, n_out);
                if dst != src_idx % n_out {
                    bytes += combiner_bytes(&k, &c);
                }
                outgoing[dst].push((k, c));
            }
            (outgoing, bytes)
        });
        // Exchange: concatenate each target's inbox in source order (the
        // deterministic merge order for non-commutative combiners).
        let mut shuffled_bytes = 0u64;
        let mut inboxes: Vec<Vec<(K, C)>> = (0..n_out).map(|_| Vec::new()).collect();
        for (outgoing, bytes) in routed {
            shuffled_bytes += bytes;
            for (dst, batch) in outgoing.into_iter().enumerate() {
                inboxes[dst].extend(batch);
            }
        }
        cluster.charge_shuffle(account, shuffled_bytes);
        // Stage 2 (reduce side): merge combiners per target partition.
        let targets: Vec<Vec<(K, C)>> = exec.run(inboxes, |inbox| {
            let mut m: HashMap<K, C> = HashMap::new();
            for (k, c) in inbox {
                match m.get_mut(&k) {
                    Some(existing) => merge_combiners(existing, c),
                    None => {
                        m.insert(k, c);
                    }
                }
            }
            m.into_iter().collect()
        });
        (Rdd::from_partitions(targets), shuffled_bytes)
    }
}

/// Spark broadcast variable: one read-only copy per node (the paper
/// broadcasts the decision-tree model, §5.3.1).
#[derive(Clone, Debug)]
pub struct Broadcast<T> {
    value: Arc<T>,
}

impl<T> Broadcast<T> {
    pub fn new(cluster: &SimCluster, account: &str, value: T, bytes: u64) -> Broadcast<T> {
        cluster.charge_broadcast(account, bytes);
        Broadcast {
            value: Arc::new(value),
        }
    }

    pub fn get(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn exec() -> Executor {
        Executor::new(4)
    }

    #[test]
    fn from_vec_distributes_evenly() {
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 3);
        assert_eq!(r.n_partitions(), 3);
        let parts = r.collect_partitions(&exec());
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(parts.into_iter().flatten().count(), 10);
    }

    #[test]
    fn from_vec_more_partitions_than_items() {
        let r = Rdd::from_vec(vec![1, 2], 5);
        assert_eq!(r.n_partitions(), 5);
        assert_eq!(r.count(&exec()), 2);
    }

    #[test]
    fn coalesce_merges_adjacent_and_preserves_order() {
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 5).coalesce(2);
        assert_eq!(r.n_partitions(), 2);
        let parts = r.collect_partitions(&exec());
        // 5 source partitions of 2 items merge as contiguous runs [3, 2].
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![6, 4]);
        let flat: Vec<i32> = parts.into_iter().flatten().collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn coalesce_edge_cases() {
        // Target above current count: no-op.
        let r = Rdd::from_vec((0..4).collect::<Vec<_>>(), 2).coalesce(9);
        assert_eq!(r.n_partitions(), 2);
        // Zero target clamps to one partition.
        let r = Rdd::from_vec((0..4).collect::<Vec<_>>(), 4).coalesce(0);
        assert_eq!(r.n_partitions(), 1);
        assert_eq!(r.collect(&exec()), (0..4).collect::<Vec<_>>());
        // Empty RDD coalesces without panicking.
        let r = Rdd::from_vec(Vec::<u8>::new(), 6).coalesce(2);
        assert_eq!(r.n_partitions(), 2);
        assert_eq!(r.count(&exec()), 0);
    }

    #[test]
    fn map_is_lazy_and_preserves_partitioning() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let r = Rdd::from_vec((0..10).collect::<Vec<_>>(), 3).map(|x| {
            CALLS.fetch_add(1, Ordering::Relaxed);
            x * 2
        });
        // Plan built, nothing evaluated yet.
        assert_eq!(CALLS.load(Ordering::Relaxed), 0);
        assert_eq!(r.n_partitions(), 3);
        assert_eq!(r.collect(&exec()), (0..10).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(CALLS.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn narrow_stages_fuse_per_partition() {
        // map → map_partitions → coalesce chains stay one thunk deep per
        // output partition and evaluate in one pass at the action.
        let r = Rdd::from_vec((0..100u32).collect::<Vec<_>>(), 8)
            .map(|x| x + 1)
            .map_partitions(|p| p.into_iter().filter(|x| x % 2 == 0).collect())
            .coalesce(3);
        assert_eq!(r.n_partitions(), 3);
        let got = r.collect(&exec());
        let want: Vec<u32> = (1..=100).filter(|x| x % 2 == 0).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn aggregate_by_key_groups_all_values() {
        let items: Vec<(u32, u32)> = (0..100).map(|i| (i % 7, i)).collect();
        let r = Rdd::from_vec(items, 4);
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let (grouped, bytes) = r.aggregate_by_key(
            4,
            &exec(),
            &cluster,
            "shuffle",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_k, c| (c.len() * 4) as u64,
        );
        let mut all: Vec<(u32, Vec<u32>)> = grouped.collect(&exec());
        all.sort();
        assert_eq!(all.len(), 7);
        let total: usize = all.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 100);
        for (k, vs) in &all {
            assert!(vs.iter().all(|v| v % 7 == *k));
        }
        assert!(bytes > 0);
        assert!(cluster.account("shuffle") > 0.0);
    }

    #[test]
    fn aggregate_by_key_same_key_lands_in_one_partition() {
        let items: Vec<(u8, u8)> = (0..50).map(|i| (i % 5, i)).collect();
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let (grouped, _) = Rdd::from_vec(items, 8).aggregate_by_key(
            8,
            &exec(),
            &cluster,
            "s",
            |v| vec![v],
            |c, v| c.push(v),
            |c, mut o| c.append(&mut o),
            |_, _| 1,
        );
        // No key may appear in two partitions.
        let mut seen = std::collections::HashSet::new();
        for part in grouped.collect_partitions(&exec()) {
            let keys: std::collections::HashSet<u8> = part.iter().map(|(k, _)| *k).collect();
            for k in keys {
                assert!(seen.insert(k), "key {k} in two partitions");
            }
        }
    }

    #[test]
    fn map_side_combine_reduces_shuffle() {
        // All values share one key: combine collapses each partition to a
        // single combiner before the shuffle.
        let items: Vec<(u8, u64)> = (0..1000).map(|i| (0u8, i)).collect();
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let (_, bytes) = Rdd::from_vec(items, 4).aggregate_by_key(
            4,
            &exec(),
            &cluster,
            "s",
            |_v| 1u64, // combiner = count
            |c, _v| *c += 1,
            |c, o| *c += o,
            |_k, _c| 8,
        );
        // At most 4 combiners cross partitions (one per source partition),
        // not 1000 values.
        assert!(bytes <= 4 * 8, "bytes={bytes}");
    }

    #[test]
    fn aggregate_by_key_invariant_across_thread_counts() {
        let items: Vec<(u32, u64)> = (0..400).map(|i| (i % 13, i as u64)).collect();
        let run = |threads: usize| {
            let exec = Executor::new(threads);
            let cluster = SimCluster::new(ClusterSpec::lncc());
            let (grouped, bytes) = Rdd::from_vec(items.clone(), 6).aggregate_by_key(
                6,
                &exec,
                &cluster,
                "s",
                |v| vec![v],
                |c, v| c.push(v),
                |c, mut o| c.append(&mut o),
                |_, c| 8 * c.len() as u64,
            );
            let mut all: Vec<(u32, Vec<u64>)> = grouped.collect(&exec);
            all.sort();
            (all, bytes, cluster.account("s").to_bits())
        };
        let base = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn broadcast_provides_value_and_charges() {
        let cluster = SimCluster::new(ClusterSpec::g5k(16));
        let b = Broadcast::new(&cluster, "bcast", vec![1, 2, 3], 12);
        assert_eq!(b.get(), &vec![1, 2, 3]);
        assert!(cluster.account("bcast") > 0.0);
    }
}
