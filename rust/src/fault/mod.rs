//! Deterministic fault injection and the shared retry/backoff policy.
//!
//! A *failpoint* is a named site on a durability-critical I/O path
//! (`segment.read`, `catalog.save`, ...). When the process is armed
//! with a fault spec — via the `PDFFLOW_FAULTS` environment variable or
//! the `faults.spec` config key — each site consults its clause and may
//! inject a transient I/O error ([`check`]) or flip one byte of a
//! buffer in flight ([`mangle`]). Triggers draw from a seeded
//! per-failpoint PRNG stream, so a given spec replays the exact same
//! fault sequence on every run: the torture suite
//! (`tests/fault_torture.rs`) depends on this determinism.
//!
//! When no spec is armed the entire subsystem compiles down to one
//! relaxed atomic load per hook — the same discipline as the telemetry
//! span gate — so production paths pay nothing for carrying the hooks.
//!
//! # Spec grammar
//!
//! Comma-separated clauses:
//!
//! ```text
//! seed=<u64>                      PRNG seed (default 0)
//! retry=<attempts>:<backoff_ms>   override the retry policy
//! <site>=<kind>[:<prob>[:<max>]]  arm a failpoint
//! ```
//!
//! `kind` is `io` (inject a transient error) or `corrupt` (flip one
//! byte); `prob` is the per-visit trigger probability (default 1.0);
//! `max` caps the total number of firings (default unlimited).
//! Example: `seed=7,segment.read=io:0.5:3,catalog.save=corrupt`.
//!
//! # Retry policy
//!
//! [`retry`] wraps an I/O closure and re-runs it on transient errors
//! ([`crate::PdfflowError::is_transient`]) with bounded exponential
//! backoff. The policy comes from the armed spec's `retry=` clause,
//! else `PDFFLOW_RETRY_ATTEMPTS` / `PDFFLOW_RETRY_BACKOFF_MS`, else 3
//! attempts starting at 10 ms. Each re-run increments
//! `io.retry.attempts`; giving up increments `io.retry.exhausted` and
//! drops a flight-recorder mark.

use std::sync::atomic::{AtomicU8, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::telemetry::{self, Registry};
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

/// Counter bumped once per injected fault (both kinds).
pub const INJECTED: &str = "fault.injected";
/// Counter bumped once per transient-error re-run inside [`retry`].
pub const RETRY_ATTEMPTS: &str = "io.retry.attempts";
/// Counter bumped when [`retry`] gives up on a transient error.
pub const RETRY_EXHAUSTED: &str = "io.retry.exhausted";

/// Ceiling on a single backoff sleep, keeping worst-case retry latency
/// bounded no matter how the knobs are set.
const MAX_BACKOFF_MS: u64 = 250;

/// What an armed failpoint injects when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    /// A transient I/O error (`ErrorKind::Interrupted`).
    Io,
    /// One flipped byte in the buffer passing through [`mangle`].
    Corrupt,
}

#[derive(Debug)]
struct Failpoint {
    site: String,
    kind: Kind,
    prob: f64,
    /// Remaining firings; `None` = unlimited.
    remaining: Option<u64>,
    rng: Rng,
}

impl Failpoint {
    fn fire(&mut self) -> bool {
        if self.remaining == Some(0) {
            return false;
        }
        // Always consume one draw so the stream position depends only
        // on the visit count, not on earlier outcomes.
        let roll = self.rng.f64();
        let hit = self.prob >= 1.0 || roll < self.prob;
        if hit {
            if let Some(n) = &mut self.remaining {
                *n -= 1;
            }
        }
        hit
    }
}

/// Bounded-backoff retry knobs used by [`retry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total tries, including the first (clamped to ≥ 1).
    pub attempts: u32,
    /// First backoff sleep; doubles per retry, capped at 250 ms.
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 3, backoff_ms: 10 }
    }
}

#[derive(Debug)]
struct Plan {
    points: Vec<Failpoint>,
    retry: Option<RetryPolicy>,
}

/// 0 = unresolved (env not consulted yet), 1 = idle, 2 = armed.
static STATE: AtomicU8 = AtomicU8::new(0);
static PLAN: Mutex<Option<Plan>> = Mutex::new(None);
static ENV_POLICY: OnceLock<RetryPolicy> = OnceLock::new();

/// Whether any fault spec is armed. One relaxed load on the hot path;
/// the first call resolves `PDFFLOW_FAULTS` from the environment.
#[inline]
pub fn active() -> bool {
    match STATE.load(Relaxed) {
        1 => false,
        2 => true,
        _ => resolve_env(),
    }
}

#[cold]
fn resolve_env() -> bool {
    match std::env::var("PDFFLOW_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => match install(&spec) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("pdfflow: ignoring PDFFLOW_FAULTS: {e}");
                clear();
                false
            }
        },
        _ => {
            STATE.store(1, Relaxed);
            false
        }
    }
}

/// Parse `spec` and arm it process-wide, replacing any prior plan.
pub fn install(spec: &str) -> Result<()> {
    let plan = parse(spec)?;
    *PLAN.lock().unwrap() = Some(plan);
    STATE.store(2, Relaxed);
    Ok(())
}

/// Disarm all failpoints (tests call this between scenarios).
pub fn clear() {
    *PLAN.lock().unwrap() = None;
    STATE.store(1, Relaxed);
}

fn parse(spec: &str) -> Result<Plan> {
    fn bad(clause: &str, why: &str) -> PdfflowError {
        PdfflowError::Config(format!("fault spec clause {clause:?}: {why}"))
    }
    let clauses = || spec.split(',').map(str::trim).filter(|c| !c.is_empty());
    // Pass 1: the seed, so failpoint streams don't depend on where the
    // seed= clause sits relative to the site clauses.
    let mut seed = 0u64;
    for clause in clauses() {
        if let Some(v) = clause.strip_prefix("seed=") {
            seed = v.parse().map_err(|_| bad(clause, "seed must be a u64"))?;
        }
    }
    let root = Rng::new(seed ^ 0x5eed_fa17_5eed_fa17);
    let mut points: Vec<Failpoint> = Vec::new();
    let mut retry = None;
    for clause in clauses() {
        let Some((key, val)) = clause.split_once('=') else {
            return Err(bad(clause, "expected key=value"));
        };
        match key {
            "seed" => {}
            "retry" => {
                let (a, b) = val
                    .split_once(':')
                    .ok_or_else(|| bad(clause, "expected retry=attempts:backoff_ms"))?;
                retry = Some(RetryPolicy {
                    attempts: a.parse().map_err(|_| bad(clause, "attempts must be a u32"))?,
                    backoff_ms: b.parse().map_err(|_| bad(clause, "backoff_ms must be a u64"))?,
                });
            }
            site => {
                let mut it = val.split(':');
                let kind = match it.next().unwrap_or("") {
                    "io" => Kind::Io,
                    "corrupt" => Kind::Corrupt,
                    other => return Err(bad(clause, &format!("unknown kind {other:?} (want io|corrupt)"))),
                };
                let prob = match it.next() {
                    None | Some("") => 1.0,
                    Some(p) => {
                        let p: f64 = p.parse().map_err(|_| bad(clause, "prob must be a float"))?;
                        if !(0.0..=1.0).contains(&p) {
                            return Err(bad(clause, "prob must be in [0, 1]"));
                        }
                        p
                    }
                };
                let remaining = match it.next() {
                    None | Some("") => None,
                    Some(m) => Some(m.parse().map_err(|_| bad(clause, "max must be a u64"))?),
                };
                if it.next().is_some() {
                    return Err(bad(clause, "too many ':' fields (kind[:prob[:max]])"));
                }
                let stream = points.len() as u64;
                points.push(Failpoint {
                    site: site.to_string(),
                    kind,
                    prob,
                    remaining,
                    rng: root.fork(stream),
                });
            }
        }
    }
    Ok(Plan { points, retry })
}

/// Failpoint hook for error injection. Idle: one relaxed load. Armed
/// with an `io` clause for `site` that fires: returns a transient
/// `Io(Interrupted)` error, bumps `fault.injected`, and marks the
/// flight recorder.
#[inline]
pub fn check(site: &'static str) -> Result<()> {
    if !active() {
        return Ok(());
    }
    check_armed(site)
}

#[cold]
fn check_armed(site: &'static str) -> Result<()> {
    {
        let mut plan = PLAN.lock().unwrap();
        let Some(p) = plan
            .as_mut()
            .and_then(|p| p.points.iter_mut().find(|p| p.kind == Kind::Io && p.site == site))
        else {
            return Ok(());
        };
        if !p.fire() {
            return Ok(());
        }
    }
    Registry::global().counter(INJECTED).inc();
    telemetry::mark("fault.injected", || format!("io fault at {site}"));
    Err(PdfflowError::Io(std::io::Error::new(
        std::io::ErrorKind::Interrupted,
        format!("injected fault at {site}"),
    )))
}

/// Failpoint hook for data corruption. Idle: one relaxed load. Armed
/// with a `corrupt` clause for `site` that fires: flips one
/// deterministically chosen byte of `buf` in place and returns `true`.
///
/// Callers on write paths must hash the *original* bytes before
/// mangling, so injected write corruption stays detectable downstream
/// instead of being checksummed into truth.
#[inline]
pub fn mangle(site: &'static str, buf: &mut [u8]) -> bool {
    if !active() || buf.is_empty() {
        return false;
    }
    mangle_armed(site, buf)
}

#[cold]
fn mangle_armed(site: &'static str, buf: &mut [u8]) -> bool {
    let at = {
        let mut plan = PLAN.lock().unwrap();
        let Some(p) = plan
            .as_mut()
            .and_then(|p| p.points.iter_mut().find(|p| p.kind == Kind::Corrupt && p.site == site))
        else {
            return false;
        };
        if !p.fire() {
            return false;
        }
        p.rng.below(buf.len())
    };
    buf[at] ^= 0x40;
    Registry::global().counter(INJECTED).inc();
    telemetry::mark("fault.injected", || format!("corrupt fault at {site}, byte {at}"));
    true
}

/// The effective retry policy: armed spec's `retry=` clause, else the
/// `PDFFLOW_RETRY_*` environment knobs, else the default (3 × 10 ms).
pub fn policy() -> RetryPolicy {
    if STATE.load(Relaxed) == 2 {
        if let Some(p) = PLAN.lock().unwrap().as_ref().and_then(|p| p.retry) {
            return p;
        }
    }
    *ENV_POLICY.get_or_init(|| {
        let env_u64 = |key: &str| std::env::var(key).ok().and_then(|v| v.parse::<u64>().ok());
        RetryPolicy {
            attempts: env_u64("PDFFLOW_RETRY_ATTEMPTS").unwrap_or(3).max(1) as u32,
            backoff_ms: env_u64("PDFFLOW_RETRY_BACKOFF_MS").unwrap_or(10),
        }
    })
}

/// Run `f`, re-running it on transient errors with bounded exponential
/// backoff per [`policy`]. Permanent errors return immediately; a
/// transient error on the last attempt bumps `io.retry.exhausted`,
/// marks the flight recorder, and is returned.
pub fn retry<T>(op: &'static str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
    let pol = policy();
    let attempts = pol.attempts.max(1);
    let mut delay_ms = pol.backoff_ms.min(MAX_BACKOFF_MS);
    for attempt in 1..=attempts {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient() => {
                if attempt == attempts {
                    Registry::global().counter(RETRY_EXHAUSTED).inc();
                    telemetry::mark("io.retry.exhausted", || {
                        format!("{op}: gave up after {attempts} attempts: {e}")
                    });
                    return Err(e);
                }
                Registry::global().counter(RETRY_ATTEMPTS).inc();
                if delay_ms > 0 {
                    std::thread::sleep(Duration::from_millis(delay_ms));
                }
                delay_ms = (delay_ms * 2).min(MAX_BACKOFF_MS);
            }
            Err(e) => return Err(e),
        }
    }
    unreachable!("retry returns within its attempts")
}

/// Eagerly create the fault/retry/quarantine counter families so they
/// export (as zeros) even on runs where nothing went wrong — the CI
/// telemetry smoke greps for them unconditionally.
pub fn register_metrics() {
    let r = Registry::global();
    for name in [INJECTED, RETRY_ATTEMPTS, RETRY_EXHAUSTED, crate::pdfstore::QUARANTINED] {
        let _ = r.counter(name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fault state is process-global and lib tests run in parallel, so
    /// every test that installs a plan serializes here and uses
    /// `test.*` site names no real I/O path consults.
    static LOCK: Mutex<()> = Mutex::new(());

    fn counter(name: &str) -> u64 {
        Registry::global().counter(name).get()
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for spec in [
            "nonsense",
            "seed=abc",
            "x=badkind",
            "x=io:2.0",
            "x=io:0.5:1:extra",
            "retry=3",
            "retry=a:b",
        ] {
            assert!(parse(spec).is_err(), "spec {spec:?} should be rejected");
        }
    }

    #[test]
    fn parse_accepts_full_grammar() {
        let p = parse("seed=7, segment.read=io:0.5:3 ,catalog.save=corrupt,retry=5:0").unwrap();
        assert_eq!(p.points.len(), 2);
        assert_eq!(p.points[0].site, "segment.read");
        assert_eq!(p.points[0].kind, Kind::Io);
        assert_eq!(p.points[0].prob, 0.5);
        assert_eq!(p.points[0].remaining, Some(3));
        assert_eq!(p.points[1].kind, Kind::Corrupt);
        assert_eq!(p.points[1].prob, 1.0);
        assert_eq!(p.points[1].remaining, None);
        assert_eq!(p.retry, Some(RetryPolicy { attempts: 5, backoff_ms: 0 }));
    }

    #[test]
    fn idle_hooks_are_no_ops() {
        let _g = LOCK.lock().unwrap();
        clear();
        assert!(check("test.idle").is_ok());
        let mut buf = [1u8, 2, 3];
        assert!(!mangle("test.idle", &mut buf));
        assert_eq!(buf, [1, 2, 3]);
    }

    #[test]
    fn max_caps_the_number_of_firings() {
        let _g = LOCK.lock().unwrap();
        install("seed=1,test.capped=io:1:2").unwrap();
        let before = counter(INJECTED);
        let fired = (0..10).filter(|_| check("test.capped").is_err()).count();
        clear();
        assert_eq!(fired, 2);
        assert_eq!(counter(INJECTED) - before, 2);
    }

    #[test]
    fn zero_probability_never_fires_and_other_sites_pass() {
        let _g = LOCK.lock().unwrap();
        install("seed=3,test.never=io:0").unwrap();
        for _ in 0..50 {
            assert!(check("test.never").is_ok());
            assert!(check("test.other").is_ok());
        }
        clear();
    }

    #[test]
    fn trigger_sequence_is_deterministic_for_a_seed() {
        let _g = LOCK.lock().unwrap();
        let run = || {
            install("seed=42,test.seq=io:0.3").unwrap();
            let hits: Vec<bool> = (0..64).map(|_| check("test.seq").is_err()).collect();
            clear();
            hits
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.iter().any(|&h| h), "prob 0.3 over 64 visits should fire");
        assert!(a.iter().any(|&h| !h), "prob 0.3 over 64 visits should also pass");
    }

    #[test]
    fn mangle_flips_exactly_one_byte() {
        let _g = LOCK.lock().unwrap();
        install("seed=5,test.buf=corrupt:1:1").unwrap();
        let orig: Vec<u8> = (0..128).collect();
        let mut buf = orig.clone();
        assert!(mangle("test.buf", &mut buf));
        let diffs: Vec<usize> = (0..orig.len()).filter(|&i| orig[i] != buf[i]).collect();
        assert_eq!(diffs.len(), 1);
        assert_eq!(orig[diffs[0]] ^ buf[diffs[0]], 0x40);
        // The max=1 cap is spent; a second visit leaves the buffer alone.
        let mut again = orig.clone();
        assert!(!mangle("test.buf", &mut again));
        assert_eq!(again, orig);
        clear();
    }

    #[test]
    fn retry_recovers_from_transient_errors_and_counts() {
        let _g = LOCK.lock().unwrap();
        install("retry=4:0").unwrap();
        let before = counter(RETRY_ATTEMPTS);
        let mut failures = 2;
        let out = retry("test.retry", || {
            if failures > 0 {
                failures -= 1;
                Err(PdfflowError::Io(std::io::Error::from(std::io::ErrorKind::Interrupted)))
            } else {
                Ok(7u32)
            }
        });
        clear();
        assert_eq!(out.unwrap(), 7);
        assert_eq!(counter(RETRY_ATTEMPTS) - before, 2);
    }

    #[test]
    fn retry_does_not_retry_permanent_errors() {
        let _g = LOCK.lock().unwrap();
        install("retry=5:0").unwrap();
        let mut calls = 0;
        let out: Result<()> = retry("test.perm", || {
            calls += 1;
            Err(PdfflowError::Format("permanent".into()))
        });
        clear();
        assert!(matches!(out, Err(PdfflowError::Format(_))));
        assert_eq!(calls, 1);
    }

    #[test]
    fn retry_exhaustion_is_counted() {
        let _g = LOCK.lock().unwrap();
        install("retry=3:0").unwrap();
        let before = counter(RETRY_EXHAUSTED);
        let mut calls = 0;
        let out: Result<()> = retry("test.exhaust", || {
            calls += 1;
            Err(PdfflowError::Io(std::io::Error::from(std::io::ErrorKind::Interrupted)))
        });
        clear();
        assert!(out.is_err());
        assert_eq!(calls, 3);
        assert_eq!(counter(RETRY_EXHAUSTED) - before, 1);
    }
}
