//! Typed experiment configuration, loadable from TOML-subset files
//! (`configs/*.toml`) with programmatic presets matching the paper's
//! three datasets and two testbeds.

use std::path::Path;

use crate::cluster::ClusterSpec;
use crate::cube::CubeDims;
use crate::datagen::DatasetSpec;
use crate::runtime::{self, Backend, BackendKind, BackendOptions};
use crate::util::toml::TomlDoc;
use crate::{PdfflowError, Result};

/// Pipeline knobs (paper §4.2/§4.3).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Lines per sliding window for PDF computation.
    pub window_lines: usize,
    /// Eq. 5 interval count.
    pub bins: usize,
    /// Point batch per PJRT execute (must match an artifact batch).
    pub batch: usize,
    /// RDD partitions (defaults to cluster slot count at run time).
    pub partitions: Option<usize>,
    /// Window-cache budget in bytes (§4.3.1).
    pub cache_bytes: u64,
    /// Quantization step for grouping keys on (mean, std) (§5.2: "points
    /// with exactly the same mean and standard deviation"; f32 results
    /// need an epsilon grid).
    pub group_quantum: f64,
    /// **The single host thread budget**: total size of the shared
    /// [`crate::runtime::hostpool`] every layer (executor stages,
    /// backend chunk fan-out, query fan-out) draws from. `None` leaves
    /// the pool at its default (`PDFFLOW_THREADS` env > all host
    /// cores). Applied at startup via `hostpool::configure`; the pool
    /// is process-wide, so the first configured value wins. Precedence:
    /// `--host-threads` CLI flag > `pipeline.host_threads` config key >
    /// `PDFFLOW_THREADS` env > cores.
    pub host_threads: Option<usize>,
    /// Width cap on the backend's chunk fan-out within the shared
    /// budget (not a thread count — nothing spawns per call anymore).
    pub workers: usize,
    /// Driver executor width: how many windows (and RDD partition tasks)
    /// may be in flight at once. Results are thread-count invariant —
    /// this knob only trades wall-clock for cores. Like `workers` it is
    /// a width cap on the one shared pool budget: raising both can no
    /// longer oversubscribe the host. Precedence: `--executor-threads`
    /// CLI flag > `pipeline.executor_threads` config key >
    /// `PDFFLOW_EXECUTOR_THREADS` env > all host cores.
    pub executor_threads: usize,
    /// When set, per-slice fit outcomes are persisted here (Algorithm 1
    /// line 11) as legacy flat `.pdfout` files.
    pub persist_dir: Option<String>,
    /// When set, fit outcomes stream into an indexed, queryable
    /// [`crate::pdfstore`] store at this directory (footer-indexed
    /// segments + generational run catalog).
    pub store_dir: Option<String>,
    /// Run id stamped into persisted segments alongside (method, types)
    /// — the rerun label the store's catalog keys generations by.
    /// `None` uses [`crate::pdfstore::DEFAULT_RUN_ID`]. Precedence:
    /// `--run-id` CLI flag > `pipeline.run_id` config key > default.
    pub run_id: Option<String>,
    /// Segment block-cache budget for the store's `QueryEngine`, bytes.
    pub query_cache_bytes: u64,
    /// Let the backend adapt its chunk width and fan-out between
    /// windows from the host pool's occupancy meters
    /// ([`crate::runtime::AdaptiveController`]); `batch`/`workers` stay
    /// the seed and clamp anchors. On by default — results are pinned
    /// bitwise width-invariant, so only scheduling granularity moves.
    /// Set `pipeline.adaptive_batch = false` to pin the fixed widths.
    pub adaptive_batch: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            window_lines: 25, // the paper's tuned optimum
            bins: 32,
            batch: 256,
            partitions: None,
            cache_bytes: 512 << 20,
            group_quantum: 1e-6,
            host_threads: None,
            workers: runtime::hostpool::default_budget(),
            executor_threads: crate::executor::default_threads(),
            persist_dir: None,
            store_dir: None,
            run_id: None,
            query_cache_bytes: 64 << 20,
            adaptive_batch: true,
        }
    }
}

/// A full experiment: dataset + cluster + pipeline + target slice.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetSpec,
    pub cluster: ClusterSpec,
    pub pipeline: PipelineConfig,
    /// Slice under analysis (the paper always uses Slice 201; scaled cubes
    /// use the proportional slice).
    pub slice: usize,
    /// Slice whose previously generated output trains the tree (paper:
    /// Slice 0).
    pub train_slice: usize,
    pub data_dir: String,
    pub artifacts_dir: String,
    /// Compute backend. Precedence: `--backend` CLI flag > `backend`
    /// config key > `PDFFLOW_BACKEND` env > native.
    pub backend: BackendKind,
    /// Fault-injection spec installed at startup (`faults.spec` config
    /// key; the `PDFFLOW_FAULTS` env takes precedence — see
    /// [`crate::fault`] for the grammar). `None` leaves injection idle.
    pub faults: Option<String>,
}

/// Backend default for programmatic constructors: the `PDFFLOW_BACKEND`
/// env override when readable, else native. (`preset`/`from_file`
/// additionally turn an unparseable env value into a hard error.)
fn default_backend() -> BackendKind {
    BackendKind::from_env().ok().flatten().unwrap_or(BackendKind::Native)
}

impl ExperimentConfig {
    /// Set1-analog on the LNCC-shaped cluster (the paper's §6.2 setup).
    pub fn set1() -> ExperimentConfig {
        let dataset = DatasetSpec::set1_analog();
        // Paper uses Slice 201 of 501 → proportional slice here.
        let slice = dataset.dims.nz * 201 / 501;
        ExperimentConfig {
            name: "set1".into(),
            dataset,
            cluster: ClusterSpec::lncc(),
            pipeline: PipelineConfig::default(),
            slice,
            train_slice: 0,
            data_dir: "data/set1".into(),
            artifacts_dir: "artifacts".into(),
            backend: default_backend(),
            faults: None,
        }
    }

    /// Set2-analog: bigger cube, same 1000 observations (paper §6.3.1).
    pub fn set2() -> ExperimentConfig {
        let mut c = Self::set1();
        c.name = "set2".into();
        c.dataset.dims = CubeDims::new(251, 128, 128);
        c.dataset.seed = 20180516;
        c.slice = c.dataset.dims.nz * 201 / 501;
        c.cluster = ClusterSpec::g5k(30);
        c.data_dir = "data/set2".into();
        c
    }

    /// Set3-analog: 4000 observations per point (paper §6.3.2 is 10000;
    /// scaled 0.4x like the cube volume — the shuffle-volume effect it
    /// exists to show kicks in at 4x vector size already).
    pub fn set3() -> ExperimentConfig {
        let mut c = Self::set1();
        c.name = "set3".into();
        c.dataset.dims = CubeDims::new(128, 96, 96);
        c.dataset.n_sims = 4000;
        c.dataset.seed = 20180517;
        c.cluster = ClusterSpec::g5k(30);
        c.pipeline.batch = 64; // matches the 64x4000 artifacts
        c.data_dir = "data/set3".into();
        c
    }

    /// Tiny config for tests and the quickstart example.
    pub fn small() -> ExperimentConfig {
        let dataset = DatasetSpec::tiny();
        ExperimentConfig {
            name: "small".into(),
            dataset,
            cluster: ClusterSpec::lncc(),
            pipeline: PipelineConfig {
                batch: 64,
                window_lines: 4,
                ..PipelineConfig::default()
            },
            slice: 2,
            train_slice: 0,
            data_dir: "data/small".into(),
            artifacts_dir: "artifacts".into(),
            backend: default_backend(),
            faults: None,
        }
    }

    pub fn preset(name: &str) -> Result<ExperimentConfig> {
        let mut cfg = match name {
            "set1" => Self::set1(),
            "set2" => Self::set2(),
            "set3" => Self::set3(),
            "small" => Self::small(),
            other => return Err(PdfflowError::Config(format!("unknown preset {other:?}"))),
        };
        // Surface an unparseable PDFFLOW_BACKEND as an error here (the
        // constructors above silently fall back to native).
        if let Some(k) = BackendKind::from_env()? {
            cfg.backend = k;
        }
        Ok(cfg)
    }

    /// Build the configured compute backend (see [`runtime::make_backend`]).
    pub fn make_backend(&self) -> Result<Box<dyn Backend>> {
        runtime::make_backend(
            self.backend,
            &self.artifacts_dir,
            &BackendOptions {
                batch: self.pipeline.batch,
                workers: self.pipeline.workers,
                bins: self.pipeline.bins,
                adaptive: self.pipeline.adaptive_batch,
            },
        )
    }

    /// Load from a TOML file; unspecified keys fall back to the preset
    /// named by the file's `preset` key (default "set1").
    pub fn from_file(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(&path)?;
        let doc = TomlDoc::parse(&text).map_err(PdfflowError::Config)?;
        let mut cfg = Self::preset(&doc.str_or("preset", "set1"))?;
        cfg.name = doc.str_or("name", &cfg.name);
        // Dataset.
        cfg.dataset.dims = CubeDims::new(
            doc.usize_or("dataset.nx", cfg.dataset.dims.nx),
            doc.usize_or("dataset.ny", cfg.dataset.dims.ny),
            doc.usize_or("dataset.nz", cfg.dataset.dims.nz),
        );
        cfg.dataset.n_sims = doc.usize_or("dataset.simulations", cfg.dataset.n_sims);
        cfg.dataset.group_levels = doc.usize_or("dataset.group_levels", cfg.dataset.group_levels);
        cfg.dataset.blend_fraction = doc.f64_or("dataset.blend_fraction", cfg.dataset.blend_fraction);
        cfg.dataset.unique_fraction =
            doc.f64_or("dataset.unique_fraction", cfg.dataset.unique_fraction);
        cfg.dataset.seed = doc.i64_or("dataset.seed", cfg.dataset.seed as i64) as u64;
        // Cluster.
        match doc.str_or("cluster.kind", "").as_str() {
            "" => {}
            "lncc" => cfg.cluster = ClusterSpec::lncc(),
            "g5k" => cfg.cluster = ClusterSpec::g5k(doc.usize_or("cluster.nodes", 30)),
            "local" => cfg.cluster = ClusterSpec::local(doc.usize_or("cluster.cores", 4)),
            other => {
                return Err(PdfflowError::Config(format!("unknown cluster kind {other:?}")))
            }
        }
        // Pipeline.
        cfg.pipeline.window_lines = doc.usize_or("pipeline.window_lines", cfg.pipeline.window_lines);
        cfg.pipeline.batch = doc.usize_or("pipeline.batch", cfg.pipeline.batch);
        cfg.pipeline.bins = doc.usize_or("pipeline.bins", cfg.pipeline.bins);
        cfg.pipeline.workers = doc.usize_or("pipeline.workers", cfg.pipeline.workers);
        cfg.pipeline.adaptive_batch =
            doc.bool_or("pipeline.adaptive_batch", cfg.pipeline.adaptive_batch);
        cfg.pipeline.executor_threads = doc
            .usize_or("pipeline.executor_threads", cfg.pipeline.executor_threads)
            .max(1);
        if let Some(n) = doc.get("pipeline.host_threads").and_then(|v| v.as_i64()) {
            cfg.pipeline.host_threads = Some((n.max(1)) as usize);
        }
        cfg.pipeline.group_quantum = doc.f64_or("pipeline.group_quantum", cfg.pipeline.group_quantum);
        cfg.pipeline.cache_bytes = doc.i64_or("pipeline.cache_bytes", cfg.pipeline.cache_bytes as i64) as u64;
        if let Some(p) = doc.get("pipeline.partitions").and_then(|v| v.as_i64()) {
            cfg.pipeline.partitions = Some(p as usize);
        }
        if let Some(d) = doc.get("pipeline.persist_dir").and_then(|v| v.as_str()) {
            cfg.pipeline.persist_dir = Some(d.to_string());
        }
        if let Some(d) = doc.get("pipeline.store_dir").and_then(|v| v.as_str()) {
            cfg.pipeline.store_dir = Some(d.to_string());
        }
        if let Some(r) = doc.get("pipeline.run_id").and_then(|v| v.as_str()) {
            crate::pdfstore::validate_run_id(r)
                .map_err(|e| PdfflowError::Config(e.to_string()))?;
            cfg.pipeline.run_id = Some(r.to_string());
        }
        cfg.pipeline.query_cache_bytes =
            doc.i64_or("pipeline.query_cache_bytes", cfg.pipeline.query_cache_bytes as i64) as u64;
        // Paths + slices + backend.
        cfg.slice = doc.usize_or("slice", cfg.slice);
        cfg.train_slice = doc.usize_or("train_slice", cfg.train_slice);
        cfg.data_dir = doc.str_or("data_dir", &cfg.data_dir);
        cfg.artifacts_dir = doc.str_or("artifacts_dir", &cfg.artifacts_dir);
        match doc.str_or("backend", "").as_str() {
            "" => {}
            s => {
                cfg.backend = BackendKind::from_name(s).ok_or_else(|| {
                    PdfflowError::Config(format!("unknown backend {s:?} (native|xla)"))
                })?
            }
        }
        if let Some(s) = doc.get("faults.spec").and_then(|v| v.as_str()) {
            cfg.faults = Some(s.to_string());
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for p in ["set1", "set2", "set3", "small"] {
            let c = ExperimentConfig::preset(p).unwrap();
            assert!(c.slice < c.dataset.dims.nz, "{p}");
            assert!(c.dataset.n_sims >= 100);
        }
        assert!(ExperimentConfig::preset("bogus").is_err());
    }

    #[test]
    fn set1_slice_is_proportional_201() {
        let c = ExperimentConfig::set1();
        assert_eq!(c.slice, c.dataset.dims.nz * 201 / 501);
    }

    #[test]
    fn file_overrides_preset() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            r#"
preset = "small"
name = "custom"
[dataset]
simulations = 128
[cluster]
kind = "g5k"
nodes = 20
[pipeline]
window_lines = 7
batch = 64
adaptive_batch = false
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.name, "custom");
        assert_eq!(c.dataset.n_sims, 128);
        assert_eq!(c.cluster.nodes, 20);
        assert_eq!(c.pipeline.window_lines, 7);
        assert!(!c.pipeline.adaptive_batch, "adaptive_batch key must parse");
        // Default stays adaptive.
        assert!(ExperimentConfig::small().pipeline.adaptive_batch);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_keys_parse() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.toml");
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nstore_dir = \"out/store\"\nquery_cache_bytes = 1048576\nrun_id = \"exp-1\"\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.pipeline.store_dir.as_deref(), Some("out/store"));
        assert_eq!(c.pipeline.query_cache_bytes, 1 << 20);
        assert_eq!(c.pipeline.run_id.as_deref(), Some("exp-1"));
        // Defaults: no store, no run id, 64 MiB query cache.
        let d = ExperimentConfig::small();
        assert!(d.pipeline.store_dir.is_none());
        assert!(d.pipeline.run_id.is_none());
        assert_eq!(d.pipeline.query_cache_bytes, 64 << 20);
        // Unsafe run ids are rejected at parse time.
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nrun_id = \"a/b\"\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_file(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn executor_threads_key_parses_and_defaults() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg5-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exec.toml");
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nexecutor_threads = 3\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.pipeline.executor_threads, 3);
        // A zero in the file clamps to 1 (a stage always makes progress).
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nexecutor_threads = 0\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.pipeline.executor_threads, 1);
        // Default: at least one thread, no env assumption.
        assert!(ExperimentConfig::small().pipeline.executor_threads >= 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn host_threads_key_parses_and_defaults_to_none() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg6-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("host.toml");
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nhost_threads = 6\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.pipeline.host_threads, Some(6));
        assert_eq!(ExperimentConfig::small().pipeline.host_threads, None);
        // Zero clamps to 1 (the pool always has the caller slot).
        std::fs::write(
            &path,
            "preset = \"small\"\n[pipeline]\nhost_threads = 0\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.pipeline.host_threads, Some(1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backend_key_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("backend.toml");
        std::fs::write(&path, "preset = \"small\"\nbackend = \"xla\"\n").unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.backend, BackendKind::Xla);
        std::fs::write(&path, "backend = \"spark\"\n").unwrap();
        assert!(ExperimentConfig::from_file(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn default_backend_builds() {
        // Presets default to the native backend (unless PDFFLOW_BACKEND
        // overrides), which must construct without any artifacts.
        let c = ExperimentConfig::small();
        if c.backend == BackendKind::Native {
            let b = c.make_backend().unwrap();
            assert_eq!(b.name(), "native");
        }
    }

    #[test]
    fn bad_cluster_kind_fails() {
        let dir = std::env::temp_dir().join(format!("pdfflow-cfg2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.toml");
        std::fs::write(&path, "[cluster]\nkind = \"mesos\"\n").unwrap();
        assert!(ExperimentConfig::from_file(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
