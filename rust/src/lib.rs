//! # pdfflow
//!
//! Parallel computation of Probability Density Functions (PDFs) on big
//! spatial data — a Rust + JAX + Pallas reproduction of *Liu, Lemus,
//! Pacitti, Porto, Valduriez: "Parallel Computation of PDFs on Big Spatial
//! Data Using Spark"* (CS.DC 2018).
//!
//! The crate is the paper's **Layer-3 coordinator**: it owns the dataset
//! generator (HPC4e seismic-benchmark analog), the NFS-style storage
//! reader, a simulated shared-nothing Spark-like cluster, a staged task
//! [`executor`] driving a lazy mini-[`rdd`] dataflow layer, the
//! decision-tree (MLlib analog), the sampling machinery, and the five
//! PDF-computation methods of the paper (Baseline / Grouping / Reuse /
//! ML / Sampling plus combinations). The pipeline runs windows as
//! parallel executor tasks (configurable via `executor_threads`) with a
//! sequenced persist sink, so reports and persisted bytes are identical
//! at any thread count. Every parallel layer — executor stages, the
//! native backend's chunk fan-out, the query engine — draws from one
//! process-wide thread budget ([`runtime::hostpool`]), so width knobs
//! compose without oversubscribing the host.
//!
//! The numeric hot path — distribution fitting plus the Eq. 5 error for
//! up to ten candidate types — runs through a pluggable
//! [`runtime::Backend`]:
//!
//! * [`runtime::NativeBackend`] (**default**) evaluates the pure-Rust
//!   kernels in [`stats`] over thread-parallel point batches. No AOT
//!   artifacts, no Python, no XLA toolchain — the pipeline, benches and
//!   the whole test tier run on any machine.
//! * `runtime::Engine` (behind the **`xla`** cargo feature) executes JAX
//!   graphs (with Pallas kernels at the innermost level) AOT-lowered to
//!   HLO text by `python/compile/aot.py` through the PJRT CPU client.
//!   Python never runs on the request path.
//!
//! Backends are selected via the `backend` config key, the `--backend`
//! CLI flag, or the `PDFFLOW_BACKEND` environment variable; see
//! `rust/README.md` for the full backend matrix.
//!
//! Downstream of the pipeline, the [`pdfstore`] subsystem persists every
//! fitted PDF into a partitioned, checksummed on-disk store: per-slice
//! segment files with footer window indexes, organized by a
//! **generational run catalog** — every run `(method, types, run_id)`
//! owns immutable segments, reruns append generations instead of
//! clobbering, and `pdfstore::compact` collapses a run to one dense
//! generation with bit-identical query results. Reads go through the
//! sharded-LRU-cached [`pdfstore::QueryEngine`] (point lookups, region
//! scans, density/CDF/quantile analytics), and the [`serve`] layer puts
//! an admission-controlled front door (in-flight + queue-depth caps,
//! shed-with-error, per-class latency/shed counters) on top — the
//! layers that turn the batch reproduction into a servable system
//! (`store` / `query` / `serve` CLI subcommands, `cargo bench --bench
//! queries` for throughput). The [`spatial`] tier adds grid-indexed 3D
//! box / radius / kNN queries, per-cell aggregation of fit outcomes and
//! cross-run diffs on top of the store, each verified bit-identical
//! against a brute-force oracle (`tests/spatial_oracle.rs`).

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cube;
pub mod datagen;
pub mod executor;
pub mod fault;
pub mod mltree;
pub mod pdfstore;
pub mod rdd;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod spatial;
pub mod stats;
pub mod storage;
pub mod telemetry;
pub mod util;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::cluster::{ClusterSpec, SimCluster};
    pub use crate::config::ExperimentConfig;
    pub use crate::coordinator::{Method, Pipeline, SliceReport, TypeSet};
    pub use crate::cube::{CellGrid, CubeDims, PointId, Window};
    pub use crate::datagen::SyntheticDataset;
    pub use crate::executor::Executor;
    pub use crate::mltree::DecisionTree;
    pub use crate::pdfstore::{
        compact_run, PdfStore, QueryEngine, QueryOptions, ReadPath, RegionQuery, RunKey,
        RunSelector,
    };
    #[cfg(feature = "xla")]
    pub use crate::runtime::Engine;
    pub use crate::runtime::{
        make_backend, Backend, BackendKind, BackendOptions, HostPool, NativeBackend,
    };
    pub use crate::serve::net::{closed_loop_net, Client, NetOptions, NetServer};
    pub use crate::serve::{closed_loop, ServeFront, ServeOptions};
    pub use crate::spatial::{BoxQuery, KnnQuery, RadiusQuery, RunDiff, SpatialAggregate};
    pub use crate::stats::DistType;
}

/// Typed error for module boundaries; binaries wrap it in `anyhow`.
#[derive(Debug, thiserror::Error)]
pub enum PdfflowError {
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("xla/pjrt error: {0}")]
    Xla(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("data format error: {0}")]
    Format(String),
    #[error("invalid argument: {0}")]
    InvalidArg(String),
    /// Load shed by the serving tier's admission control — the caller
    /// should back off and retry, nothing is wrong with the request.
    #[error("overloaded: {0}")]
    Overloaded(String),
}

impl PdfflowError {
    /// True for admission-control sheds (retryable by design).
    pub fn is_overload(&self) -> bool {
        matches!(self, PdfflowError::Overloaded(_))
    }

    /// True for errors worth retrying: raw I/O failures and admission
    /// sheds. Corruption (`Format`) and misuse (`Config`/`InvalidArg`)
    /// are permanent — retrying them cannot help, and [`fault::retry`]
    /// returns them immediately. Missing files and denied permissions
    /// are I/O errors that won't heal either, so they are permanent
    /// too.
    pub fn is_transient(&self) -> bool {
        match self {
            PdfflowError::Io(e) => !matches!(
                e.kind(),
                std::io::ErrorKind::NotFound | std::io::ErrorKind::PermissionDenied
            ),
            PdfflowError::Overloaded(_) => true,
            _ => false,
        }
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for PdfflowError {
    fn from(e: xla::Error) -> Self {
        PdfflowError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, PdfflowError>;
