//! Sampling method substrate (paper §5.4, Algorithm 5).
//!
//! Two ways to pick the "double sampled" points whose features stand in
//! for the whole slice: plain random sampling and k-means clustering on
//! (mean, std) with the nearest-to-centroid point per cluster. The slice
//! features (avg mean, avg std, distribution-type percentages) and the
//! Fig. 17 Euclidean distance metric live here too.

use crate::stats::DistType;
use crate::util::prng::Rng;

/// Random sample of `rate * n` point indices (paper's chosen default).
pub fn random_sample(rng: &mut Rng, n: usize, rate: f64) -> Vec<usize> {
    let k = ((n as f64 * rate).round() as usize).clamp(1, n);
    let mut idx = rng.sample_indices(n, k);
    idx.sort_unstable();
    idx
}

/// Lloyd k-means on feature rows; returns the index of the point nearest
/// to each centroid (the paper's alternative "double sampling"). `k` is
/// `rate * n` like random sampling.
pub fn kmeans_sample(
    rng: &mut Rng,
    features: &[[f64; 2]],
    rate: f64,
    max_iters: usize,
) -> Vec<usize> {
    let n = features.len();
    let k = ((n as f64 * rate).round() as usize).clamp(1, n);
    if k >= n {
        return (0..n).collect();
    }
    // k-means++ style seeding (first uniform, rest distance-weighted —
    // simplified to uniform distinct seeds; fine for sampling purposes).
    let seeds = rng.sample_indices(n, k);
    let mut centroids: Vec<[f64; 2]> = seeds.iter().map(|&i| features[i]).collect();
    let mut assign = vec![0usize; n];
    for _ in 0..max_iters {
        let mut moved = false;
        for (i, f) in features.iter().enumerate() {
            let best = nearest(&centroids, f);
            if assign[i] != best {
                assign[i] = best;
                moved = true;
            }
        }
        let mut sums = vec![[0.0f64; 2]; k];
        let mut counts = vec![0usize; k];
        for (i, f) in features.iter().enumerate() {
            let c = assign[i];
            sums[c][0] += f[0];
            sums[c][1] += f[1];
            counts[c] += 1;
        }
        for c in 0..k {
            if counts[c] > 0 {
                centroids[c] = [sums[c][0] / counts[c] as f64, sums[c][1] / counts[c] as f64];
            }
        }
        if !moved {
            break;
        }
    }
    // Nearest point to each non-empty centroid.
    let mut out: Vec<usize> = Vec::with_capacity(k);
    for c in 0..k {
        let mut best: Option<(f64, usize)> = None;
        for (i, f) in features.iter().enumerate() {
            if assign[i] != c {
                continue;
            }
            let d = dist2(f, &centroids[c]);
            if best.map_or(true, |(bd, _)| d < bd) {
                best = Some((d, i));
            }
        }
        if let Some((_, i)) = best {
            out.push(i);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

fn dist2(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    dx * dx + dy * dy
}

fn nearest(centroids: &[[f64; 2]], f: &[f64; 2]) -> usize {
    let mut best = 0;
    let mut bd = f64::INFINITY;
    for (c, cen) in centroids.iter().enumerate() {
        let d = dist2(f, cen);
        if d < bd {
            bd = d;
            best = c;
        }
    }
    best
}

/// Slice features (paper §3): average mean, average std, percentage of
/// points per distribution type.
#[derive(Clone, Debug, Default)]
pub struct SliceFeatures {
    pub avg_mean: f64,
    pub avg_std: f64,
    pub type_percentages: [f64; 10],
    pub n_points: usize,
}

impl SliceFeatures {
    pub fn from_points(means: &[f64], stds: &[f64], types: &[DistType]) -> SliceFeatures {
        let n = means.len();
        assert_eq!(n, stds.len());
        assert_eq!(n, types.len());
        if n == 0 {
            return SliceFeatures::default();
        }
        let mut pct = [0.0f64; 10];
        for t in types {
            pct[t.id()] += 1.0;
        }
        for p in pct.iter_mut() {
            *p /= n as f64;
        }
        SliceFeatures {
            avg_mean: means.iter().sum::<f64>() / n as f64,
            avg_std: stds.iter().sum::<f64>() / n as f64,
            type_percentages: pct,
            n_points: n,
        }
    }

    /// Fig. 17 metric: Euclidean distance between two type-percentage
    /// vectors.
    pub fn type_distance(&self, other: &SliceFeatures) -> f64 {
        self.type_percentages
            .iter()
            .zip(other.type_percentages.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_sample_rate_and_bounds() {
        let mut rng = Rng::new(1);
        let s = random_sample(&mut rng, 1000, 0.1);
        assert_eq!(s.len(), 100);
        assert!(s.windows(2).all(|w| w[0] < w[1])); // sorted distinct
        assert!(s.iter().all(|&i| i < 1000));
    }

    #[test]
    fn random_sample_extreme_rates() {
        let mut rng = Rng::new(2);
        assert_eq!(random_sample(&mut rng, 50, 1.0).len(), 50);
        assert_eq!(random_sample(&mut rng, 50, 0.0).len(), 1); // clamped min
        assert_eq!(random_sample(&mut rng, 50, 2.0).len(), 50); // clamped max
    }

    #[test]
    fn kmeans_centroid_points_cover_clusters() {
        // Two tight blobs: sampled points must hit both.
        let mut rng = Rng::new(3);
        let mut features: Vec<[f64; 2]> = Vec::new();
        for i in 0..200 {
            let (cx, cy) = if i % 2 == 0 { (0.0, 0.0) } else { (10.0, 10.0) };
            features.push([cx + rng.f64() * 0.1, cy + rng.f64() * 0.1]);
        }
        let picks = kmeans_sample(&mut rng, &features, 0.02, 20); // k = 4
        assert!(!picks.is_empty() && picks.len() <= 4);
        let has_low = picks.iter().any(|&i| features[i][0] < 1.0);
        let has_high = picks.iter().any(|&i| features[i][0] > 9.0);
        assert!(has_low && has_high, "picks {picks:?}");
    }

    #[test]
    fn kmeans_rate_one_returns_everything() {
        let mut rng = Rng::new(4);
        let features = vec![[0.0, 0.0], [1.0, 1.0], [2.0, 2.0]];
        let picks = kmeans_sample(&mut rng, &features, 1.0, 5);
        assert_eq!(picks, vec![0, 1, 2]);
    }

    #[test]
    fn kmeans_picks_are_valid_distinct_indices() {
        let mut rng = Rng::new(5);
        let features: Vec<[f64; 2]> = (0..300)
            .map(|_| [rng.f64() * 5.0, rng.f64() * 5.0])
            .collect();
        let picks = kmeans_sample(&mut rng, &features, 0.1, 15);
        let mut u = picks.clone();
        u.dedup();
        assert_eq!(u.len(), picks.len());
        assert!(picks.iter().all(|&i| i < 300));
        assert!(picks.len() <= 30);
    }

    #[test]
    fn slice_features_percentages_sum_to_one() {
        let means = vec![1.0, 2.0, 3.0, 4.0];
        let stds = vec![0.1, 0.2, 0.3, 0.4];
        let types = vec![
            DistType::Normal,
            DistType::Normal,
            DistType::Uniform,
            DistType::Weibull,
        ];
        let f = SliceFeatures::from_points(&means, &stds, &types);
        assert!((f.avg_mean - 2.5).abs() < 1e-12);
        assert!((f.avg_std - 0.25).abs() < 1e-12);
        assert!((f.type_percentages.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((f.type_percentages[DistType::Normal.id()] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn type_distance_zero_for_identical() {
        let means = vec![1.0; 10];
        let stds = vec![1.0; 10];
        let types = vec![DistType::Gamma; 10];
        let a = SliceFeatures::from_points(&means, &stds, &types);
        let b = SliceFeatures::from_points(&means, &stds, &types);
        assert_eq!(a.type_distance(&b), 0.0);
    }

    #[test]
    fn type_distance_max_for_disjoint() {
        let a = SliceFeatures::from_points(&[1.0], &[1.0], &[DistType::Normal]);
        let b = SliceFeatures::from_points(&[1.0], &[1.0], &[DistType::Uniform]);
        assert!((a.type_distance(&b) - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_slice_features() {
        let f = SliceFeatures::from_points(&[], &[], &[]);
        assert_eq!(f.n_points, 0);
        assert_eq!(f.avg_mean, 0.0);
    }
}
