//! Algorithm 2: parallel data loading of one window.
//!
//! For each point of the window, gather its K observation values from the
//! K simulation files on "NFS" (one contiguous positioned read per file),
//! then compute the per-point statistics (mean, std, …) via the backend's
//! stats kernel — the paper computes mean/std inside the loading Map.
//! Loaded windows are cached (§4.3.1); both real wall-clock and simulated
//! cluster time are recorded.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::SimCluster;
use crate::cube::{PointId, Window};
use crate::runtime::{Backend, OutMatrix};
use crate::storage::{DatasetReader, ObsMatrix, WindowCache};
use crate::Result;

/// A loaded window: observation vectors plus per-point statistics.
pub struct LoadedWindow {
    pub window: Window,
    pub obs: Arc<ObsMatrix>,
    /// Stats kernel output: (n_points, 12) — see `distfit.STATS_COLS`.
    pub stats: OutMatrix,
    /// Real wall-clock spent loading (I/O + transpose + stats), seconds.
    pub real_s: f64,
    /// Simulated cluster time for the same work, seconds.
    pub sim_s: f64,
    /// True when the observation matrix came from the window cache.
    pub cache_hit: bool,
}

impl LoadedWindow {
    pub fn n_points(&self) -> usize {
        self.obs.n_points()
    }

    pub fn point_ids(&self) -> &[PointId] {
        &self.obs.point_ids
    }

    /// (mean, std) feature pair of point `p` (grouping key and ML input).
    pub fn mean_std(&self, p: usize) -> (f64, f64) {
        let row = self.stats.row(p);
        (row[0] as f64, row[1] as f64)
    }
}

/// Load one window (Algorithm 2), consulting the cache first. Takes the
/// cluster by shared reference — the ledger is internally synchronized,
/// so concurrent window tasks can all charge the same session (the
/// pipeline passes a per-window scratch to keep `sim_s` attributable).
pub fn load_window(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster: &SimCluster,
    window: Window,
) -> Result<LoadedWindow> {
    let t0 = Instant::now();
    let (obs, cache_hit) = match cache.get(&window) {
        Some(m) => (m, true),
        None => {
            // NFS reads are the classic transient-failure surface;
            // bounded retry keeps a blip from killing a whole run.
            let m = Arc::new(crate::fault::retry("loader.read", || {
                crate::fault::check("loader.read")?;
                reader.read_window(&window)
            })?);
            cache.put(&window, Arc::clone(&m));
            (m, false)
        }
    };
    let io_real = t0.elapsed().as_secs_f64();

    // Simulated NFS time: cache hits skip the server entirely.
    let mut sim_s = 0.0;
    if !cache_hit {
        let bytes = obs.bytes();
        let reads = reader.dataset().spec.n_sims as u64;
        sim_s += cluster.charge_nfs("load.nfs", bytes, reads);
    }

    // Per-point statistics via the backend's stats kernel. The simulated
    // loading stage runs one Map task per point (the paper's Algorithm
    // 2): each task pays the emulated per-value gather cost (external
    // Java program doing positioned reads) plus this host's real
    // per-point share of the stats execution. Cache hits skip the gather
    // cost.
    let t1 = Instant::now();
    let n = obs.n_points();
    let stats = backend.run_stats(&obs.data, n, obs.n_obs)?;
    let stats_real = t1.elapsed().as_secs_f64();
    let gather = if cache_hit {
        0.0
    } else {
        cluster.spec.load_cost_per_value * obs.n_obs as f64
    };
    let per_task = gather + stats_real / n as f64;
    sim_s += cluster.run_stage("load.stats", &vec![per_task; n]);

    Ok(LoadedWindow {
        window,
        obs,
        stats,
        real_s: io_real + stats_real,
        sim_s,
        cache_hit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::datagen::{DatasetSpec, SyntheticDataset};
    use crate::runtime::NativeBackend;
    use crate::stats::PointStats;

    fn setup(tag: &str) -> (SyntheticDataset, std::path::PathBuf, NativeBackend) {
        let dir =
            std::env::temp_dir().join(format!("pdfflow-loader-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), &dir).unwrap();
        let backend = NativeBackend::with_options(2, 64, 32);
        (ds, dir, backend)
    }

    #[test]
    fn loads_window_with_stats_matching_oracle() {
        let (ds, dir, backend) = setup("basic");
        let reader = DatasetReader::new(&ds);
        let cache = WindowCache::new(64 << 20);
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let w = Window { z: 2, y0: 0, lines: 2 };
        let lw = load_window(&reader, &cache, &backend, &cluster, w).unwrap();
        assert_eq!(lw.n_points(), 2 * ds.spec.dims.nx);
        assert!(!lw.cache_hit);
        assert!(lw.real_s > 0.0 && lw.sim_s > 0.0);
        // Spot-check stats row 0 against the oracle.
        let s = PointStats::of(lw.obs.point_row(0));
        let (mean, std) = lw.mean_std(0);
        assert!((mean - s.mean).abs() < 1e-2 * s.mean.abs().max(1.0));
        assert!((std - s.std).abs() < 2e-2 * s.std.abs().max(1e-3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn second_load_hits_cache_and_skips_nfs() {
        let (ds, dir, backend) = setup("cache");
        let reader = DatasetReader::new(&ds);
        let cache = WindowCache::new(64 << 20);
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let w = Window { z: 1, y0: 2, lines: 2 };
        load_window(&reader, &cache, &backend, &cluster, w).unwrap();
        let nfs_after_first = cluster.account("load.nfs");
        let lw2 = load_window(&reader, &cache, &backend, &cluster, w).unwrap();
        assert!(lw2.cache_hit);
        assert_eq!(cluster.account("load.nfs"), nfs_after_first);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
