//! Decision-tree model generation (paper §5.3.1).
//!
//! The paper trains on "previously generated output data": per point the
//! mean, std and the distribution type chosen by a full fit. We generate
//! that output the same way the paper did — by running the full
//! `fit_all` on points of a training slice (Slice 0) — then train the
//! CART tree on (mean, std) → type and report the wrong-prediction rate
//! on a held-out test split as the *model error*.

use crate::cluster::SimCluster;
use crate::coordinator::loader::{self, LoadedWindow};
use crate::coordinator::methods::TypeSet;
use crate::cube::CubeDims;
use crate::mltree::{self, DecisionTree, Sample, TreeParams};
use crate::runtime::Backend;
use crate::storage::{DatasetReader, WindowCache};
use crate::util::prng::Rng;
use crate::Result;

/// Labeled training data extracted from a slice's full-fit output.
pub struct TrainingData {
    pub samples: Vec<Sample>,
    /// Real seconds spent producing the "previous output" (fit_all runs).
    pub generation_real_s: f64,
}

/// Slices whose previously generated output trains the tree. The paper
/// uses Slice 0 only — valid there because wave propagation mixes all 16
/// uncertain inputs into every point, so all slices share one
/// (mean, std) → type correlation. Our synthetic generator keeps layers
/// disjoint in feature space (each slice sees one layer's Vp range), so
/// the "previous output" must span the layers: we take `train_slice`
/// plus one representative slice per value layer (documented deviation,
/// DESIGN.md §3).
pub fn training_slices(dims: &CubeDims, train_slice: usize, n_layers: usize) -> Vec<usize> {
    let mut out = vec![train_slice];
    let nv = n_layers.max(1);
    for l in 0..nv {
        let z = (l * dims.nz + dims.nz / (2 * nv)) / nv;
        let z = z.min(dims.nz - 1);
        if !out.contains(&z) {
            out.push(z);
        }
    }
    out
}

/// Produce labeled (mean, std) → type samples from up to `max_points`
/// points spread over `train_slices` (paper: 25000 points of Slice 0).
#[allow(clippy::too_many_arguments)]
pub fn build_training_data(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster: &SimCluster,
    dims: &CubeDims,
    train_slices: &[usize],
    types: TypeSet,
    max_points: usize,
    window_lines: usize,
) -> Result<TrainingData> {
    let mut samples = Vec::new();
    let mut gen_s = 0.0;
    let per_slice = max_points.div_ceil(train_slices.len().max(1));
    for &train_slice in train_slices {
        let mut slice_taken = 0usize;
        for window in dims.windows(train_slice, window_lines) {
            if slice_taken >= per_slice || samples.len() >= max_points {
                break;
            }
            let lw: LoadedWindow = loader::load_window(reader, cache, backend, cluster, window)?;
            let take = (per_slice - slice_taken)
                .min(max_points - samples.len())
                .min(lw.n_points());
            let values = &lw.obs.data[..take * lw.obs.n_obs];
            let t0 = std::time::Instant::now();
            let out = backend.run_fit_all(values, take, lw.obs.n_obs, types.n_types())?;
            gen_s += t0.elapsed().as_secs_f64();
            for p in 0..take {
                let (mean, std) = lw.mean_std(p);
                samples.push(Sample {
                    features: vec![mean, std],
                    label: out.row(p)[0] as usize,
                });
            }
            slice_taken += take;
        }
    }
    Ok(TrainingData {
        samples,
        generation_real_s: gen_s,
    })
}

/// A trained model plus the paper's quality/tuning metadata.
pub struct TrainedModel {
    pub tree: DecisionTree,
    /// Wrong-prediction rate on the held-out test split (§5.3.1).
    pub model_error: f64,
    pub params: TreeParams,
    pub train_real_s: f64,
    pub n_train: usize,
    pub n_test: usize,
}

/// Train with fixed hyper-parameters on a random train/test split
/// (paper: hypers are tuned once and reused across datasets).
pub fn train_model(data: &TrainingData, params: TreeParams, seed: u64) -> Result<TrainedModel> {
    let mut idx: Vec<usize> = (0..data.samples.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let split = (idx.len() * 8) / 10;
    let train: Vec<Sample> = idx[..split].iter().map(|&i| data.samples[i].clone()).collect();
    let test: Vec<Sample> = idx[split..].iter().map(|&i| data.samples[i].clone()).collect();
    let t0 = std::time::Instant::now();
    let tree = DecisionTree::train(&train, params)?;
    let train_real_s = t0.elapsed().as_secs_f64();
    let model_error = tree.error_rate(&test);
    Ok(TrainedModel {
        tree,
        model_error,
        params,
        train_real_s,
        n_train: train.len(),
        n_test: test.len(),
    })
}

/// The paper's hyper-parameter tuning (§5.3.1): grid over depth × maxBins
/// on a train/validation split. Returns the chosen params + tuning time.
pub fn tune_hypers(data: &TrainingData, seed: u64) -> Result<(TreeParams, f64, f64)> {
    let t0 = std::time::Instant::now();
    let (params, err) = mltree::tune(
        &data.samples,
        &[2, 3, 4, 6, 8, 10, 12],
        &[4, 8, 16, 32, 64],
        seed,
    )?;
    Ok((params, err, t0.elapsed().as_secs_f64()))
}
