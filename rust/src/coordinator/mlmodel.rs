//! Decision-tree model generation (paper §5.3.1).
//!
//! The paper trains on "previously generated output data": per point the
//! mean, std and the distribution type chosen by a full fit. We generate
//! that output the same way the paper did — by running the full
//! `fit_all` on points of a training slice (Slice 0) — then train the
//! CART tree on (mean, std) → type and report the wrong-prediction rate
//! on a held-out test split as the *model error*.
//!
//! When a pdfstore already holds that previous output (a full-fit
//! "baseline" run over the training slices), the labels are **read back
//! from the store** instead of refit ([`LabelSource::Store`],
//! [`store_label_engine`]) — the paper's "reuse of previous results"
//! applied to model generation itself. The samples are identical either
//! way (the store holds exactly the full-fit outcome per point), pinned
//! by `tests/store_generations.rs`.

use crate::cluster::SimCluster;
use crate::coordinator::loader::{self, LoadedWindow};
use crate::coordinator::methods::TypeSet;
use crate::cube::CubeDims;
use crate::mltree::{self, DecisionTree, Sample, TreeParams};
use crate::pdfstore::{Catalog, PdfStore, QueryEngine, QueryOptions, RegionQuery, RunSelector};
use crate::runtime::Backend;
use crate::storage::{DatasetReader, WindowCache};
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

/// Where the training labels (the "previously generated output") come
/// from: a fresh full fit, or a prior full-fit run read from the store.
#[derive(Clone, Copy)]
pub enum LabelSource<'a> {
    /// Regenerate by running the full fit over the training windows.
    Refit,
    /// Read the per-point types of a prior full-fit run from an open
    /// store run (built by [`store_label_engine`]).
    Store(&'a QueryEngine),
}

/// Labeled training data extracted from a slice's full-fit output.
pub struct TrainingData {
    pub samples: Vec<Sample>,
    /// Real seconds spent producing the "previous output" (fit_all runs
    /// or store reads).
    pub generation_real_s: f64,
    /// True when the labels were read from a pdfstore run instead of
    /// refit.
    pub from_store: bool,
}

/// Try to build a store-backed label source: the most recent full-fit
/// ("baseline") run with this candidate-type set, in a store whose
/// geometry matches and whose resolved view fully covers every training
/// slice. `None` means "refit" — a missing or unusable store is never
/// an error, just the slow path.
pub fn store_label_engine(
    store_dir: Option<&str>,
    dims: &CubeDims,
    n_obs: usize,
    train_slices: &[usize],
    types: TypeSet,
) -> Option<QueryEngine> {
    let dir = std::path::Path::new(store_dir?);
    if !Catalog::exists(dir) {
        return None;
    }
    let catalog = Catalog::load(dir).ok()?;
    if catalog.dims != *dims || catalog.n_obs != n_obs {
        return None;
    }
    let key = catalog
        .runs
        .iter()
        .filter(|r| r.key.method == "baseline" && r.key.types == types.n_types())
        .max_by_key(|r| r.seq)?
        .key
        .clone();
    let store = PdfStore::open_run(dir, RunSelector::Key(&key)).ok()?;
    let covered = train_slices
        .iter()
        .all(|&z| store.covers_lines(z, 0, dims.ny.saturating_sub(1)));
    if !covered {
        return None;
    }
    Some(QueryEngine::new(
        store,
        QueryOptions {
            cache_bytes: 8 << 20,
            ..QueryOptions::default()
        },
    ))
}

/// Slices whose previously generated output trains the tree. The paper
/// uses Slice 0 only — valid there because wave propagation mixes all 16
/// uncertain inputs into every point, so all slices share one
/// (mean, std) → type correlation. Our synthetic generator keeps layers
/// disjoint in feature space (each slice sees one layer's Vp range), so
/// the "previous output" must span the layers: we take `train_slice`
/// plus one representative slice per value layer (documented deviation,
/// DESIGN.md §3).
pub fn training_slices(dims: &CubeDims, train_slice: usize, n_layers: usize) -> Vec<usize> {
    let mut out = vec![train_slice];
    let nv = n_layers.max(1);
    for l in 0..nv {
        let z = (l * dims.nz + dims.nz / (2 * nv)) / nv;
        let z = z.min(dims.nz - 1);
        if !out.contains(&z) {
            out.push(z);
        }
    }
    out
}

/// Produce labeled (mean, std) → type samples from up to `max_points`
/// points spread over `train_slices` (paper: 25000 points of Slice 0).
/// Features always come from loading the windows (mean/std of the raw
/// observations); `labels` decides whether the type labels are refit or
/// read back from a prior store run.
#[allow(clippy::too_many_arguments)]
pub fn build_training_data(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster: &SimCluster,
    dims: &CubeDims,
    train_slices: &[usize],
    types: TypeSet,
    max_points: usize,
    window_lines: usize,
    labels: LabelSource,
) -> Result<TrainingData> {
    let mut samples = Vec::new();
    let mut gen_s = 0.0;
    let from_store = matches!(labels, LabelSource::Store(_));
    let per_slice = max_points.div_ceil(train_slices.len().max(1));
    for &train_slice in train_slices {
        let mut slice_taken = 0usize;
        for window in dims.windows(train_slice, window_lines) {
            if slice_taken >= per_slice || samples.len() >= max_points {
                break;
            }
            let lw: LoadedWindow = loader::load_window(reader, cache, backend, cluster, window)?;
            let take = (per_slice - slice_taken)
                .min(max_points - samples.len())
                .min(lw.n_points());
            let t0 = std::time::Instant::now();
            let window_labels: Vec<usize> = match labels {
                LabelSource::Refit => {
                    let values = &lw.obs.data[..take * lw.obs.n_obs];
                    let out = backend.run_fit_all(values, take, lw.obs.n_obs, types.n_types())?;
                    (0..take).map(|p| out.row(p)[0] as usize).collect()
                }
                LabelSource::Store(engine) => {
                    let q = RegionQuery {
                        z: train_slice,
                        x0: 0,
                        x1: dims.nx - 1,
                        y0: window.y0,
                        y1: window.y0 + window.lines - 1,
                    };
                    let recs = engine.region(&q)?;
                    if recs.len() < take {
                        return Err(PdfflowError::Format(format!(
                            "store run {} holds {} records for slice {train_slice} lines \
                             {}..{}, training needs {take}",
                            engine.store().run_key().label(),
                            recs.len(),
                            q.y0,
                            q.y1
                        )));
                    }
                    // Region scans return point-id order == window point
                    // order; pin that before trusting the labels.
                    let mut out = Vec::with_capacity(take);
                    for (p, rec) in recs[..take].iter().enumerate() {
                        if rec.point != lw.obs.point_ids[p] {
                            return Err(PdfflowError::Format(format!(
                                "store row mismatch at training point {p}: store {:?}, \
                                 window {:?}",
                                rec.point, lw.obs.point_ids[p]
                            )));
                        }
                        out.push(rec.dist.id());
                    }
                    out
                }
            };
            gen_s += t0.elapsed().as_secs_f64();
            for (p, &label) in window_labels.iter().enumerate() {
                let (mean, std) = lw.mean_std(p);
                samples.push(Sample {
                    features: vec![mean, std],
                    label,
                });
            }
            slice_taken += take;
        }
    }
    Ok(TrainingData {
        samples,
        generation_real_s: gen_s,
        from_store,
    })
}

/// A trained model plus the paper's quality/tuning metadata.
pub struct TrainedModel {
    pub tree: DecisionTree,
    /// Wrong-prediction rate on the held-out test split (§5.3.1).
    pub model_error: f64,
    pub params: TreeParams,
    pub train_real_s: f64,
    pub n_train: usize,
    pub n_test: usize,
    /// True when the training labels were read back from a store run.
    pub from_store: bool,
}

/// Train with fixed hyper-parameters on a random train/test split
/// (paper: hypers are tuned once and reused across datasets).
pub fn train_model(data: &TrainingData, params: TreeParams, seed: u64) -> Result<TrainedModel> {
    let mut idx: Vec<usize> = (0..data.samples.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let split = (idx.len() * 8) / 10;
    let train: Vec<Sample> = idx[..split].iter().map(|&i| data.samples[i].clone()).collect();
    let test: Vec<Sample> = idx[split..].iter().map(|&i| data.samples[i].clone()).collect();
    let t0 = std::time::Instant::now();
    let tree = DecisionTree::train(&train, params)?;
    let train_real_s = t0.elapsed().as_secs_f64();
    let model_error = tree.error_rate(&test);
    Ok(TrainedModel {
        tree,
        model_error,
        params,
        train_real_s,
        n_train: train.len(),
        n_test: test.len(),
        from_store: data.from_store,
    })
}

/// The paper's hyper-parameter tuning (§5.3.1): grid over depth × maxBins
/// on a train/validation split. Returns the chosen params + tuning time.
pub fn tune_hypers(data: &TrainingData, seed: u64) -> Result<(TreeParams, f64, f64)> {
    let t0 = std::time::Instant::now();
    let (params, err) = mltree::tune(
        &data.samples,
        &[2, 3, 4, 6, 8, 10, 12],
        &[4, 8, 16, 32, 64],
        seed,
    )?;
    Ok((params, err, t0.elapsed().as_secs_f64()))
}
