//! The paper's PDF-computation methods (Algorithm 1's `Select` +
//! `ComputePDF&Error` bodies for Baseline / Grouping / Reuse / ML and
//! the ML combinations).
//!
//! All numeric work goes through the backend's batched kernels: Baseline
//! and Grouping run `run_fit_all` (compute every candidate type, argmin —
//! the O(T) cost of Algorithm 3), the ML paths run exactly one
//! `run_fit_single` per point (Algorithm 4's O(1) cost). The methods
//! differ *only* in which points reach the executor and over which
//! kernels — exactly the paper's design space.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::SimCluster;
use crate::coordinator::loader::LoadedWindow;
use crate::executor::Executor;
use crate::mltree::DecisionTree;
use crate::rdd::Rdd;
use crate::runtime::Backend;
use crate::stats::DistType;
use crate::{PdfflowError, Result};

/// The paper's methods (§5 / §6 naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Baseline,
    Grouping,
    Reuse,
    /// "ML" / "Baseline + ML" in the paper.
    Ml,
    GroupingMl,
    ReuseMl,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::Baseline,
        Method::Grouping,
        Method::Reuse,
        Method::Ml,
        Method::GroupingMl,
        Method::ReuseMl,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::Grouping => "grouping",
            Method::Reuse => "reuse",
            Method::Ml => "ml",
            Method::GroupingMl => "grouping+ml",
            Method::ReuseMl => "reuse+ml",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        Self::ALL.into_iter().find(|m| m.name() == s)
    }

    pub fn uses_grouping(self) -> bool {
        matches!(
            self,
            Method::Grouping | Method::Reuse | Method::GroupingMl | Method::ReuseMl
        )
    }

    pub fn uses_reuse(self) -> bool {
        matches!(self, Method::Reuse | Method::ReuseMl)
    }

    pub fn uses_ml(self) -> bool {
        matches!(self, Method::Ml | Method::GroupingMl | Method::ReuseMl)
    }
}

/// Candidate distribution sets (paper §6.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TypeSet {
    Four,
    Ten,
}

impl TypeSet {
    pub fn n_types(self) -> usize {
        match self {
            TypeSet::Four => 4,
            TypeSet::Ten => 10,
        }
    }

    pub fn candidates(self) -> &'static [DistType] {
        match self {
            TypeSet::Four => &DistType::FOUR,
            TypeSet::Ten => &DistType::ALL,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TypeSet::Four => "4-types",
            TypeSet::Ten => "10-types",
        }
    }
}

/// The fitted PDF of one point (the paper's persisted key-value value).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FitOutcome {
    pub dist: DistType,
    pub error: f32,
    pub params: [f32; 3],
}

impl FitOutcome {
    fn from_fit_all_row(row: &[f32]) -> FitOutcome {
        FitOutcome {
            dist: DistType::from_id(row[0] as usize).unwrap_or(DistType::Normal),
            error: row[1],
            params: [row[2], row[3], row[4]],
        }
    }

    fn from_fit_single_row(dist: DistType, row: &[f32]) -> FitOutcome {
        FitOutcome {
            dist,
            error: row[0],
            params: [row[1], row[2], row[3]],
        }
    }
}

/// Cross-window reuse cache (§5.2.1): quantized (mean, std) → outcome.
/// Internally synchronized (mutexed map + atomic meters) so a shared
/// `&ReuseCache` can cross window-task boundaries; the *pipeline* still
/// serializes reuse-method fits in window order, because whether window
/// N+1 hits depends on window N having fitted first.
#[derive(Debug, Default)]
pub struct ReuseCache {
    map: Mutex<HashMap<(i64, i64), FitOutcome>>,
    lookups: AtomicU64,
    hits: AtomicU64,
}

impl ReuseCache {
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.lock().unwrap().is_empty()
    }

    /// Metered lookup (counts the lookup, and the hit when found).
    pub fn lookup(&self, key: &(i64, i64)) -> Option<FitOutcome> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let hit = self.map.lock().unwrap().get(key).copied();
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub fn insert(&self, key: (i64, i64), outcome: FitOutcome) {
        self.map.lock().unwrap().insert(key, outcome);
    }

    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}

/// Result of fitting one window.
#[derive(Clone, Debug)]
pub struct WindowFit {
    /// One outcome per window point, in point-id order.
    pub outcomes: Vec<FitOutcome>,
    pub real_s: f64,
    pub sim_s: f64,
    /// Points actually sent to the executor.
    pub fits: usize,
    /// Distinct groups (grouping methods; == points otherwise).
    pub groups: usize,
    pub reuse_hits: usize,
    pub shuffle_bytes: u64,
}

/// Quantize a feature to the grouping grid (§5.2: identical mean/std, up
/// to an epsilon appropriate for f32-computed statistics).
pub fn quantize(v: f64, quantum: f64) -> i64 {
    (v / quantum).round() as i64
}

/// A group of points sharing a quantized (mean, std) key.
#[derive(Clone, Debug)]
pub struct Group {
    pub key: (i64, i64),
    /// Representative index (within the window's point order).
    pub rep: usize,
    pub members: Vec<usize>,
}

/// Group the window's points with the Spark `aggregateByKey` analog
/// (partition tasks submitted to `exec`); returns groups plus the
/// shuffled-byte count charged to the cluster.
pub fn group_points(
    lw: &LoadedWindow,
    quantum: f64,
    partitions: usize,
    exec: &Executor,
    cluster: &SimCluster,
) -> (Vec<Group>, u64) {
    let n = lw.n_points();
    let obs_row_bytes = (lw.obs.n_obs * 4) as u64;
    let items: Vec<((i64, i64), usize)> = (0..n)
        .map(|p| {
            let (m, s) = lw.mean_std(p);
            ((quantize(m, quantum), quantize(s, quantum)), p)
        })
        .collect();
    let rdd = Rdd::from_vec(items, partitions.max(1));
    let (grouped, shuffle_bytes) = rdd.aggregate_by_key(
        partitions.max(1),
        exec,
        cluster,
        "fit.shuffle",
        |p| vec![p],
        |c, p| c.push(p),
        |c, mut o| c.append(&mut o),
        // A combiner ships the representative observation vector once
        // plus a (point id, key) record per member — the payload that
        // makes Grouping collapse on big vectors (paper Fig. 19).
        |_k, c| obs_row_bytes + 16 * c.len() as u64,
    );
    let mut groups: Vec<Group> = grouped
        .collect(exec)
        .into_iter()
        .map(|(key, mut members)| {
            members.sort_unstable();
            Group {
                key,
                rep: members[0],
                members,
            }
        })
        .collect();
    // Deterministic order (hash maps scramble it).
    groups.sort_by_key(|g| g.rep);
    (groups, shuffle_bytes)
}

/// Gather selected observation rows into a compact point-major matrix.
fn gather_rows(lw: &LoadedWindow, idx: &[usize]) -> Vec<f32> {
    let n_obs = lw.obs.n_obs;
    let mut out = Vec::with_capacity(idx.len() * n_obs);
    for &p in idx {
        out.extend_from_slice(lw.obs.point_row(p));
    }
    out
}

/// Simulated fit-stage charge: the paper fits each point in its own Map
/// task by launching an external R process (§4.2 principle 5), so the
/// simulated stage runs one task per point, costing the emulated
/// external-fitter price per candidate type plus this host's real
/// per-point share of the AOT execution.
fn charge_fit_stage(
    cluster: &SimCluster,
    n_points: usize,
    types_fitted: usize,
    real_s: f64,
) -> f64 {
    if n_points == 0 {
        return 0.0;
    }
    let per_point =
        cluster.spec.fit_cost_per_point_type * types_fitted as f64 + real_s / n_points as f64;
    cluster.run_stage("fit.compute", &vec![per_point; n_points])
}

/// Run `fit_all` on a set of points, returning outcomes + timing, and
/// charging the simulated stage.
fn fit_all_points(
    backend: &dyn Backend,
    cluster: &SimCluster,
    lw: &LoadedWindow,
    idx: &[usize],
    types: TypeSet,
) -> Result<(Vec<FitOutcome>, f64)> {
    if idx.is_empty() {
        return Ok((Vec::new(), 0.0));
    }
    let values = gather_rows(lw, idx);
    let t0 = Instant::now();
    let out = backend.run_fit_all(&values, idx.len(), lw.obs.n_obs, types.n_types())?;
    let real = t0.elapsed().as_secs_f64();
    charge_fit_stage(cluster, idx.len(), types.n_types(), real);
    let outcomes = (0..idx.len())
        .map(|i| FitOutcome::from_fit_all_row(out.row(i)))
        .collect();
    Ok((outcomes, real))
}

/// Run single-type fits on points partitioned by the tree's prediction
/// (Algorithm 4). Returns outcomes aligned with `idx` order.
fn fit_ml_points(
    backend: &dyn Backend,
    cluster: &SimCluster,
    lw: &LoadedWindow,
    idx: &[usize],
    types: TypeSet,
    tree: &DecisionTree,
) -> Result<(Vec<FitOutcome>, f64)> {
    if idx.is_empty() {
        return Ok((Vec::new(), 0.0));
    }
    // Predict each point's type from (mean, std); clamp stray labels into
    // the candidate set (a tree trained on 10-types may emit ids the
    // 4-types run cannot fit — the paper's setups never mix them, but the
    // runtime should not crash if a user does).
    let n_types = types.n_types();
    let mut by_type: Vec<Vec<usize>> = vec![Vec::new(); 10];
    let t0 = Instant::now();
    for (slot, &p) in idx.iter().enumerate() {
        let (m, s) = lw.mean_std(p);
        let label = tree.predict(&[m, s]).min(n_types - 1);
        by_type[label].push(slot);
    }
    let mut outcomes = vec![
        FitOutcome {
            dist: DistType::Normal,
            error: f32::NAN,
            params: [0.0; 3],
        };
        idx.len()
    ];
    let mut real_total = t0.elapsed().as_secs_f64();
    for (tid, slots) in by_type.iter().enumerate() {
        if slots.is_empty() {
            continue;
        }
        let dist = DistType::from_id(tid).unwrap();
        let point_idx: Vec<usize> = slots.iter().map(|&s| idx[s]).collect();
        let values = gather_rows(lw, &point_idx);
        let t1 = Instant::now();
        let out = backend.run_fit_single(&values, point_idx.len(), lw.obs.n_obs, dist)?;
        let real = t1.elapsed().as_secs_f64();
        real_total += real;
        charge_fit_stage(cluster, point_idx.len(), 1, real);
        for (i, &slot) in slots.iter().enumerate() {
            outcomes[slot] = FitOutcome::from_fit_single_row(dist, out.row(i));
        }
    }
    Ok((outcomes, real_total))
}

/// Fit one loaded window with the chosen method (Algorithm 1 body).
///
/// `cluster` should be this window's *scratch* session when windows run
/// concurrently: `sim_s` is derived from the ledger delta, so sharing a
/// ledger across in-flight windows would cross-charge them. The pipeline
/// merges scratches in window order afterwards.
#[allow(clippy::too_many_arguments)]
pub fn fit_window(
    backend: &dyn Backend,
    cluster: &SimCluster,
    exec: &Executor,
    method: Method,
    types: TypeSet,
    lw: &LoadedWindow,
    tree: Option<&DecisionTree>,
    reuse: &ReuseCache,
    quantum: f64,
    partitions: usize,
) -> Result<WindowFit> {
    if method.uses_ml() && tree.is_none() {
        return Err(PdfflowError::InvalidArg(format!(
            "method {} requires a trained decision tree",
            method.name()
        )));
    }
    let n = lw.n_points();
    let wall = Instant::now();
    let sim_before = cluster.total();

    let (outcomes, fits, groups, reuse_hits, shuffle_bytes) = if !method.uses_grouping() {
        // Baseline / ML: every point goes to the executor.
        let idx: Vec<usize> = (0..n).collect();
        let (outs, _real) = if method.uses_ml() {
            fit_ml_points(backend, cluster, lw, &idx, types, tree.unwrap())?
        } else {
            fit_all_points(backend, cluster, lw, &idx, types)?
        };
        (outs, n, n, 0, 0)
    } else {
        // Grouping / Reuse (± ML): aggregate, fit representatives only.
        let (groups, shuffle_bytes) = group_points(lw, quantum, partitions, exec, cluster);
        let mut rep_outcomes: Vec<Option<FitOutcome>> = vec![None; groups.len()];
        let mut to_fit: Vec<usize> = Vec::new(); // group indices
        let mut hits = 0usize;
        if method.uses_reuse() {
            for (gi, g) in groups.iter().enumerate() {
                if let Some(hit) = reuse.lookup(&g.key) {
                    hits += 1;
                    rep_outcomes[gi] = Some(hit);
                } else {
                    to_fit.push(gi);
                }
            }
        } else {
            to_fit = (0..groups.len()).collect();
        }
        let rep_idx: Vec<usize> = to_fit.iter().map(|&gi| groups[gi].rep).collect();
        let (fitted, _real) = if method.uses_ml() {
            fit_ml_points(backend, cluster, lw, &rep_idx, types, tree.unwrap())?
        } else {
            fit_all_points(backend, cluster, lw, &rep_idx, types)?
        };
        let fits = rep_idx.len();
        for (i, &gi) in to_fit.iter().enumerate() {
            rep_outcomes[gi] = Some(fitted[i]);
            if method.uses_reuse() {
                reuse.insert(groups[gi].key, fitted[i]);
            }
        }
        if method.uses_reuse() && !to_fit.is_empty() {
            // New results are collected at the driver and re-broadcast to
            // the workers for the next window's lookups (§5.2.1 overhead).
            cluster.charge_broadcast("fit.reuse", 24 * to_fit.len() as u64);
        }
        // Scatter representative outcomes to all group members.
        let mut outs = vec![
            FitOutcome {
                dist: DistType::Normal,
                error: f32::NAN,
                params: [0.0; 3],
            };
            n
        ];
        let n_groups = groups.len();
        for (gi, g) in groups.into_iter().enumerate() {
            let o = rep_outcomes[gi].expect("every group resolved");
            for m in g.members {
                outs[m] = o;
            }
        }
        (outs, fits, n_groups, hits, shuffle_bytes)
    };

    debug_assert!(outcomes.iter().all(|o| !o.error.is_nan()));
    Ok(WindowFit {
        outcomes,
        real_s: wall.elapsed().as_secs_f64(),
        sim_s: cluster.total() - sim_before,
        fits,
        groups,
        reuse_hits,
        shuffle_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn method_predicates() {
        assert!(!Method::Baseline.uses_grouping());
        assert!(!Method::Baseline.uses_ml());
        assert!(Method::Grouping.uses_grouping() && !Method::Grouping.uses_ml());
        assert!(Method::Reuse.uses_reuse() && Method::Reuse.uses_grouping());
        assert!(Method::Ml.uses_ml() && !Method::Ml.uses_grouping());
        assert!(Method::GroupingMl.uses_grouping() && Method::GroupingMl.uses_ml());
        assert!(Method::ReuseMl.uses_reuse() && Method::ReuseMl.uses_ml());
    }

    #[test]
    fn typeset_candidates() {
        assert_eq!(TypeSet::Four.candidates().len(), 4);
        assert_eq!(TypeSet::Ten.candidates().len(), 10);
        assert_eq!(TypeSet::Four.n_types(), 4);
    }

    #[test]
    fn quantize_groups_nearby_values() {
        assert_eq!(quantize(1.0000001, 1e-6), quantize(1.0000004, 1e-6));
        assert_ne!(quantize(1.0, 1e-6), quantize(1.1, 1e-6));
        assert_eq!(quantize(-3.5, 1e-6), quantize(-3.5, 1e-6));
    }
}
