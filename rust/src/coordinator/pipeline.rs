//! Algorithm 1: the sliding-window pipeline driver.
//!
//! `Pipeline::run_slice` pipelines the slice's windows through the
//! staged [`crate::executor`]: each window is one task (load, Algorithm
//! 2, then — for non-reuse methods — method-specific select + fit,
//! Algorithms 3/4), up to `executor_threads` windows in flight at once.
//! All of it — window tasks, the backend's chunk fan-out nested inside
//! them, RDD partition tasks — draws from the one shared
//! [`crate::runtime::hostpool`] budget, so the knobs cap widths rather
//! than multiply thread counts.
//! Results flow through the executor's *sequenced sink*, so persist
//! (Algorithm 1 line 11) always appends windows in slice order, and
//! every result-derived value — outcomes, errors, fit/group/shuffle
//! counts, persisted bytes, byte-derived sim accounts (nfs / shuffle /
//! persist) — is identical at any thread count. (The `*_sim_s` stage
//! columns fed by *measured* wall-clock, like `fit.compute`, vary run
//! to run the way any timing does.) Reuse-method fits stay in the
//! ordered sink (window N+1's cache hits depend on window N having
//! fitted), so only their loads overlap. Every window charges a
//! private scratch [`SimCluster`] merged in window order — both clocks —
//! real wall-clock on this host and simulated cluster time — stay
//! attributable per phase, which is how the paper's figures separate
//! "data loading" from "PDF computation".
//!
//! Persistence has two sinks: the legacy flat `.pdfout` file
//! (`persist_dir`) and the indexed, queryable [`crate::pdfstore`] store
//! (`store_dir`) that `pdfflow query` serves from — both write through
//! the [`crate::pdfstore::PdfRecord`] codec, so their bytes cannot
//! drift. Persisted bytes are charged to the simulated cluster like any
//! other data path (`persist.nfs` account) and reported per window/slice.

use std::sync::Mutex;

use crate::cluster::{ClusterSpec, SimCluster};
use crate::config::PipelineConfig;
use crate::coordinator::loader::{self, LoadedWindow};
use crate::coordinator::methods::{self, FitOutcome, Method, ReuseCache, TypeSet, WindowFit};
use crate::coordinator::mlmodel;
use crate::cube::Window;
use crate::datagen::SyntheticDataset;
use crate::executor::{Executor, StageMetrics};
use crate::mltree::DecisionTree;
use crate::pdfstore::{PdfRecord, RunKey, SegmentWriter, StoreWriter, DEFAULT_RUN_ID, REC_LEN};
use crate::runtime::hostpool::HostPool;
use crate::runtime::Backend;
use crate::storage::{CacheStats, DatasetReader, WindowCache};
use crate::{PdfflowError, Result};

/// Per-window accounting.
#[derive(Clone, Debug)]
pub struct WindowReport {
    pub window: Window,
    pub n_points: usize,
    pub groups: usize,
    pub fits: usize,
    pub reuse_hits: usize,
    pub shuffle_bytes: u64,
    /// True when the observation matrix came from the window cache.
    pub cache_hit: bool,
    /// Bytes persisted for this window (all sinks).
    pub persist_bytes: u64,
    /// Simulated cluster time charged for persisting those bytes.
    pub persist_sim_s: f64,
    pub load_real_s: f64,
    pub load_sim_s: f64,
    pub fit_real_s: f64,
    pub fit_sim_s: f64,
    pub err_sum: f64,
}

/// Slice-level result (one paper data point).
#[derive(Clone, Debug)]
pub struct SliceReport {
    pub method: Method,
    pub types: TypeSet,
    pub slice: usize,
    pub n_points: usize,
    pub windows: Vec<WindowReport>,
    /// Eq. 6: average Eq.5 error over all slice points.
    pub avg_error: f64,
    pub load_real_s: f64,
    pub load_sim_s: f64,
    pub fit_real_s: f64,
    pub fit_sim_s: f64,
    pub fits: usize,
    pub groups: usize,
    pub reuse_hits: usize,
    pub shuffle_bytes: u64,
    /// Windows served from the window cache vs loaded from "NFS".
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Bytes persisted over the whole slice (all sinks).
    pub persist_bytes: u64,
    /// Simulated cluster time charged for persisting.
    pub persist_sim_s: f64,
    /// Window-stage executor metrics (queue depth, tasks, busy time) —
    /// surfaced by verbose reports; timings vary run to run.
    pub exec: StageMetrics,
}

impl SliceReport {
    pub fn total_real_s(&self) -> f64 {
        self.load_real_s + self.fit_real_s
    }

    pub fn total_sim_s(&self) -> f64 {
        self.load_sim_s + self.fit_sim_s + self.persist_sim_s
    }

    /// FNV-64 over the deterministic face of the report: every field
    /// that must not depend on executor width, backend chunking, or
    /// SIMD dispatch (times are measurements and are excluded), folded
    /// per window in window order. Two runs over the same dataset and
    /// method must agree bit-for-bit; `pdfflow run` stamps this into
    /// `--metrics-out` snapshots (`provenance.report_fingerprint`) so
    /// perf before/after pairs carry a checkable no-behavior-change
    /// witness.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(80 + 56 * self.windows.len());
        let mut push = |v: u64| bytes.extend_from_slice(&v.to_le_bytes());
        push(self.avg_error.to_bits());
        push(self.n_points as u64);
        push(self.fits as u64);
        push(self.groups as u64);
        push(self.reuse_hits as u64);
        push(self.shuffle_bytes);
        push(self.persist_bytes);
        push(self.cache_hits as u64);
        push(self.cache_misses as u64);
        for w in &self.windows {
            push(w.n_points as u64);
            push(w.fits as u64);
            push(w.groups as u64);
            push(w.reuse_hits as u64);
            push(w.shuffle_bytes);
            push(w.persist_bytes);
            push(w.err_sum.to_bits());
        }
        crate::pdfstore::fnv64(&bytes)
    }

    /// One human-readable summary row (bench drivers print these).
    pub fn row(&self) -> String {
        format!(
            "{:<12} {:<8} load {:>8.2}s/{:>8.2}s  fit {:>8.3}s/{:>8.3}s  E {:.4}  fits {:>6}  groups {:>6}  hits {:>5}  shuffle {:>10}B  wcache {}/{}  persist {}B",
            self.method.name(),
            self.types.name(),
            self.load_real_s,
            self.load_sim_s,
            self.fit_real_s,
            self.fit_sim_s,
            self.avg_error,
            self.fits,
            self.groups,
            self.reuse_hits,
            self.shuffle_bytes,
            self.cache_hits,
            self.cache_misses,
            self.persist_bytes,
        )
    }
}

/// The pipeline: dataset + backend + simulated cluster + caches + model.
pub struct Pipeline<'a> {
    reader: DatasetReader<'a>,
    backend: &'a dyn Backend,
    pub cluster: SimCluster,
    pub cfg: PipelineConfig,
    cache: WindowCache,
    reuse: ReuseCache,
    /// Lazily opened pdfstore writer (when `cfg.store_dir` is set).
    store: Option<StoreWriter>,
    pub tree: Option<DecisionTree>,
    pub model_error: Option<f64>,
    /// True when the current tree's training labels were read back from
    /// a prior store run instead of refit (ROADMAP's store-backed tree
    /// training).
    pub tree_from_store: bool,
}

impl<'a> Pipeline<'a> {
    pub fn new(
        dataset: &'a SyntheticDataset,
        backend: &'a dyn Backend,
        cluster: SimCluster,
        cfg: PipelineConfig,
    ) -> Pipeline<'a> {
        let cache = WindowCache::new(cfg.cache_bytes);
        Pipeline {
            reader: DatasetReader::new(dataset),
            backend,
            cluster,
            cfg,
            cache,
            reuse: ReuseCache::default(),
            store: None,
            tree: None,
            model_error: None,
            tree_from_store: false,
        }
    }

    /// The compute backend this pipeline fits with.
    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    pub fn dataset(&self) -> &SyntheticDataset {
        self.reader.dataset()
    }

    fn partitions(&self) -> usize {
        self.cfg
            .partitions
            .unwrap_or_else(|| self.cluster.spec.total_slots())
    }

    /// Train (or re-train) the decision tree from `train_slice`'s full-fit
    /// output (paper §5.3.1; tree generation is *not* part of the measured
    /// PDF-computation time). When `cfg.store_dir` holds a matching prior
    /// full-fit run, the training labels are read back through the store's
    /// `QueryEngine` instead of refit (`tree_from_store` records which
    /// path ran; the samples — and so the tree — are identical either
    /// way). Returns the model error.
    pub fn ensure_tree(
        &mut self,
        train_slice: usize,
        types: TypeSet,
        max_points: usize,
    ) -> Result<f64> {
        if let Some(e) = self.model_error {
            if self.tree.is_some() {
                return Ok(e);
            }
        }
        let model = train_tree_model(
            &self.reader,
            &self.cache,
            self.backend,
            self.cluster.spec.clone(),
            train_slice,
            types,
            max_points,
            self.cfg.window_lines,
            self.cfg.store_dir.as_deref(),
        )?;
        self.model_error = Some(model.model_error);
        self.tree_from_store = model.from_store;
        self.tree = Some(model.tree);
        Ok(model.model_error)
    }

    /// Install an externally trained tree (e.g. loaded from JSON).
    pub fn set_tree(&mut self, tree: DecisionTree) {
        self.tree = Some(tree);
        self.model_error = None;
        self.tree_from_store = false;
    }

    /// Run the full slice (paper's "Execution of One Slice").
    pub fn run_slice(&mut self, method: Method, slice: usize, types: TypeSet) -> Result<SliceReport> {
        let dims = self.reader.dataset().spec.dims;
        self.run_windows(method, types, dims.windows(slice, self.cfg.window_lines), slice)
    }

    /// [`run_slice`] that overlaps decision-tree training with the
    /// run's first-window loads (ROADMAP follow-up): when `method`
    /// needs a tree and none is trained yet, the training-data
    /// generation runs as one task on the shared [`HostPool`] while
    /// sibling tasks warm the window cache with the slice's first
    /// windows. Both are *unmeasured* setup (the paper keeps tree
    /// generation out of the measured PDF-computation time), so the
    /// measured run starts with its first windows hot — results are
    /// identical to `ensure_tree()` + `run_slice()`, only the
    /// cache-hit/NFS columns shift from the measured run into setup.
    pub fn run_slice_overlapped(
        &mut self,
        method: Method,
        slice: usize,
        types: TypeSet,
        train_slice: usize,
        max_points: usize,
    ) -> Result<SliceReport> {
        let dims = self.reader.dataset().spec.dims;
        let windows = dims.windows(slice, self.cfg.window_lines);
        if method.uses_ml() && self.tree.is_none() {
            let k = windows.len().min(self.cfg.executor_threads.max(1));
            let trained: Mutex<Option<Result<mlmodel::TrainedModel>>> = Mutex::new(None);
            {
                let reader = &self.reader;
                let cache = &self.cache;
                let backend = self.backend;
                let spec = self.cluster.spec.clone();
                let window_lines = self.cfg.window_lines;
                let store_dir = self.cfg.store_dir.clone();
                // Prefetch charges go to a throwaway ledger: warm-up is
                // setup, like training itself.
                let prefetch_cluster = SimCluster::new(spec.clone());
                let warm = &windows[..k];
                let trained = &trained;
                let store_dir = &store_dir;
                let task = |i: usize| {
                    if i == 0 {
                        let r = train_tree_model(
                            reader,
                            cache,
                            backend,
                            spec.clone(),
                            train_slice,
                            types,
                            max_points,
                            window_lines,
                            store_dir.as_deref(),
                        );
                        *trained.lock().unwrap() = Some(r);
                    } else {
                        // Best-effort warm; a failing load resurfaces in
                        // the measured run below.
                        let _ = loader::load_window(
                            reader,
                            cache,
                            backend,
                            &prefetch_cluster,
                            warm[i - 1],
                        );
                    }
                };
                HostPool::global().scope_run(1 + k, 1 + k, &task);
            }
            let model = trained.into_inner().unwrap().expect("training task ran")?;
            self.model_error = Some(model.model_error);
            self.tree_from_store = model.from_store;
            self.tree = Some(model.tree);
        }
        self.run_windows(method, types, windows, slice)
    }

    /// Run only the first `lines` lines of a slice (the paper's "small
    /// workload": 6 lines / 3006 points of Slice 201).
    pub fn run_lines(
        &mut self,
        method: Method,
        slice: usize,
        types: TypeSet,
        lines: usize,
    ) -> Result<SliceReport> {
        let dims = self.reader.dataset().spec.dims;
        let lines = lines.min(dims.ny);
        let windows: Vec<Window> = dims
            .windows(slice, self.cfg.window_lines)
            .into_iter()
            .filter(|w| w.y0 + w.lines <= lines)
            .collect();
        if windows.is_empty() {
            return Err(PdfflowError::InvalidArg(format!(
                "lines {lines} smaller than one window ({})",
                self.cfg.window_lines
            )));
        }
        self.run_windows(method, types, windows, slice)
    }

    fn run_windows(
        &mut self,
        method: Method,
        types: TypeSet,
        windows: Vec<Window>,
        slice: usize,
    ) -> Result<SliceReport> {
        if method.uses_ml() && self.tree.is_none() {
            return Err(PdfflowError::InvalidArg(format!(
                "method {} needs ensure_tree() first",
                method.name()
            )));
        }
        // Backend warm-up (PJRT compilation for XLA, no-op for native)
        // happens once here, never inside the measured stages (Spark
        // analog: executor JVM/code-gen warm-up).
        self.backend
            .warm_all_for(self.reader.dataset().spec.n_sims)?;
        // Reuse results never leak between experiment runs.
        self.reuse = ReuseCache::default();
        let partitions = self.partitions();
        let quantum = self.cfg.group_quantum;
        let mut persist = self.open_persist(method, types, slice)?;
        let mut segment = self.open_store_segment(method, types, slice)?;
        let mut reports: Vec<WindowReport> = Vec::with_capacity(windows.len());

        // Stage split: worker tasks share the pipeline's read side, the
        // sequenced sink owns the write side (ordered persist, report
        // vector, shared-ledger merge). With adaptive batching on, the
        // stage width is clamped to the window count and the shared
        // pool budget — wider fan-out cannot run more tasks than either
        // bound allows, it only deepens the queue the backend's own
        // adaptive fan-out then has to share. Results are pinned
        // thread-count invariant, so the clamp is a pure scheduling
        // choice; `pipeline.adaptive_batch = false` keeps the raw knob.
        let exec_width = if self.cfg.adaptive_batch {
            self.cfg
                .executor_threads
                .min(windows.len().max(1))
                .min(crate::runtime::HostPool::global().budget())
                .max(1)
        } else {
            self.cfg.executor_threads
        };
        let exec = Executor::new(exec_width);
        let exec_ref = &exec;
        let reader = &self.reader;
        let cache = &self.cache;
        let backend = self.backend;
        let tree = self.tree.as_ref();
        let reuse = &self.reuse;
        let cluster = &self.cluster;
        let spec = cluster.spec.clone();
        // Reuse-method fits must see windows in order (window N seeds
        // window N+1's cache); other methods fit inside the parallel task.
        let fit_in_task = !method.uses_reuse();

        /// One window's parallel-stage output, en route to the sink.
        struct Staged {
            window: Window,
            lw: LoadedWindow,
            fit: Option<WindowFit>,
            /// Private ledger: everything this window charged.
            scratch: SimCluster,
        }

        let mut stage = StageMetrics::default();
        let span_stage = crate::span!("stage", "{} slice {slice}", method.name());
        exec.run_sequenced_metered(
            windows,
            |window| -> Result<Staged> {
                let _span = crate::span!("window", "z{} y0 {}", window.z, window.y0);
                let scratch = SimCluster::new(spec.clone());
                let lw = {
                    let _s = crate::span!("load", "y0 {}", window.y0);
                    loader::load_window(reader, cache, backend, &scratch, window)?
                };
                let fit = if fit_in_task {
                    // Window-level parallelism already fills the stage
                    // width, so the nested RDD stages run sequentially.
                    // The backend's chunk fan-out inside this task draws
                    // from the same shared HostPool budget as the window
                    // tasks themselves — knobs cap widths, they no
                    // longer multiply thread counts.
                    let _s = crate::span!("fit", "y0 {}", window.y0);
                    Some(methods::fit_window(
                        backend,
                        &scratch,
                        &Executor::sequential(),
                        method,
                        types,
                        &lw,
                        tree,
                        reuse,
                        quantum,
                        partitions,
                    )?)
                } else {
                    None
                };
                Ok(Staged {
                    window,
                    lw,
                    fit,
                    scratch,
                })
            },
            |_idx, staged| {
                let Staged {
                    window,
                    lw,
                    fit,
                    scratch,
                } = staged;
                let fit = match fit {
                    Some(fit) => fit,
                    None => {
                        // Reuse-method fits run here in the ordered sink.
                        let _s = crate::span!("fit", "y0 {}", window.y0);
                        methods::fit_window(
                            backend, &scratch, exec_ref, method, types, &lw, tree, reuse,
                            quantum, partitions,
                        )?
                    }
                };
                let mut persist_bytes = 0u64;
                {
                    let _s = crate::span!("persist", "y0 {}", window.y0);
                    if let Some(f) = persist.as_mut() {
                        persist_bytes += persist_window(f, &lw.obs.point_ids, &fit.outcomes)?;
                    }
                    if let Some(sw) = segment.as_mut() {
                        persist_bytes +=
                            sw.append_window(&window, &lw.obs.point_ids, &fit.outcomes)?;
                    }
                }
                // Persisted output travels back to the shared store: charge
                // it like any other data path (one append batch per sink).
                let persist_sim_s = if persist_bytes > 0 {
                    let sinks = persist.is_some() as u64 + segment.is_some() as u64;
                    scratch.charge_persist("persist.nfs", persist_bytes, sinks)
                } else {
                    0.0
                };
                // Window-order merge: byte-derived ledger accounts end up
                // identical at any executor thread count (time-derived
                // stage accounts vary with measured wall-clock, as ever).
                cluster.merge(&scratch);
                let err_sum: f64 = fit.outcomes.iter().map(|o| o.error as f64).sum();
                reports.push(WindowReport {
                    window,
                    n_points: lw.n_points(),
                    groups: fit.groups,
                    fits: fit.fits,
                    reuse_hits: fit.reuse_hits,
                    shuffle_bytes: fit.shuffle_bytes,
                    cache_hit: lw.cache_hit,
                    persist_bytes,
                    persist_sim_s,
                    load_real_s: lw.real_s,
                    load_sim_s: lw.sim_s,
                    fit_real_s: fit.real_s,
                    fit_sim_s: fit.sim_s,
                    err_sum,
                });
                Ok(())
            },
            &mut stage,
        )?;
        drop(span_stage);
        if let Some(sw) = segment {
            let meta = sw.finish()?;
            self.store
                .as_mut()
                .expect("segment implies store writer")
                .add_segment(meta)?;
        }
        let n_points: usize = reports.iter().map(|w| w.n_points).sum();
        let err_total: f64 = reports.iter().map(|w| w.err_sum).sum();
        Ok(SliceReport {
            method,
            types,
            slice,
            n_points,
            avg_error: if n_points > 0 { err_total / n_points as f64 } else { 0.0 },
            load_real_s: reports.iter().map(|w| w.load_real_s).sum(),
            load_sim_s: reports.iter().map(|w| w.load_sim_s).sum(),
            fit_real_s: reports.iter().map(|w| w.fit_real_s).sum(),
            fit_sim_s: reports.iter().map(|w| w.fit_sim_s).sum(),
            fits: reports.iter().map(|w| w.fits).sum(),
            groups: reports.iter().map(|w| w.groups).sum(),
            reuse_hits: reports.iter().map(|w| w.reuse_hits).sum(),
            shuffle_bytes: reports.iter().map(|w| w.shuffle_bytes).sum(),
            cache_hits: reports.iter().filter(|w| w.cache_hit).count(),
            cache_misses: reports.iter().filter(|w| !w.cache_hit).count(),
            persist_bytes: reports.iter().map(|w| w.persist_bytes).sum(),
            persist_sim_s: reports.iter().map(|w| w.persist_sim_s).sum(),
            exec: stage,
            windows: reports,
        })
    }

    fn open_persist(
        &self,
        method: Method,
        types: TypeSet,
        slice: usize,
    ) -> Result<Option<std::io::BufWriter<std::fs::File>>> {
        let Some(dir) = &self.cfg.persist_dir else {
            return Ok(None);
        };
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!(
            "slice{slice}_{}_{}.pdfout",
            method.name(),
            types.n_types()
        ));
        Ok(Some(std::io::BufWriter::new(std::fs::File::create(path)?)))
    }

    /// The run identity this pipeline stamps into every segment it
    /// persists: `(method, types, run_id)`, with `run_id` from
    /// `cfg.run_id` (`--run-id`) or the default.
    pub fn run_key(&self, method: Method, types: TypeSet) -> RunKey {
        let run_id = self.cfg.run_id.as_deref().unwrap_or(DEFAULT_RUN_ID);
        RunKey::new(method.name(), types.n_types(), run_id)
    }

    /// Open a pdfstore segment for this run when `cfg.store_dir` is set,
    /// lazily attaching the store writer on first use. The catalog
    /// assigns the generation, so a rerun of the same `(method, types,
    /// run_id, slice)` appends instead of overwriting.
    fn open_store_segment(
        &mut self,
        method: Method,
        types: TypeSet,
        slice: usize,
    ) -> Result<Option<SegmentWriter>> {
        let Some(dir) = self.cfg.store_dir.clone() else {
            return Ok(None);
        };
        if self.store.is_none() {
            let spec = &self.reader.dataset().spec;
            self.store = Some(StoreWriter::create(&dir, spec.dims, spec.n_sims)?);
        }
        let store = self.store.as_ref().expect("just created");
        let key = self.run_key(method, types);
        Ok(Some(store.open_segment(slice, &key)?))
    }

    /// The attached pdfstore writer, if this pipeline persists to one.
    pub fn store(&self) -> Option<&StoreWriter> {
        self.store.as_ref()
    }

    /// Window-cache statistics (hits/misses/evictions/bytes/entries).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn clear_cache(&self) {
        self.cache.clear()
    }

    pub fn reuse_stats(&self) -> (u64, u64, usize) {
        (self.reuse.lookups(), self.reuse.hits(), self.reuse.len())
    }
}

/// Tree-training body shared by [`Pipeline::ensure_tree`] and the
/// overlapped path in [`Pipeline::run_slice_overlapped`]: everything it
/// needs comes in explicitly so it can run as a pool task concurrent
/// with cache-prefetch tasks. Charges go to a scratch cluster — tree
/// generation is outside the measured pipeline.
#[allow(clippy::too_many_arguments)]
fn train_tree_model(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster_spec: ClusterSpec,
    train_slice: usize,
    types: TypeSet,
    max_points: usize,
    window_lines: usize,
    store_dir: Option<&str>,
) -> Result<mlmodel::TrainedModel> {
    let dims = reader.dataset().spec.dims;
    let scratch = SimCluster::new(cluster_spec);
    let slices = mlmodel::training_slices(&dims, train_slice, reader.dataset().spec.n_value_layers());
    // Store-backed training (ROADMAP follow-up): when the store already
    // holds a matching full-fit run, read the "previous output" back
    // instead of refitting it. Falls back to the refit path whenever the
    // store is absent, mismatched, or incomplete.
    let engine = mlmodel::store_label_engine(
        store_dir,
        &dims,
        reader.dataset().spec.n_sims,
        &slices,
        types,
    );
    let labels = match &engine {
        Some(e) => mlmodel::LabelSource::Store(e),
        None => mlmodel::LabelSource::Refit,
    };
    let data = mlmodel::build_training_data(
        reader,
        cache,
        backend,
        &scratch,
        &dims,
        &slices,
        types,
        max_points,
        window_lines,
        labels,
    )?;
    mlmodel::train_model(&data, Default::default(), 42)
}

/// Persist one window's outcomes as legacy flat rows — Algorithm 1 line
/// 11. Rows go through the [`PdfRecord`] codec (the same 28-byte wire
/// form the pdfstore segments use), so the two persist sinks cannot
/// drift; returns bytes written.
fn persist_window(
    f: &mut impl std::io::Write,
    ids: &[crate::cube::PointId],
    outcomes: &[FitOutcome],
) -> Result<u64> {
    if ids.len() != outcomes.len() {
        return Err(PdfflowError::InvalidArg(format!(
            "persist: {} ids vs {} outcomes",
            ids.len(),
            outcomes.len()
        )));
    }
    let mut buf = [0u8; REC_LEN];
    for (id, o) in ids.iter().zip(outcomes) {
        PdfRecord {
            point: *id,
            dist: o.dist,
            error: o.error,
            params: o.params,
        }
        .encode(&mut buf);
        f.write_all(&buf)?;
    }
    Ok((ids.len() * REC_LEN) as u64)
}
