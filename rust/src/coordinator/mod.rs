//! The coordinator — the paper's contribution, as a Rust L3 layer.
//!
//! * [`loader`] — Algorithm 2: sliding-window data loading from NFS with
//!   per-point statistics (the stats HLO artifact) and window caching;
//! * [`methods`] — the five PDF-computation methods and combinations:
//!   Baseline / Grouping / Reuse / ML (± ML), Algorithm 1/3/4 bodies;
//! * [`pipeline`] — the window driver: windows pipelined through the
//!   staged [`crate::executor`] (load → select → fit as parallel tasks,
//!   persist through the sequenced sink) → aggregate the slice error E,
//!   with real + simulated clocks;
//! * [`sampling`] — Algorithm 5: slice features from sampled points;
//! * [`mlmodel`] — training the decision tree from "previously generated
//!   output data" (paper §5.3.1).

pub mod loader;
pub mod methods;
pub mod mlmodel;
pub mod pipeline;
pub mod sampling;

pub use methods::{FitOutcome, Method, TypeSet};
pub use pipeline::{Pipeline, SliceReport, WindowReport};
pub use sampling::{Sampler, SamplingReport};
