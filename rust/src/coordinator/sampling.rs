//! Algorithm 5: the Sampling method — slice features from sampled points.
//!
//! Random sampling loads only the sampled points (positioned reads per
//! (point, file)); k-means sampling must first load the *whole* slice's
//! statistics to cluster on (mean, std) — which is why its loading time
//! at rate 0.2 already exceeds random sampling at rate 1.0 (paper
//! Fig. 16). Neither path ever calls the fit artifacts: types come from
//! the broadcast decision tree (the ~2 s flat "PDF computation" of
//! Fig. 15).

use std::time::Instant;

use crate::cluster::SimCluster;
use crate::coordinator::loader;
use crate::cube::PointId;
use crate::mltree::DecisionTree;
use crate::runtime::Backend;
use crate::sampling::{kmeans_sample, random_sample, SliceFeatures};
use crate::stats::DistType;
use crate::storage::{DatasetReader, WindowCache};
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

/// Double-sampling strategy (paper §5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampler {
    Random,
    KMeans,
}

impl Sampler {
    pub fn name(self) -> &'static str {
        match self {
            Sampler::Random => "random",
            Sampler::KMeans => "kmeans",
        }
    }
}

/// Result of one sampling run (one Fig. 15/16 data point).
#[derive(Clone, Debug)]
pub struct SamplingReport {
    pub sampler: Sampler,
    pub rate: f64,
    pub n_sampled: usize,
    pub features: SliceFeatures,
    pub load_real_s: f64,
    pub load_sim_s: f64,
    pub compute_real_s: f64,
    pub compute_sim_s: f64,
}

/// Run Algorithm 5 over slice `z`.
#[allow(clippy::too_many_arguments)]
pub fn run_sampling(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster: &SimCluster,
    tree: &DecisionTree,
    z: usize,
    rate: f64,
    sampler: Sampler,
    seed: u64,
) -> Result<SamplingReport> {
    if !(0.0..=1.0).contains(&rate) {
        return Err(PdfflowError::InvalidArg(format!("rate {rate} not in [0,1]")));
    }
    let dims = reader.dataset().spec.dims;
    let n_slice = dims.slice_points();
    let mut rng = Rng::new(seed ^ (z as u64) << 17);

    let (feat_rows, load_real_s, load_sim_s, n_sampled) = match sampler {
        Sampler::Random => {
            // Lines 2–14: load only the sampled points.
            let picks = random_sample(&mut rng, n_slice, rate);
            let ids: Vec<PointId> = picks
                .iter()
                .map(|&i| PointId((z * n_slice + i) as u64))
                .collect();
            let t0 = Instant::now();
            let obs = reader.read_points(&ids)?;
            let io_real = t0.elapsed().as_secs_f64();
            let bytes = obs.bytes();
            let reads = (ids.len() * reader.dataset().spec.n_sims) as u64;
            let t1 = Instant::now();
            let stats = backend.run_stats(&obs.data, ids.len(), obs.n_obs)?;
            let stats_real = t1.elapsed().as_secs_f64();
            let mut sim = cluster.charge_nfs("sample.nfs", bytes, reads);
            // Loading stage: one Map task per sampled point, paying the
            // emulated per-value gather cost plus the real stats share.
            let per_task = cluster.spec.load_cost_per_value * obs.n_obs as f64
                + stats_real / ids.len().max(1) as f64;
            sim += cluster.run_stage("sample.stats", &vec![per_task; ids.len()]);
            let rows: Vec<[f64; 2]> = (0..ids.len())
                .map(|p| [stats.row(p)[0] as f64, stats.row(p)[1] as f64])
                .collect();
            (rows, io_real + stats_real, sim, ids.len())
        }
        Sampler::KMeans => {
            // k-means needs every point's features first: full slice load.
            let t0 = Instant::now();
            let mut all_rows: Vec<[f64; 2]> = Vec::with_capacity(n_slice);
            let mut sim = 0.0;
            for w in dims.windows(z, 16) {
                let lw = loader::load_window(reader, cache, backend, cluster, w)?;
                sim += lw.sim_s;
                for p in 0..lw.n_points() {
                    let (m, s) = lw.mean_std(p);
                    all_rows.push([m, s]);
                }
            }
            let k_t0 = Instant::now();
            let picks = kmeans_sample(&mut rng, &all_rows, rate, 10);
            let kmeans_real = k_t0.elapsed().as_secs_f64();
            // k-means itself runs as a driver-side iterative job.
            sim += cluster.run_stage("sample.kmeans", &[kmeans_real]);
            let rows: Vec<[f64; 2]> = picks.iter().map(|&i| all_rows[i]).collect();
            let n = rows.len();
            (rows, t0.elapsed().as_secs_f64(), sim, n)
        }
    };

    // Lines 15–26: predict types with the broadcast tree, aggregate the
    // slice features. No fit artifact runs — this is the whole point.
    let t1 = Instant::now();
    let mut means = Vec::with_capacity(feat_rows.len());
    let mut stds = Vec::with_capacity(feat_rows.len());
    let mut types = Vec::with_capacity(feat_rows.len());
    for r in &feat_rows {
        means.push(r[0]);
        stds.push(r[1]);
        types.push(DistType::from_id(tree.predict(r)).unwrap_or(DistType::Normal));
    }
    let features = SliceFeatures::from_points(&means, &stds, &types);
    let compute_real_s = t1.elapsed().as_secs_f64();
    // Driver collects (mean, std, type) triples from the workers.
    let mut compute_sim_s = cluster.charge_shuffle("sample.collect", 24 * feat_rows.len() as u64);
    compute_sim_s += cluster.run_stage("sample.predict", &[compute_real_s]);

    Ok(SamplingReport {
        sampler,
        rate,
        n_sampled,
        features,
        load_real_s,
        load_sim_s,
        compute_real_s,
        compute_sim_s,
    })
}

/// Reference features of ALL slice points (tree-predicted types), used as
/// the Fig. 17 ground truth for the type-percentage distance.
pub fn full_slice_features(
    reader: &DatasetReader,
    cache: &WindowCache,
    backend: &dyn Backend,
    cluster: &SimCluster,
    tree: &DecisionTree,
    z: usize,
) -> Result<SliceFeatures> {
    let dims = reader.dataset().spec.dims;
    let mut means = Vec::new();
    let mut stds = Vec::new();
    let mut types = Vec::new();
    for w in dims.windows(z, 16) {
        let lw = loader::load_window(reader, cache, backend, cluster, w)?;
        for p in 0..lw.n_points() {
            let (m, s) = lw.mean_std(p);
            means.push(m);
            stds.push(s);
            types.push(DistType::from_id(tree.predict(&[m, s])).unwrap_or(DistType::Normal));
        }
    }
    Ok(SliceFeatures::from_points(&means, &stds, &types))
}
