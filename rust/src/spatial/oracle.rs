//! Brute-force reference implementations of every spatial query.
//!
//! Each function answers by exhaustively scanning **every** resolved
//! window of the store — no grid index, no block cache, no executor —
//! so it is trivially correct and independent of the fast paths in
//! [`QueryEngine`](crate::pdfstore::QueryEngine). The oracle-
//! differential suite (`tests/spatial_oracle.rs`) asserts the indexed
//! engine answers are *bit-identical* to these on randomized stores.
//!
//! The only shared contract is the deterministic summation order
//! documented in [`crate::spatial`]: error sums fold per-window
//! record-order partials in `(z, y0)` window order, and diff deltas
//! accumulate in point-id order. Both sides implement that definition
//! with their own loop structure.

use std::collections::{BTreeMap, BTreeSet};

use crate::cube::CellGrid;
use crate::pdfstore::query::{RegionSummary, ERROR_HIST_BINS};
use crate::pdfstore::{PdfRecord, PdfStore, SlicePart};
use crate::stats::PENALTY_ERROR;
use crate::Result;

use super::{
    dist2, dominant_type, BoxQuery, CellSummary, KnnQuery, RadiusQuery, RunDiff, SpatialAggregate,
};

/// Every resolved window of the store, ascending `(z, y0)` — the
/// canonical deterministic scan order. Strict: an unresolvable slice
/// (coverage lost to quarantine) is a typed error, matching the
/// engine's pre-checks.
fn all_windows(store: &PdfStore) -> Result<Vec<(usize, SlicePart)>> {
    let mut out = Vec::new();
    for z in store.slices() {
        if let Some(parts) = store.slice_parts(z)? {
            for p in parts.iter() {
                out.push((z, *p));
            }
        }
    }
    Ok(out)
}

/// Full-scan box query: all records inside the box, point-id order.
pub fn box_records(store: &PdfStore, q: &BoxQuery) -> Result<Vec<PdfRecord>> {
    let dims = store.dims();
    let mut out = Vec::new();
    for (_, p) in all_windows(store)? {
        for rec in store.reader(p.seg)?.read_window(p.win)? {
            let (x, y, z) = dims.coords(rec.point);
            if q.contains(x, y, z) {
                out.push(rec);
            }
        }
    }
    Ok(out)
}

/// Full-scan analytical box summary (same shape as a 2D region
/// summary, computed over the 3D box).
pub fn box_summary(store: &PdfStore, q: &BoxQuery) -> Result<RegionSummary> {
    let dims = store.dims();
    let mut s = RegionSummary {
        n_points: 0,
        avg_error: 0.0,
        max_error: 0.0,
        type_counts: [0; 10],
        error_hist: [0; ERROR_HIST_BINS],
    };
    let mut err_sum = 0.0f64;
    for (_, p) in all_windows(store)? {
        // Per-window partial, folded in window order (module contract).
        let mut win_sum = 0.0f64;
        for rec in store.reader(p.seg)?.read_window(p.win)? {
            let (x, y, z) = dims.coords(rec.point);
            if !q.contains(x, y, z) {
                continue;
            }
            s.n_points += 1;
            let e = rec.error as f64;
            win_sum += e;
            s.max_error = s.max_error.max(e);
            s.type_counts[rec.dist.id()] += 1;
            let bin = ((e / PENALTY_ERROR) * ERROR_HIST_BINS as f64).floor();
            s.error_hist[(bin.max(0.0) as usize).min(ERROR_HIST_BINS - 1)] += 1;
        }
        err_sum += win_sum;
    }
    if s.n_points > 0 {
        s.avg_error = err_sum / s.n_points as f64;
    }
    Ok(s)
}

/// Full-scan radius query: all records within Euclidean `radius` of the
/// center, point-id order. The predicate is the exact integer squared
/// distance compared against `radius²` in f64 — identical on both the
/// oracle and the indexed path.
pub fn radius_records(store: &PdfStore, q: &RadiusQuery) -> Result<Vec<PdfRecord>> {
    if q.radius < 0.0 {
        return Ok(Vec::new());
    }
    let dims = store.dims();
    let r2 = q.radius * q.radius;
    let center = (q.x, q.y, q.z);
    let mut out = Vec::new();
    for (_, p) in all_windows(store)? {
        for rec in store.reader(p.seg)?.read_window(p.win)? {
            if dist2(dims.coords(rec.point), center) as f64 <= r2 {
                out.push(rec);
            }
        }
    }
    Ok(out)
}

/// Full-scan k-nearest-neighbors: every stored record ranked by
/// `(squared distance, PointId)`, truncated to `k`.
pub fn knn(store: &PdfStore, q: &KnnQuery) -> Result<Vec<PdfRecord>> {
    let dims = store.dims();
    let center = (q.x, q.y, q.z);
    let mut all = Vec::new();
    for (_, p) in all_windows(store)? {
        all.extend(store.reader(p.seg)?.read_window(p.win)?);
    }
    all.sort_unstable_by_key(|rec| (dist2(dims.coords(rec.point), center), rec.point));
    all.truncate(q.k);
    Ok(all)
}

/// Full-scan per-cell aggregation over a box.
pub fn cell_aggregate(store: &PdfStore, grid: CellGrid, q: &BoxQuery) -> Result<SpatialAggregate> {
    let dims = store.dims();
    struct Acc {
        n: usize,
        types: [u64; 10],
        err_sum: f64,
        max: f32,
    }
    let mut cells: BTreeMap<usize, Acc> = BTreeMap::new();
    for (_, p) in all_windows(store)? {
        // Window-order fold of per-window partials (module contract).
        let mut partial: BTreeMap<usize, Acc> = BTreeMap::new();
        for rec in store.reader(p.seg)?.read_window(p.win)? {
            let (x, y, z) = dims.coords(rec.point);
            if !q.contains(x, y, z) {
                continue;
            }
            let idx = grid.cell_index(grid.cell_of(x, y, z));
            let a = partial.entry(idx).or_insert(Acc {
                n: 0,
                types: [0; 10],
                err_sum: 0.0,
                max: 0.0,
            });
            a.n += 1;
            a.types[rec.dist.id()] += 1;
            a.err_sum += rec.error as f64;
            a.max = a.max.max(rec.error);
        }
        for (idx, w) in partial {
            let a = cells.entry(idx).or_insert(Acc {
                n: 0,
                types: [0; 10],
                err_sum: 0.0,
                max: 0.0,
            });
            a.n += w.n;
            for i in 0..10 {
                a.types[i] += w.types[i];
            }
            a.err_sum += w.err_sum;
            a.max = a.max.max(w.max);
        }
    }
    let summaries: Vec<CellSummary> = cells
        .iter()
        .map(|(&idx, a)| CellSummary {
            cell: grid.cell_at(idx),
            n_points: a.n,
            type_counts: a.types,
            dominant: dominant_type(&a.types),
            err_sum: a.err_sum,
            max_error: a.max,
        })
        .collect();
    Ok(SpatialAggregate {
        grid,
        boundary: boundary_cells(&grid, &summaries),
        cells: summaries,
    })
}

/// Type-transition boundary cells of an aggregation: non-empty cells
/// with at least one non-empty 6-neighbor of a different dominant type,
/// ascending flat cell index.
pub fn boundary_cells(grid: &CellGrid, cells: &[CellSummary]) -> Vec<(usize, usize, usize)> {
    let dominant: BTreeMap<usize, u8> = cells
        .iter()
        .map(|c| (grid.cell_index(c.cell), c.dominant.id() as u8))
        .collect();
    let (ncx, ncy, ncz) = (grid.ncx(), grid.ncy(), grid.ncz());
    let mut out = Vec::new();
    for c in cells {
        let (cx, cy, cz) = c.cell;
        let mut neighbors: Vec<(usize, usize, usize)> = Vec::with_capacity(6);
        if cx > 0 {
            neighbors.push((cx - 1, cy, cz));
        }
        if cx + 1 < ncx {
            neighbors.push((cx + 1, cy, cz));
        }
        if cy > 0 {
            neighbors.push((cx, cy - 1, cz));
        }
        if cy + 1 < ncy {
            neighbors.push((cx, cy + 1, cz));
        }
        if cz > 0 {
            neighbors.push((cx, cy, cz - 1));
        }
        if cz + 1 < ncz {
            neighbors.push((cx, cy, cz + 1));
        }
        let me = c.dominant.id() as u8;
        if neighbors
            .iter()
            .any(|&n| dominant.get(&grid.cell_index(n)).is_some_and(|&d| d != me))
        {
            out.push(c.cell);
        }
    }
    out
}

/// Full-scan cross-run diff: join both runs' in-box records by point
/// id, accumulating deltas in point-id order (module contract).
pub fn diff(
    store_a: &PdfStore,
    store_b: &PdfStore,
    grid: CellGrid,
    q: &BoxQuery,
) -> Result<RunDiff> {
    let collect = |store: &PdfStore| -> Result<BTreeMap<u64, PdfRecord>> {
        Ok(box_records(store, q)?
            .into_iter()
            .map(|r| (r.point.0, r))
            .collect())
    };
    let a = collect(store_a)?;
    let b = collect(store_b)?;
    let dims = store_a.dims();
    let mut d = RunDiff {
        n_compared: 0,
        only_a: 0,
        only_b: 0,
        type_changed: 0,
        type_counts_a: [0; 10],
        type_counts_b: [0; 10],
        err_delta_sum: 0.0,
        max_err_delta: 0.0,
        changed_cells: Vec::new(),
        grid,
    };
    let mut changed: BTreeSet<usize> = BTreeSet::new();
    for (id, ra) in &a {
        match b.get(id) {
            None => d.only_a += 1,
            Some(rb) => {
                d.n_compared += 1;
                d.type_counts_a[ra.dist.id()] += 1;
                d.type_counts_b[rb.dist.id()] += 1;
                let delta = (ra.error - rb.error).abs();
                d.err_delta_sum += delta as f64;
                d.max_err_delta = d.max_err_delta.max(delta);
                if ra.dist != rb.dist {
                    d.type_changed += 1;
                    let (x, y, z) = dims.coords(ra.point);
                    changed.insert(grid.cell_index(grid.cell_of(x, y, z)));
                }
            }
        }
    }
    d.only_b = b.len() - d.n_compared;
    d.changed_cells = changed.into_iter().map(|i| grid.cell_at(i)).collect();
    Ok(d)
}
