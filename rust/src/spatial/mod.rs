//! Spatial query tier over the fitted-PDF store (ROADMAP: "scenario"
//! queries beyond per-point PDFs).
//!
//! The store persists one [`PdfRecord`] per cube point, window by
//! window; this module adds the spatial vocabulary on top:
//!
//! * [`BoxQuery`] — true 3D axis-aligned boxes (inclusive bounds).
//! * [`RadiusQuery`] / [`KnnQuery`] — Euclidean neighborhoods around a
//!   point, distances in point-index units. Squared distances are
//!   exact `u64` integers, so ordering never depends on float rounding;
//!   kNN ties are broken by ascending [`PointId`](crate::cube::PointId).
//! * [`GridIndex`] — a uniform [`CellGrid`] index mapping cells to the
//!   resolved `(slice, window, line-range)` parts that overlap them,
//!   the pruning structure behind the
//!   [`QueryEngine`](crate::pdfstore::QueryEngine) spatial entry points
//!   (grid partitioning as in SedonaSpark-style spatial datasets).
//! * [`SpatialAggregate`] — per-cell aggregation of fitted parameters:
//!   dominant [`DistType`], mean Eq. 5 error, and the type-transition
//!   *boundary cells* where the dominant type changes between
//!   neighboring cells.
//! * [`RunDiff`] — a cross-run comparison of two runs' type/error maps
//!   over a region (both sides selected through the generational
//!   catalog via [`RunSelector`](crate::pdfstore::RunSelector)).
//!
//! **Determinism contract.** Every aggregate defined here is
//! bit-identical at any thread count *and* bit-comparable against the
//! brute-force [`oracle`]: per-cell and per-region error sums are
//! defined as the window-order fold of within-window point-order
//! partial sums (windows ordered by `(z, y0)` — which is first-point-id
//! order), and cross-run error deltas accumulate in point-id order.
//! The engine and the oracle both implement this definition, so the
//! oracle-differential suite (`tests/spatial_oracle.rs`) can assert
//! exact equality, not tolerance.

pub mod oracle;

use crate::cube::{CellGrid, CubeDims};
use crate::pdfstore::{PdfStore, SlicePart};
use crate::stats::DistType;

/// Inclusive 3D axis-aligned box. An inverted axis (`x1 < x0`, …)
/// makes the box empty — useful for "no match" sentinels and exercised
/// by the oracle suite's edge cases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BoxQuery {
    pub x0: usize,
    pub x1: usize,
    pub y0: usize,
    pub y1: usize,
    pub z0: usize,
    pub z1: usize,
}

impl BoxQuery {
    /// The whole cube.
    pub fn whole(dims: &CubeDims) -> BoxQuery {
        BoxQuery {
            x0: 0,
            x1: dims.nx.saturating_sub(1),
            y0: 0,
            y1: dims.ny.saturating_sub(1),
            z0: 0,
            z1: dims.nz.saturating_sub(1),
        }
    }

    /// A single-point box.
    pub fn point(x: usize, y: usize, z: usize) -> BoxQuery {
        BoxQuery { x0: x, x1: x, y0: y, y1: y, z0: z, z1: z }
    }

    /// The Chebyshev ball of half-width `half` around a point, clamped
    /// to the cube (the kNN search frontier and radius bounding box).
    pub fn around(dims: &CubeDims, (x, y, z): (usize, usize, usize), half: usize) -> BoxQuery {
        BoxQuery {
            x0: x.saturating_sub(half),
            x1: (x + half).min(dims.nx.saturating_sub(1)),
            y0: y.saturating_sub(half),
            y1: (y + half).min(dims.ny.saturating_sub(1)),
            z0: z.saturating_sub(half),
            z1: (z + half).min(dims.nz.saturating_sub(1)),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.x1 < self.x0 || self.y1 < self.y0 || self.z1 < self.z0
    }

    pub fn n_points(&self) -> usize {
        if self.is_empty() {
            return 0;
        }
        (self.x1 - self.x0 + 1) * (self.y1 - self.y0 + 1) * (self.z1 - self.z0 + 1)
    }

    pub fn contains(&self, x: usize, y: usize, z: usize) -> bool {
        x >= self.x0
            && x <= self.x1
            && y >= self.y0
            && y <= self.y1
            && z >= self.z0
            && z <= self.z1
    }
}

/// Euclidean ball around a grid point; `radius` in point-index units.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RadiusQuery {
    pub x: usize,
    pub y: usize,
    pub z: usize,
    pub radius: f64,
}

impl RadiusQuery {
    /// The clamped bounding box of the ball: any cube point outside it
    /// is farther than `radius` on some axis.
    pub fn bounding_box(&self, dims: &CubeDims) -> BoxQuery {
        if self.radius < 0.0 {
            // Empty sentinel (inverted x axis).
            return BoxQuery { x0: 1, x1: 0, y0: 0, y1: 0, z0: 0, z1: 0 };
        }
        BoxQuery::around(dims, (self.x, self.y, self.z), self.radius.floor() as usize)
    }
}

/// k nearest stored records around a grid point, ordered by
/// `(squared distance, PointId)` — exact integers, so the order (and
/// every tie) is deterministic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KnnQuery {
    pub x: usize,
    pub y: usize,
    pub z: usize,
    pub k: usize,
}

/// Exact squared Euclidean distance between two grid points.
pub fn dist2(a: (usize, usize, usize), b: (usize, usize, usize)) -> u64 {
    let d = |p: usize, q: usize| {
        let d = p.abs_diff(q) as u64;
        d * d
    };
    d(a.0, b.0) + d(a.1, b.1) + d(a.2, b.2)
}

/// Uniform grid index over a store's resolved view: each (cy, cz) cell
/// row maps to the resolved windows overlapping it. Windows span every
/// x of their lines, so the x axis of the 3D grid is resolved per
/// record during the scan; the index prunes on (y, z) — the axes the
/// on-disk layout actually partitions.
pub struct GridIndex {
    grid: CellGrid,
    /// Bucket per (cz * ncy + cy): indices into `parts`, ascending.
    buckets: Vec<Vec<u32>>,
    /// Every resolved window, ascending `(z, y0)` — first-point-id
    /// order, the deterministic merge order for every spatial scan.
    parts: Vec<(usize, SlicePart)>,
}

impl GridIndex {
    /// Build the index over every resolved window of the open run.
    /// Unresolvable slices index nothing — the engine's strict
    /// pre-checks turn queries touching them into typed errors before
    /// the index is consulted.
    pub fn build(store: &PdfStore, grid: CellGrid) -> GridIndex {
        let ncy = grid.ncy();
        let mut buckets = vec![Vec::new(); ncy * grid.ncz()];
        let mut parts: Vec<(usize, SlicePart)> = Vec::new();
        for z in store.slices() {
            let cz = z / grid.sz;
            let Some(resolved) = store.resolved_parts(z) else {
                continue;
            };
            for p in resolved.iter() {
                let idx = parts.len() as u32;
                parts.push((z, *p));
                let y1 = (p.entry.y0 + p.entry.lines - 1) as usize;
                for cy in p.entry.y0 as usize / grid.sy..=y1 / grid.sy {
                    buckets[cz * ncy + cy].push(idx);
                }
            }
        }
        GridIndex { grid, buckets, parts }
    }

    pub fn grid(&self) -> CellGrid {
        self.grid
    }

    /// Indexed windows (the whole resolved view).
    pub fn n_parts(&self) -> usize {
        self.parts.len()
    }

    /// Candidate windows for a box: union of the overlapped cell rows'
    /// buckets, exact-filtered by (z, y) overlap, ascending `(z, y0)`.
    pub fn parts_for_box(&self, q: &BoxQuery) -> Vec<(usize, SlicePart)> {
        let dims = self.grid.dims;
        if q.is_empty() || q.y0 >= dims.ny || q.z0 >= dims.nz || dims.ny == 0 {
            return Vec::new();
        }
        let y1 = q.y1.min(dims.ny - 1);
        let z1 = q.z1.min(dims.nz - 1);
        let ncy = self.grid.ncy();
        let mut idxs: Vec<u32> = Vec::new();
        for cz in q.z0 / self.grid.sz..=z1 / self.grid.sz {
            for cy in q.y0 / self.grid.sy..=y1 / self.grid.sy {
                idxs.extend(&self.buckets[cz * ncy + cy]);
            }
        }
        idxs.sort_unstable();
        idxs.dedup();
        idxs.into_iter()
            .map(|i| self.parts[i as usize])
            .filter(|(z, p)| {
                let (lo, hi) = (p.entry.y0 as usize, (p.entry.y0 + p.entry.lines) as usize);
                *z >= q.z0 && *z <= z1 && hi > q.y0 && lo <= y1
            })
            .collect()
    }
}

/// Aggregated fit outcomes of one grid cell (intersected with the
/// query box: edge cells summarize only their in-box points).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellSummary {
    /// Cell coordinates `(cx, cy, cz)`.
    pub cell: (usize, usize, usize),
    pub n_points: usize,
    /// Count per `DistType` id.
    pub type_counts: [u64; 10],
    /// Most frequent type (ties → lowest type id).
    pub dominant: DistType,
    /// Eq. 5 error sum in the documented deterministic order (window-
    /// order fold of within-window partial sums; see module docs).
    pub err_sum: f64,
    pub max_error: f32,
}

impl CellSummary {
    pub fn mean_error(&self) -> f64 {
        if self.n_points == 0 {
            0.0
        } else {
            self.err_sum / self.n_points as f64
        }
    }
}

/// Result of a per-cell spatial aggregation over a box.
#[derive(Clone, Debug, PartialEq)]
pub struct SpatialAggregate {
    pub grid: CellGrid,
    /// Non-empty cells, ascending flat cell index.
    pub cells: Vec<CellSummary>,
    /// Type-transition boundary cells: non-empty cells with at least
    /// one non-empty 6-neighbor of a different dominant type (both
    /// sides of a transition are boundary cells). Ascending cell index.
    pub boundary: Vec<(usize, usize, usize)>,
}

/// The dominant type of a count vector: max count, ties to lowest id.
pub fn dominant_type(counts: &[u64; 10]) -> DistType {
    let mut best = 0usize;
    for (id, &n) in counts.iter().enumerate() {
        if n > counts[best] {
            best = id;
        }
    }
    DistType::from_id(best).expect("type ids 0..10 are always valid")
}

/// Cross-run comparison of two runs' fitted type/error maps over a
/// box. "Compared" points are covered by both runs' resolved views;
/// coverage differences are counted, not an error — two runs may have
/// persisted different slices or line ranges.
#[derive(Clone, Debug, PartialEq)]
pub struct RunDiff {
    /// Points present in both runs inside the box.
    pub n_compared: usize,
    /// In-box points covered by only one side.
    pub only_a: usize,
    pub only_b: usize,
    /// Compared points whose fitted `DistType` differs.
    pub type_changed: usize,
    /// Type histograms of the compared points, per side.
    pub type_counts_a: [u64; 10],
    pub type_counts_b: [u64; 10],
    /// Point-id-order sum of `|err_a − err_b|` over compared points.
    pub err_delta_sum: f64,
    pub max_err_delta: f32,
    /// Grid cells holding at least one type-changed point, ascending
    /// flat cell index of `grid`.
    pub changed_cells: Vec<(usize, usize, usize)>,
    /// The grid `changed_cells` refers to.
    pub grid: CellGrid,
}

impl RunDiff {
    pub fn mean_err_delta(&self) -> f64 {
        if self.n_compared == 0 {
            0.0
        } else {
            self.err_delta_sum / self.n_compared as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box_geometry() {
        let dims = CubeDims::new(8, 6, 4);
        let whole = BoxQuery::whole(&dims);
        assert_eq!(whole.n_points(), 8 * 6 * 4);
        assert!(whole.contains(7, 5, 3));
        let p = BoxQuery::point(2, 3, 1);
        assert_eq!(p.n_points(), 1);
        assert!(p.contains(2, 3, 1) && !p.contains(2, 3, 2));
        let empty = BoxQuery { x0: 3, x1: 2, ..whole };
        assert!(empty.is_empty());
        assert_eq!(empty.n_points(), 0);
        // around() clamps at the cube edge.
        let b = BoxQuery::around(&dims, (0, 5, 1), 2);
        assert_eq!(b, BoxQuery { x0: 0, x1: 2, y0: 3, y1: 5, z0: 0, z1: 3 });
    }

    #[test]
    fn squared_distances_are_exact() {
        assert_eq!(dist2((0, 0, 0), (3, 4, 0)), 25);
        assert_eq!(dist2((5, 2, 1), (2, 2, 1)), 9);
        assert_eq!(dist2((1, 1, 1), (1, 1, 1)), 0);
        // Symmetric in both argument orders.
        assert_eq!(dist2((9, 0, 3), (1, 7, 0)), dist2((1, 7, 0), (9, 0, 3)));
    }

    #[test]
    fn radius_bounding_box() {
        let dims = CubeDims::new(10, 10, 10);
        let q = RadiusQuery { x: 5, y: 5, z: 5, radius: 2.9 };
        assert_eq!(q.bounding_box(&dims), BoxQuery::around(&dims, (5, 5, 5), 2));
        let none = RadiusQuery { x: 5, y: 5, z: 5, radius: -1.0 };
        assert!(none.bounding_box(&dims).is_empty());
    }

    #[test]
    fn dominant_breaks_ties_by_lowest_id() {
        let mut counts = [0u64; 10];
        counts[3] = 5;
        counts[7] = 5;
        assert_eq!(dominant_type(&counts), DistType::from_id(3).unwrap());
        assert_eq!(dominant_type(&[0; 10]), DistType::from_id(0).unwrap());
    }
}
