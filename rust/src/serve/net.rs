//! TCP socket front end: the [`crate::serve::ServeFront`] behind a real
//! wire, with no async runtime and no event-loop crate.
//!
//! One dedicated thread runs a hand-rolled `poll(2)` event loop over a
//! nonblocking listener plus every live connection, speaking the
//! length-prefixed JSON protocol of [`super::wire`]. Parsed query
//! frames are handed to a small worker pool through a **bounded**
//! dispatch queue; when that queue is full the request is shed *on the
//! wire* as a typed `status:"shed"` frame (and counted in the same
//! per-class ledger as gate sheds via `ServeFront::note_shed`) — the
//! overload contract of the in-process front survives the socket hop.
//! Cheap control frames (`meta`, `shutdown`) are answered inline on the
//! event thread.
//!
//! Per connection, requests are answered **in order**: the loop parses
//! at most one query frame ahead per connection (further pipelined
//! frames wait buffered until the reply is written), so a synchronous
//! client can never observe reordering. Shutdown is graceful: the
//! listener stops accepting, in-flight queries finish, every write
//! buffer drains (the shutdown ack included), then the loop exits and
//! the workers follow.
//!
//! Counters: `net.conns` (connections accepted), `net.frames_in` /
//! `net.frames_out`, and `net.sheds` (dispatch-queue sheds).
//!
//! [`closed_loop_net`] is the socket twin of [`crate::serve::closed_loop`]:
//! the *same* deterministic request mix, driven end-to-end over loopback
//! by N synchronous clients — wire encode/decode included in every
//! measured latency.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::serve::wire::{self, ControlOrQuery, ServeMeta};
use crate::serve::{next_request, Request, Served, ServeFront};
use crate::telemetry::{Counter, Registry};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x1;
const POLLOUT: i16 = 0x4;
const POLLERR: i16 = 0x8;
const POLLHUP: i16 = 0x10;
const POLLNVAL: i16 = 0x20;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
}

/// Socket-layer knobs (`pdfflow serve --listen`).
#[derive(Clone, Copy, Debug)]
pub struct NetOptions {
    /// Worker threads executing admitted queries. `0` is a valid test
    /// configuration: with no workers every query frame is shed, which
    /// makes the typed-shed wire path deterministic.
    pub workers: usize,
    /// Bound of the dispatch queue between the event loop and the
    /// workers; a full queue sheds on the wire.
    pub queue_depth: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        let w = crate::runtime::hostpool::default_budget().max(1);
        NetOptions { workers: w, queue_depth: 2 * w }
    }
}

struct Job {
    conn: u64,
    req: Request,
}

/// One live connection's state machine.
struct Conn {
    stream: TcpStream,
    /// Inbound bytes not yet parsed into frames.
    rbuf: Vec<u8>,
    /// Outbound bytes; `wpos..` is still unsent.
    wbuf: Vec<u8>,
    wpos: usize,
    /// A query from this connection is with the workers; don't parse
    /// further frames until its reply is queued (in-order contract).
    busy: bool,
    /// Stop reading; drop the connection once `wbuf` drains (used after
    /// protocol errors so the error frame still goes out).
    closing: bool,
    /// Dead now; reaped on the next sweep.
    closed: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            busy: false,
            closing: false,
            closed: false,
        }
    }

    fn pending_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    fn push_frame(&mut self, doc: &Json) {
        // Infallible: Vec<u8> as Write cannot error.
        let _ = wire::write_frame(&mut self.wbuf, doc);
    }

    /// Write as much of `wbuf` as the socket accepts right now.
    fn flush(&mut self) {
        while self.pending_write() {
            match (&self.stream).write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.closed = true;
                    return;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    return;
                }
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        if self.closing {
            self.closed = true;
        }
    }

    /// Drain readable bytes into `rbuf`.
    fn fill(&mut self) {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match (&self.stream).read(&mut buf) {
                Ok(0) => {
                    self.closed = true;
                    return;
                }
                Ok(n) => self.rbuf.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    return;
                }
            }
        }
    }

    /// Pop one complete frame off `rbuf`, if buffered. A hostile length
    /// prefix turns into an error frame and a drain-then-close.
    fn next_frame(&mut self) -> Option<Json> {
        if self.rbuf.len() < 4 {
            return None;
        }
        let len = u32::from_le_bytes(self.rbuf[..4].try_into().unwrap()) as usize;
        if len > wire::MAX_FRAME {
            self.push_frame(&wire::encode_error(&PdfflowError::Format(format!(
                "frame length {len} exceeds cap {}",
                wire::MAX_FRAME
            ))));
            self.closing = true;
            return None;
        }
        if self.rbuf.len() < 4 + len {
            return None;
        }
        let text = std::str::from_utf8(&self.rbuf[4..4 + len]).ok().map(str::to_owned);
        let doc = text.and_then(|t| Json::parse(&t).ok());
        self.rbuf.drain(..4 + len);
        match doc {
            Some(doc) => Some(doc),
            None => {
                // Undecodable payload: the stream may be desynced, so
                // answer once and close instead of guessing.
                self.push_frame(&wire::encode_error(&PdfflowError::Format(
                    "unparsable frame payload".into(),
                )));
                self.closing = true;
                None
            }
        }
    }
}

struct EventLoop {
    listener: TcpListener,
    wake_rx: TcpStream,
    front: Arc<ServeFront>,
    job_tx: SyncSender<Job>,
    workers: usize,
    done: Arc<Mutex<Vec<(u64, Json)>>>,
    stop: Arc<AtomicBool>,
    conns: HashMap<u64, Conn>,
    next_id: u64,
    /// Jobs dispatched to workers whose completions haven't been
    /// drained yet (both ends touched only on this thread).
    outstanding: usize,
    ctr_conns: Arc<Counter>,
    ctr_frames_in: Arc<Counter>,
    ctr_frames_out: Arc<Counter>,
    ctr_sheds: Arc<Counter>,
}

impl EventLoop {
    fn run(mut self) {
        loop {
            self.drain_completions();
            self.conns.retain(|_, c| !c.closed);
            let stopping = self.stop.load(Ordering::Acquire);
            if stopping
                && self.outstanding == 0
                && self.conns.values().all(|c| !c.pending_write())
                && self.done.lock().unwrap().is_empty()
            {
                // Graceful exit: nothing in flight, every reply (the
                // shutdown ack included) flushed. Dropping `job_tx`
                // unblocks the workers' recv loops.
                return;
            }

            let mut fds = Vec::with_capacity(self.conns.len() + 2);
            fds.push(PollFd {
                fd: self.listener.as_raw_fd(),
                events: if stopping { 0 } else { POLLIN },
                revents: 0,
            });
            fds.push(PollFd { fd: self.wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
            let ids: Vec<u64> = self.conns.keys().copied().collect();
            for id in &ids {
                let c = &self.conns[id];
                let mut events = POLLIN;
                if c.pending_write() {
                    events |= POLLOUT;
                }
                fds.push(PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
            }

            // 100 ms cap so an externally-set stop flag is noticed even
            // if the wake byte races the fd registration.
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, 100) };
            if n <= 0 {
                continue; // timeout or EINTR
            }

            if fds[0].revents & POLLIN != 0 {
                self.accept_ready();
            }
            if fds[1].revents & POLLIN != 0 {
                let mut sink = [0u8; 256];
                while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
            }
            for (i, id) in ids.iter().enumerate() {
                let revents = fds[i + 2].revents;
                if revents != 0 {
                    self.service(*id, revents);
                }
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.conns.insert(self.next_id, Conn::new(stream));
                    self.next_id += 1;
                    self.ctr_conns.inc();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Move finished worker replies into their connections' write
    /// buffers, then resume parsing any frames the in-order contract
    /// had parked.
    fn drain_completions(&mut self) {
        let finished: Vec<(u64, Json)> = std::mem::take(&mut *self.done.lock().unwrap());
        for (id, doc) in finished {
            self.outstanding -= 1;
            let Some(mut c) = self.conns.remove(&id) else {
                continue; // connection died while its query ran
            };
            c.push_frame(&doc);
            self.ctr_frames_out.inc();
            c.busy = false;
            self.process_frames(id, &mut c);
            c.flush();
            self.conns.insert(id, c);
        }
    }

    fn service(&mut self, id: u64, revents: i16) {
        let Some(mut c) = self.conns.remove(&id) else {
            return;
        };
        if revents & (POLLERR | POLLNVAL) != 0 {
            return; // dropped
        }
        if revents & POLLOUT != 0 {
            c.flush();
        }
        if !c.closed && revents & (POLLIN | POLLHUP) != 0 && !c.closing {
            c.fill();
            if !c.closed {
                self.process_frames(id, &mut c);
                c.flush();
            }
        }
        if !c.closed {
            self.conns.insert(id, c);
        }
    }

    /// Parse and act on buffered frames, respecting the one-outstanding
    /// -query-per-connection ordering contract.
    fn process_frames(&mut self, id: u64, c: &mut Conn) {
        while !c.busy && !c.closing && !c.closed {
            let Some(doc) = c.next_frame() else {
                return;
            };
            self.ctr_frames_in.inc();
            match wire::decode_request(&doc) {
                Err(e) => {
                    // Unknown op / bad fields: typed error, connection
                    // stays usable (framing is still intact).
                    c.push_frame(&wire::encode_error(&e));
                    self.ctr_frames_out.inc();
                }
                Ok(ControlOrQuery::Meta) => {
                    let store = self.front.engine().store();
                    let meta = ServeMeta {
                        dims: store.dims(),
                        slices: store.slices(),
                        run: store.run_key().label(),
                    };
                    c.push_frame(&wire::encode_meta(&meta));
                    self.ctr_frames_out.inc();
                }
                Ok(ControlOrQuery::Shutdown) => {
                    c.push_frame(&Json::obj(vec![
                        ("status", Json::Str("ok".into())),
                        ("shutdown", Json::Bool(true)),
                    ]));
                    self.ctr_frames_out.inc();
                    self.stop.store(true, Ordering::Release);
                }
                Ok(ControlOrQuery::Query(req)) => {
                    if self.workers == 0 {
                        self.shed(c, &req);
                        continue;
                    }
                    match self.job_tx.try_send(Job { conn: id, req }) {
                        Ok(()) => {
                            c.busy = true;
                            self.outstanding += 1;
                        }
                        Err(TrySendError::Full(Job { req, .. })) => self.shed(c, &req),
                        Err(TrySendError::Disconnected(_)) => {
                            c.closing = true;
                        }
                    }
                }
            }
        }
    }

    /// Typed shed on the wire, charged to the same per-class ledger as
    /// the admission gate's own sheds.
    fn shed(&self, c: &mut Conn, req: &Request) {
        self.front.note_shed(req.class());
        self.ctr_sheds.inc();
        c.push_frame(&wire::encode_error(&PdfflowError::Overloaded(
            "net dispatch queue full".into(),
        )));
        self.ctr_frames_out.inc();
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    front: Arc<ServeFront>,
    done: Arc<Mutex<Vec<(u64, Json)>>>,
    wake: Arc<TcpStream>,
) {
    loop {
        // Lock only around recv: workers take jobs one at a time, and
        // the sender side disconnecting is the shutdown signal.
        let job = { rx.lock().unwrap().recv() };
        let Ok(job) = job else { return };
        let doc = match front.submit(job.req) {
            Ok(served) => wire::encode_served(&served),
            Err(e) => wire::encode_error(&e),
        };
        done.lock().unwrap().push((job.conn, doc));
        let _ = (&*wake).write(&[1u8]);
    }
}

/// Loopback stream pair used to interrupt a blocked `poll`: workers
/// write one byte to the tx end; the rx end sits in the poll set.
fn wake_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind(("127.0.0.1", 0))?;
    let tx = TcpStream::connect(l.local_addr()?)?;
    let (rx, _) = l.accept()?;
    rx.set_nonblocking(true)?;
    Ok((tx, rx))
}

/// Handle to a running socket server. Dropping it (or calling
/// [`Self::join`]) requests a graceful stop and joins every thread.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Arc<TcpStream>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an OS-assigned port) and
    /// start serving `front` — one event thread plus `opts.workers`
    /// query workers.
    pub fn start(front: Arc<ServeFront>, addr: &str, opts: NetOptions) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let (wake_tx, wake_rx) = wake_pair()?;
        let wake = Arc::new(wake_tx);
        let stop = Arc::new(AtomicBool::new(false));
        let done: Arc<Mutex<Vec<(u64, Json)>>> = Arc::default();
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<Job>(opts.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));

        let mut threads = Vec::with_capacity(opts.workers + 1);
        let reg = Registry::global();
        let ev = EventLoop {
            listener,
            wake_rx,
            front: Arc::clone(&front),
            job_tx,
            workers: opts.workers,
            done: Arc::clone(&done),
            stop: Arc::clone(&stop),
            conns: HashMap::new(),
            next_id: 0,
            outstanding: 0,
            ctr_conns: reg.counter("net.conns"),
            ctr_frames_in: reg.counter("net.frames_in"),
            ctr_frames_out: reg.counter("net.frames_out"),
            ctr_sheds: reg.counter("net.sheds"),
        };
        threads.push(
            std::thread::Builder::new()
                .name("pdfflow-net-poll".into())
                .spawn(move || ev.run())?,
        );
        for i in 0..opts.workers {
            let rx = Arc::clone(&job_rx);
            let front = Arc::clone(&front);
            let done = Arc::clone(&done);
            let wake = Arc::clone(&wake);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pdfflow-net-worker-{i}"))
                    .spawn(move || worker_loop(rx, front, done, wake))?,
            );
        }
        Ok(NetServer { addr: local, stop, wake, threads })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful stop (idempotent; returns immediately).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        let _ = (&*self.wake).write(&[1u8]);
    }

    /// Stop and join every server thread.
    pub fn join(mut self) {
        self.stop();
        self.join_threads();
    }

    /// Block until the server stops on its own — a wire `shutdown`
    /// frame or a concurrent [`Self::stop`] (the `--clients 0` serve
    /// mode).
    pub fn wait(mut self) {
        self.join_threads();
    }

    fn join_threads(&mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
        self.join_threads();
    }
}

/// Blocking protocol client: one frame out, one frame in.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Ask the server what it is serving (dims, slices, run label).
    pub fn meta(&mut self) -> Result<ServeMeta> {
        self.send(&Json::obj(vec![("op", Json::Str("meta".into()))]))?;
        let doc = self.recv()?;
        match doc.get("status").and_then(Json::as_str) {
            Some("ok") => wire::decode_meta(&doc),
            _ => match wire::decode_response(&doc) {
                Err(e) => Err(e),
                Ok(_) => Err(PdfflowError::Format("unexpected reply to meta".into())),
            },
        }
    }

    /// Round-trip one query. Sheds come back as
    /// [`PdfflowError::Overloaded`]; the connection stays usable after
    /// them.
    pub fn query(&mut self, req: &Request) -> Result<Served> {
        self.send(&wire::encode_request(req))?;
        wire::decode_response(&self.recv()?)
    }

    /// Ask the server to shut down gracefully; returns once the server
    /// acked (its threads may still be draining).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))?;
        let doc = self.recv()?;
        if doc.get("shutdown").and_then(Json::as_bool) == Some(true) {
            Ok(())
        } else {
            Err(PdfflowError::Format("unexpected reply to shutdown".into()))
        }
    }

    fn send(&mut self, doc: &Json) -> Result<()> {
        wire::write_frame(&mut self.stream, doc)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Json> {
        wire::read_frame(&mut self.stream)?.ok_or_else(|| {
            PdfflowError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ))
        })
    }
}

/// Result of one socket-driven closed-loop run (client-side view; the
/// server's per-class metrics live in its own `ServeFront`).
#[derive(Clone, Copy, Debug, Default)]
pub struct NetLoadReport {
    pub clients: usize,
    /// Requests issued across all clients (completed + shed + errors).
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    pub errors: u64,
    pub secs: f64,
    /// Successful replies per second.
    pub throughput: f64,
}

/// Drive a socket server with `clients` synchronous loopback clients,
/// each on its own connection, issuing the same deterministic request
/// mix as [`crate::serve::closed_loop`] (identical seeds → identical
/// blend). Sheds and query errors count and continue; transport
/// failures abort the run.
pub fn closed_loop_net(
    addr: &str,
    clients: usize,
    requests_per_client: usize,
    seed: u64,
) -> Result<NetLoadReport> {
    let clients = clients.max(1);
    let meta = Client::connect(addr)?.meta()?;
    if meta.slices.is_empty() {
        return Err(PdfflowError::InvalidArg(
            "closed_loop_net needs a non-empty store".into(),
        ));
    }
    let totals = Mutex::new((0u64, 0u64, 0u64)); // completed, shed, errors
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(clients);
        for k in 0..clients {
            let meta = &meta;
            let totals = &totals;
            handles.push(s.spawn(move || -> Result<()> {
                let mut client = Client::connect(addr)?;
                let mut rng =
                    Rng::new(seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(k as u64 + 1)));
                let (mut completed, mut shed, mut errors) = (0u64, 0u64, 0u64);
                for _ in 0..requests_per_client {
                    let req = next_request(&mut rng, &meta.dims, &meta.slices);
                    match client.query(&req) {
                        Ok(_) => completed += 1,
                        Err(e) if e.is_overload() => shed += 1,
                        Err(PdfflowError::Io(e)) => return Err(PdfflowError::Io(e)),
                        Err(_) => errors += 1,
                    }
                }
                let mut t = totals.lock().unwrap();
                t.0 += completed;
                t.1 += shed;
                t.2 += errors;
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("closed_loop_net client panicked")?;
        }
        Ok(())
    })?;
    let secs = t0.elapsed().as_secs_f64();
    let (completed, shed, errors) = *totals.lock().unwrap();
    Ok(NetLoadReport {
        clients,
        requests: (clients * requests_per_client) as u64,
        completed,
        shed,
        errors,
        secs,
        throughput: if secs > 0.0 { completed as f64 / secs } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pair_interrupts_poll() {
        let (tx, rx) = wake_pair().unwrap();
        (&tx).write_all(&[1]).unwrap();
        let mut fds = [PollFd { fd: rx.as_raw_fd(), events: POLLIN, revents: 0 }];
        let n = unsafe { poll(fds.as_mut_ptr(), 1, 1000) };
        assert_eq!(n, 1, "wake byte must be observable via poll");
        assert_ne!(fds[0].revents & POLLIN, 0);
        let mut sink = [0u8; 8];
        assert_eq!((&rx).read(&mut sink).unwrap(), 1);
    }

    #[test]
    fn net_options_default_is_sane() {
        let o = NetOptions::default();
        assert!(o.workers >= 1);
        assert!(o.queue_depth >= o.workers);
    }
}
