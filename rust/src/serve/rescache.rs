//! Serve-side result cache: whole replies keyed by canonicalized
//! request, generation-stamped so stale answers are structurally
//! impossible.
//!
//! The fast path for a repeated request is not recomputing it — it is
//! not touching the store at all. Entries are keyed by
//! `(run, class, canonicalized params)` and stamped with the front's
//! *generation stamp* (store resolve epoch ⊕ on-disk catalog identity,
//! see [`crate::pdfstore::PdfStore::catalog_stamp`]). Any event that
//! could change an answer moves the stamp:
//!
//! * a rerun appending a generation, `store compact`, or `store scrub
//!   --repair` atomically swaps `CATALOG.json` → new inode → new stamp;
//! * a mid-serve quarantine bumps the resolve epoch → new stamp.
//!
//! The first lookup under a moved stamp clears the cache wholesale
//! (`serve.result_cache.invalidations`); each entry additionally
//! carries the stamp it was computed under, so a racing insert from
//! the old generation can never be served after the swap. Degraded
//! replies are never inserted (the caller enforces this — a degraded
//! answer is exact but provisional, and must disappear as soon as a
//! repair lands, not live on in cache).
//!
//! Counters: the LRU core publishes `cache.result.{hits,misses,
//! evictions}`; hits are additionally split per request class as
//! `serve.<class>.cache_hit`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::serve::{Class, Reply, Request};
use crate::telemetry::{Counter, Registry};
use crate::util::lru::ShardedStampLru;

/// Default budget when the front enables the cache (`ServeFront::new`).
pub const DEFAULT_RESULT_CACHE_BYTES: u64 = 32 << 20;

/// Rough resident weight of one cached reply, for LRU budget
/// accounting (record vectors dominate; scalar replies are floored at
/// the key/entry overhead scale).
fn reply_weight(entry: &(u64, Arc<Reply>)) -> u64 {
    const REC: u64 = crate::pdfstore::REC_LEN as u64;
    const BASE: u64 = 64;
    BASE + match entry.1.as_ref() {
        Reply::Point(_) => REC,
        Reply::QuantileMean(_) => 8,
        Reply::Region(_) | Reply::Box(_) => 256,
        Reply::Radius(recs) | Reply::Knn(recs) => recs.len() as u64 * REC,
        Reply::DiffRun(d) => 256 + d.changed_cells.len() as u64 * 24,
    }
}

/// Canonical cache key: run label, class name, and every request
/// parameter in a fixed order. Floats are keyed by their exact bit
/// pattern — two requests share an entry only when they are the same
/// request, bit for bit.
pub fn request_key(run: &str, req: &Request) -> String {
    let class = req.class().name();
    match *req {
        Request::Point(id) => format!("{run}|{class}|{}", id.0),
        Request::Region(q) => {
            format!("{run}|{class}|{},{},{},{},{}", q.z, q.x0, q.x1, q.y0, q.y1)
        }
        Request::QuantileMean(q, p) => format!(
            "{run}|{class}|{},{},{},{},{}|{:016x}",
            q.z,
            q.x0,
            q.x1,
            q.y0,
            q.y1,
            p.to_bits()
        ),
        Request::Box(q) => format!(
            "{run}|{class}|{},{},{},{},{},{}",
            q.x0, q.x1, q.y0, q.y1, q.z0, q.z1
        ),
        Request::Radius(q) => format!(
            "{run}|{class}|{},{},{}|{:016x}",
            q.x,
            q.y,
            q.z,
            q.radius.to_bits()
        ),
        Request::Knn(q) => format!("{run}|{class}|{},{},{}|{}", q.x, q.y, q.z, q.k),
        Request::DiffRun(q) => format!(
            "{run}|{class}|{},{},{},{},{},{}",
            q.x0, q.x1, q.y0, q.y1, q.z0, q.z1
        ),
    }
}

/// Snapshot of the cache's observability counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResultCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: u64,
    pub entries: usize,
    /// Wholesale clears triggered by a generation-stamp move.
    pub invalidations: u64,
}

/// Generation-stamped reply cache (see module docs).
pub struct ResultCache {
    lru: ShardedStampLru<String, (u64, Arc<Reply>)>,
    /// Stamp the current contents were validated against. `0` is the
    /// "never rotated" sentinel: the first observed stamp is adopted
    /// without clearing (nothing resident can be stale yet) and without
    /// counting an invalidation.
    stamp: AtomicU64,
    invalidations: AtomicU64,
    /// Process-registry `serve.<class>.cache_hit` counters.
    class_hits: [Arc<Counter>; 7],
    ctr_invalidations: Arc<Counter>,
}

impl ResultCache {
    pub fn new(capacity_bytes: u64) -> ResultCache {
        let reg = Registry::global();
        ResultCache {
            // Mirrored in the process registry as `cache.result.*`.
            lru: ShardedStampLru::with_label(capacity_bytes, 8, reply_weight, "result"),
            stamp: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            class_hits: std::array::from_fn(|i| {
                reg.counter(&format!("serve.{}.cache_hit", Class::ALL[i].name()))
            }),
            ctr_invalidations: reg.counter("serve.result_cache.invalidations"),
        }
    }

    /// Drop everything when `stamp` differs from the stamp the resident
    /// entries were stored under. Racing callers may observe either
    /// stamp transiently; per-entry stamps (checked in [`Self::get`])
    /// make that race harmless.
    fn rotate_to(&self, stamp: u64) {
        let cur = self.stamp.load(Ordering::Acquire);
        if cur == stamp {
            return;
        }
        if self
            .stamp
            .compare_exchange(cur, stamp, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
            // Adopting the first real stamp is not an invalidation. (If
            // a genuine stamp ever collides with the sentinel, entries
            // survive one rotation unflushed; the per-entry stamp check
            // in `get` still refuses to serve them.)
            && cur != 0
        {
            self.lru.clear();
            self.invalidations.fetch_add(1, Ordering::Relaxed);
            self.ctr_invalidations.inc();
        }
    }

    /// Cached reply for `key` computed under exactly `stamp`, if any.
    pub fn get(&self, stamp: u64, class: Class, key: &str) -> Option<Arc<Reply>> {
        self.rotate_to(stamp);
        let (entry_stamp, reply) = self.lru.get(&key.to_string())?;
        if entry_stamp != stamp {
            return None;
        }
        self.class_hits[class as usize].inc();
        Some(reply)
    }

    /// Insert a reply computed under `stamp`. A stale insert (the stamp
    /// moved while the query ran) is stored with its original stamp and
    /// can therefore never be returned by [`Self::get`] for the new
    /// generation — at worst it wastes budget until the next rotation.
    pub fn put(&self, stamp: u64, key: String, reply: Arc<Reply>) {
        self.lru.put(key, (stamp, reply));
    }

    pub fn stats(&self) -> ResultCacheStats {
        let s = self.lru.stats();
        ResultCacheStats {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            bytes: s.bytes,
            entries: s.entries,
            invalidations: self.invalidations.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::PointId;
    use crate::pdfstore::{PdfRecord, RegionQuery};
    use crate::stats::DistType;

    fn point_reply(i: u64) -> Arc<Reply> {
        Arc::new(Reply::Point(PdfRecord {
            point: PointId(i),
            dist: DistType::Normal,
            error: 0.25,
            params: [0.0, 1.0, 0.0],
        }))
    }

    #[test]
    fn keys_are_canonical_and_distinct() {
        let q = RegionQuery { z: 1, x0: 0, x1: 3, y0: 2, y1: 5 };
        let a = request_key("r", &Request::Region(q));
        let b = request_key("r", &Request::QuantileMean(q, 0.5));
        let c = request_key("r", &Request::QuantileMean(q, 0.25));
        let d = request_key("other", &Request::Region(q));
        assert_eq!(a, request_key("r", &Request::Region(q)), "deterministic");
        assert!(a != b && b != c && a != d, "class, params and run all key");
    }

    #[test]
    fn stamp_move_invalidates_wholesale() {
        let c = ResultCache::new(1 << 20);
        let req = Request::Point(PointId(7));
        let key = request_key("r", &req);
        c.put(1, key.clone(), point_reply(7));
        assert!(c.get(1, Class::Point, &key).is_some());
        // New generation: same key, moved stamp → miss + wholesale clear.
        assert!(c.get(2, Class::Point, &key).is_none());
        assert_eq!(c.stats().invalidations, 1);
        assert_eq!(c.stats().entries, 0);
        // A stale insert under the old stamp is never served.
        c.put(1, key.clone(), point_reply(7));
        assert!(c.get(2, Class::Point, &key).is_none());
        c.put(2, key.clone(), point_reply(7));
        assert!(c.get(2, Class::Point, &key).is_some());
    }
}
