//! The serving front door: bounded admission over the query engine.
//!
//! The ROADMAP's millions-of-users north star needs more than a fast
//! [`crate::pdfstore::QueryEngine`] — it needs the engine to stay fast
//! *under overload*. An unbounded caller population would otherwise
//! pile onto the shared [`crate::runtime::hostpool`] budget until every
//! query is slow (the classic congestion collapse). [`ServeFront`] puts
//! two caps in front of the engine:
//!
//! * **`max_in_flight`** — queries executing concurrently. Admitted
//!   requests run on the *caller's* thread (the engine's internal
//!   fan-out still draws pool slots help-first), so the cap bounds how
//!   much of the compute budget serving may consume at once.
//! * **`queue_depth`** — callers allowed to wait for admission. One
//!   past that, requests are **shed immediately** with
//!   [`crate::PdfflowError::Overloaded`] instead of queuing without
//!   bound — the caller gets a fast, explicit signal to back off, and
//!   latency of admitted requests stays bounded by design.
//!
//! Every request is classified (point / region / analytic, plus the
//! spatial box / radius / knn / diff classes) and metered:
//! admitted, completed, shed, error counts plus latency and queue-wait
//! sums/maxima per class, and the peak in-flight / queued levels ever
//! observed — the counters a load balancer or autoscaler would watch.
//!
//! Replies carry a **degraded** flag ([`Served`]): when a segment has
//! been quarantined and the answer's slice range is served through
//! generation fallback, the reply is still exact for the surviving
//! data but the caller is told the store is running on fallback
//! copies. Degraded replies bump the per-class
//! `serve.<class>.degraded` counters.
//!
//! In front of admission sits the **result cache** ([`rescache`]): a
//! generation-stamped LRU of whole replies. A hit bypasses the gate
//! entirely (it still counts as admitted + completed, so the
//! request-ledger invariant `completed + shed + errors == requests`
//! holds); any catalog swap (rerun / compact / scrub repair) or
//! quarantine moves the stamp and flushes the cache wholesale, and
//! degraded replies are never inserted.
//!
//! [`closed_loop`] is the matching load driver: N synchronous clients,
//! each issuing its next request only after the previous one finished —
//! the closed-loop shape of `pdfflow serve --bench`, whose serving row
//! lands in `BENCH_queries.json` next to the raw engine numbers.
//! [`net`] puts the same front behind a real TCP socket (length-prefixed
//! JSON frames, poll-loop event handling, typed shed replies), and
//! [`net::closed_loop_net`] drives the identical request mix end-to-end
//! over loopback — wire included.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cube::{CubeDims, PointId};
use crate::pdfstore::{Fnv64, PdfRecord, QueryEngine, RegionQuery, RegionSummary};
use crate::spatial::{BoxQuery, KnnQuery, RadiusQuery, RunDiff};
use crate::telemetry::{Counter, Histogram, Registry, Span};
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

pub mod net;
pub mod rescache;
pub mod wire;

pub use rescache::{ResultCache, ResultCacheStats};

/// Admission knobs (`pdfflow serve --max-in-flight N --queue-depth N`).
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Queries executing concurrently; further arrivals wait.
    pub max_in_flight: usize,
    /// Callers allowed to wait for admission; beyond this, shed.
    pub queue_depth: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        let width = crate::runtime::hostpool::default_budget();
        ServeOptions {
            max_in_flight: width.max(1),
            queue_depth: 2 * width.max(1),
        }
    }
}

/// One query request through the front door.
#[derive(Clone, Copy, Debug)]
pub enum Request {
    /// Point lookup by flat id.
    Point(PointId),
    /// Analytical region summary.
    Region(RegionQuery),
    /// Mean quantile-`p` surface over a region (the heaviest class).
    QuantileMean(RegionQuery, f64),
    /// 3D box summary through the spatial tier.
    Box(BoxQuery),
    /// Records within a Euclidean radius of a point.
    Radius(RadiusQuery),
    /// k nearest stored records around a point.
    Knn(KnnQuery),
    /// Cross-run type/error diff over a box (needs a diff engine —
    /// [`ServeFront::with_diff`]).
    DiffRun(BoxQuery),
}

/// The matching replies.
#[derive(Clone, Debug)]
pub enum Reply {
    Point(PdfRecord),
    Region(RegionSummary),
    QuantileMean(f64),
    Box(RegionSummary),
    Radius(Vec<PdfRecord>),
    Knn(Vec<PdfRecord>),
    DiffRun(RunDiff),
}

/// A successful reply plus its serving condition.
#[derive(Clone, Debug)]
pub struct Served {
    pub reply: Reply,
    /// True when the answer's slice range is served through generation
    /// fallback around a quarantined segment: the data returned is
    /// intact (checksummed, coverage-proven), but it came from older
    /// generation copies and the store needs a scrub/repair.
    pub degraded: bool,
}

/// Request classes metered independently (their costs differ by orders
/// of magnitude, so one blended latency number would hide saturation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Point = 0,
    Region = 1,
    Analytic = 2,
    Box = 3,
    Radius = 4,
    Knn = 5,
    Diff = 6,
}

impl Class {
    pub const ALL: [Class; 7] = [
        Class::Point,
        Class::Region,
        Class::Analytic,
        Class::Box,
        Class::Radius,
        Class::Knn,
        Class::Diff,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Class::Point => "point",
            Class::Region => "region",
            Class::Analytic => "analytic",
            Class::Box => "box",
            Class::Radius => "radius",
            Class::Knn => "knn",
            Class::Diff => "diff",
        }
    }

    /// Static span name for this class's service-time span.
    fn span_name(self) -> &'static str {
        match self {
            Class::Point => "serve.point",
            Class::Region => "serve.region",
            Class::Analytic => "serve.analytic",
            Class::Box => "serve.box",
            Class::Radius => "serve.radius",
            Class::Knn => "serve.knn",
            Class::Diff => "serve.diff",
        }
    }
}

impl Request {
    pub fn class(&self) -> Class {
        match self {
            Request::Point(_) => Class::Point,
            Request::Region(_) => Class::Region,
            Request::QuantileMean(_, _) => Class::Analytic,
            Request::Box(_) => Class::Box,
            Request::Radius(_) => Class::Radius,
            Request::Knn(_) => Class::Knn,
            Request::DiffRun(_) => Class::Diff,
        }
    }
}

/// Always-on per-class counters (atomics; snapshot via `metrics()`).
///
/// Latency and queue wait live in log-linear [`Histogram`]s rather
/// than the old raw `AtomicU64` nanosecond sums: the histogram's sum
/// saturates instead of silently wrapping after ~2^64 ns of recorded
/// latency, and percentiles (p50/p95/p99) fall out of the buckets.
/// The histograms are front-owned `Arc`s so every `ServeFront` keeps
/// instance-exact metrics; [`ServeFront::register_metrics`] shares the
/// same handles with the process registry for exporters.
#[derive(Default)]
struct ClassCounters {
    admitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    errors: AtomicU64,
    /// Successful replies served with `degraded: true`.
    degraded: AtomicU64,
    /// End-to-end latency (queue wait + execution), nanoseconds.
    latency: Arc<Histogram>,
    /// Admission-queue wait, nanoseconds.
    queue: Arc<Histogram>,
}

/// Snapshot of one class's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassMetrics {
    /// Requests that passed admission (executed or errored).
    pub admitted: u64,
    /// Requests that returned a successful reply.
    pub completed: u64,
    /// Requests rejected at the door (queue full).
    pub shed: u64,
    /// Admitted requests whose query returned an error.
    pub errors: u64,
    /// Successful replies flagged `degraded` (generation fallback).
    pub degraded: u64,
    /// Summed end-to-end latency (queue wait + execution), seconds.
    pub latency_s_sum: f64,
    /// Worst end-to-end latency, seconds.
    pub latency_s_max: f64,
    /// Median end-to-end latency, seconds (log-linear bucket bound,
    /// ≤ ~3% relative error).
    pub latency_p50_s: f64,
    /// 95th-percentile end-to-end latency, seconds.
    pub latency_p95_s: f64,
    /// 99th-percentile end-to-end latency, seconds.
    pub latency_p99_s: f64,
    /// Summed admission-queue wait, seconds.
    pub queue_s_sum: f64,
}

impl ClassMetrics {
    pub fn avg_latency_s(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            self.latency_s_sum / self.admitted as f64
        }
    }
}

/// Snapshot of the whole front door.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeMetrics {
    pub point: ClassMetrics,
    pub region: ClassMetrics,
    pub analytic: ClassMetrics,
    pub spatial_box: ClassMetrics,
    pub radius: ClassMetrics,
    pub knn: ClassMetrics,
    pub diff: ClassMetrics,
    /// Most queries ever executing at once (must never exceed
    /// `max_in_flight` — the admission contract).
    pub peak_in_flight: usize,
    /// Most callers ever waiting at once (must never exceed
    /// `queue_depth`).
    pub peak_queued: usize,
}

impl ServeMetrics {
    pub fn class(&self, c: Class) -> &ClassMetrics {
        match c {
            Class::Point => &self.point,
            Class::Region => &self.region,
            Class::Analytic => &self.analytic,
            Class::Box => &self.spatial_box,
            Class::Radius => &self.radius,
            Class::Knn => &self.knn,
            Class::Diff => &self.diff,
        }
    }

    pub fn total_completed(&self) -> u64 {
        Class::ALL.iter().map(|&c| self.class(c).completed).sum()
    }

    pub fn total_shed(&self) -> u64 {
        Class::ALL.iter().map(|&c| self.class(c).shed).sum()
    }
}

/// Admission gate state (one mutex; the engine work runs outside it).
struct Gate {
    in_flight: usize,
    queued: usize,
    peak_in_flight: usize,
    peak_queued: usize,
}

/// The admission-controlled serving layer over one open [`QueryEngine`]
/// run. All methods take `&self`; one front is shared by every client
/// thread.
pub struct ServeFront {
    engine: QueryEngine,
    /// Side-B engine for cross-run diff requests ([`Self::with_diff`]).
    diff: Option<QueryEngine>,
    opts: ServeOptions,
    gate: Mutex<Gate>,
    cv: Condvar,
    classes: [ClassCounters; 7],
    /// Process-registry `serve.<class>.degraded` counters (shared
    /// handles; registered eagerly so exporters list them at zero).
    degraded_counters: [Arc<Counter>; 7],
    /// Generation-stamped whole-reply cache; `None` when disabled via
    /// [`Self::with_result_cache`]`(0)`.
    rescache: Option<ResultCache>,
}

impl ServeFront {
    pub fn new(engine: QueryEngine, opts: ServeOptions) -> ServeFront {
        ServeFront {
            engine,
            diff: None,
            opts: ServeOptions {
                max_in_flight: opts.max_in_flight.max(1),
                queue_depth: opts.queue_depth,
            },
            gate: Mutex::new(Gate {
                in_flight: 0,
                queued: 0,
                peak_in_flight: 0,
                peak_queued: 0,
            }),
            cv: Condvar::new(),
            classes: Default::default(),
            degraded_counters: std::array::from_fn(|i| {
                Registry::global().counter(&format!("serve.{}.degraded", Class::ALL[i].name()))
            }),
            rescache: Some(ResultCache::new(rescache::DEFAULT_RESULT_CACHE_BYTES)),
        }
    }

    /// Attach the side-B engine that [`Request::DiffRun`] compares
    /// against (typically another run of the same store, selected via
    /// the generational catalog).
    pub fn with_diff(mut self, diff: QueryEngine) -> ServeFront {
        self.diff = Some(diff);
        self
    }

    /// Resize the result cache (`pdfflow serve --result-cache-mb`);
    /// `0` disables it — every request then executes.
    pub fn with_result_cache(mut self, capacity_bytes: u64) -> ServeFront {
        self.rescache = (capacity_bytes > 0).then(|| ResultCache::new(capacity_bytes));
        self
    }

    pub fn engine(&self) -> &QueryEngine {
        &self.engine
    }

    /// The front's result cache, when enabled (stats / tests).
    pub fn result_cache(&self) -> Option<&ResultCache> {
        self.rescache.as_ref()
    }

    /// Identity of the store state every cached reply depends on: the
    /// resolve epoch (bumped by quarantines) folded with the on-disk
    /// catalog stamp (new inode on every rerun / compact / scrub
    /// repair), over both engines for diff-capable fronts. Any event
    /// that could change an answer moves this value.
    pub fn generation_stamp(&self) -> u64 {
        let mut h = Fnv64::new();
        h.update(&self.engine.store().epoch().to_le_bytes());
        h.update(&self.engine.store().catalog_stamp().to_le_bytes());
        if let Some(d) = &self.diff {
            h.update(&d.store().epoch().to_le_bytes());
            h.update(&d.store().catalog_stamp().to_le_bytes());
        }
        h.finish()
    }

    /// Count a shed that happened upstream of [`Self::submit`] — the
    /// socket layer sheds at its bounded dispatch queue without ever
    /// entering the gate, and those rejections must land in the same
    /// per-class ledger as gate sheds.
    pub(crate) fn note_shed(&self, class: Class) {
        self.classes[class as usize].shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Share this front's per-class latency/queue histograms with the
    /// process registry as `serve.<class>.latency_ns` /
    /// `serve.<class>.queue_ns`, so `--metrics-out` snapshots carry
    /// them. Call once on the front actually serving traffic (tests
    /// construct throwaway fronts that stay unregistered).
    pub fn register_metrics(&self) {
        let reg = Registry::global();
        for c in Class::ALL {
            let counters = &self.classes[c as usize];
            reg.register_histogram(
                &format!("serve.{}.latency_ns", c.name()),
                Arc::clone(&counters.latency),
            );
            reg.register_histogram(
                &format!("serve.{}.queue_ns", c.name()),
                Arc::clone(&counters.queue),
            );
        }
    }

    pub fn options(&self) -> ServeOptions {
        self.opts
    }

    /// True when `req`'s answer would be served through generation
    /// fallback around a quarantined segment. Evaluated *after* the
    /// query ran, so a quarantine triggered by this very request is
    /// reflected in its own reply.
    fn request_degraded(&self, req: &Request) -> bool {
        let store = self.engine.store();
        if !store.is_degraded() && !matches!(req, Request::DiffRun(_)) {
            return false;
        }
        let dims = store.dims();
        match *req {
            Request::Point(id) => {
                let (_, _, z) = dims.coords(id);
                store.degraded_in(z, z)
            }
            Request::Region(q) | Request::QuantileMean(q, _) => store.degraded_in(q.z, q.z),
            Request::Box(q) => store.degraded_in(q.z0, q.z1),
            Request::Radius(q) => {
                let b = q.bounding_box(&dims);
                !b.is_empty() && store.degraded_in(b.z0, b.z1)
            }
            // kNN may expand to any slice, so any quarantine taints it.
            Request::Knn(_) => store.is_degraded(),
            Request::DiffRun(q) => {
                store.degraded_in(q.z0, q.z1)
                    || self
                        .diff
                        .as_ref()
                        .is_some_and(|d| d.store().degraded_in(q.z0, q.z1))
            }
        }
    }

    /// Submit one request through admission control. Blocks while
    /// queued (bounded by `queue_depth` peers), sheds with
    /// [`PdfflowError::Overloaded`] when the queue is full. Successful
    /// replies say whether they were served degraded ([`Served`]).
    ///
    /// A result-cache hit returns before the admission gate — serving a
    /// memoized reply draws no engine compute, so making it wait behind
    /// the in-flight cap would only let queued misses slow down hits.
    /// Hits still count as admitted + completed (the ledger invariant),
    /// and record their (near-zero) latency in the class histogram.
    pub fn submit(&self, req: Request) -> Result<Served> {
        let class = &self.classes[req.class() as usize];
        let arrived = Instant::now();
        let cache_key = self.rescache.as_ref().map(|cache| {
            let key = rescache::request_key(self.engine.store().run_key().label(), &req);
            let stamp = self.generation_stamp();
            (cache, key, stamp)
        });
        if let Some((cache, key, stamp)) = &cache_key {
            if let Some(reply) = cache.get(*stamp, req.class(), key) {
                class.admitted.fetch_add(1, Ordering::Relaxed);
                class.completed.fetch_add(1, Ordering::Relaxed);
                class.queue.record_duration(Duration::ZERO);
                class.latency.record_duration(arrived.elapsed());
                return Ok(Served {
                    reply: (*reply).clone(),
                    degraded: false,
                });
            }
        }
        // Admission: take an execution slot or a bounded queue slot.
        {
            let mut g = self.gate.lock().unwrap();
            if g.in_flight >= self.opts.max_in_flight {
                if g.queued >= self.opts.queue_depth {
                    class.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(PdfflowError::Overloaded(format!(
                        "serve queue full ({} in flight, {} queued)",
                        g.in_flight, g.queued
                    )));
                }
                g.queued += 1;
                g.peak_queued = g.peak_queued.max(g.queued);
                while g.in_flight >= self.opts.max_in_flight {
                    g = self.cv.wait(g).unwrap();
                }
                g.queued -= 1;
            }
            g.in_flight += 1;
            g.peak_in_flight = g.peak_in_flight.max(g.in_flight);
        }
        let queue_wait = arrived.elapsed();
        class.admitted.fetch_add(1, Ordering::Relaxed);

        // Service-time span (the latency histogram below covers the
        // full queue-wait + execution path; this span is execution
        // only).
        let span = Span::enter(req.class().span_name());
        let result = match req {
            Request::Point(id) => self.engine.point_by_id(id).map(Reply::Point),
            Request::Region(q) => self.engine.region_summary(&q).map(Reply::Region),
            Request::QuantileMean(q, p) => {
                self.engine.region_quantile_mean(&q, p).map(Reply::QuantileMean)
            }
            Request::Box(q) => self.engine.box_summary(&q).map(Reply::Box),
            Request::Radius(q) => self.engine.radius_records(&q).map(Reply::Radius),
            Request::Knn(q) => self.engine.knn(&q).map(Reply::Knn),
            Request::DiffRun(q) => match &self.diff {
                Some(other) => self.engine.diff_run(other, &q).map(Reply::DiffRun),
                None => Err(PdfflowError::InvalidArg(
                    "diff requests need a diff engine (ServeFront::with_diff)".into(),
                )),
            },
        };

        drop(span);

        // Release the slot before metering, so a successor is admitted
        // as early as possible.
        {
            let mut g = self.gate.lock().unwrap();
            g.in_flight -= 1;
        }
        self.cv.notify_one();

        class.queue.record_duration(queue_wait);
        class.latency.record_duration(arrived.elapsed());
        match result {
            Ok(reply) => {
                class.completed.fetch_add(1, Ordering::Relaxed);
                let degraded = self.request_degraded(&req);
                if degraded {
                    class.degraded.fetch_add(1, Ordering::Relaxed);
                    self.degraded_counters[req.class() as usize].inc();
                } else if let Some((cache, key, stamp)) = cache_key {
                    // Inserted under the *pre-execution* stamp: if the
                    // catalog swapped or a quarantine landed while the
                    // query ran, the entry can never be served for the
                    // new generation (per-entry stamp check).
                    cache.put(stamp, key, Arc::new(reply.clone()));
                }
                Ok(Served { reply, degraded })
            }
            Err(e) => {
                class.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    pub fn metrics(&self) -> ServeMetrics {
        let snap = |c: &ClassCounters| ClassMetrics {
            admitted: c.admitted.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            shed: c.shed.load(Ordering::Relaxed),
            errors: c.errors.load(Ordering::Relaxed),
            degraded: c.degraded.load(Ordering::Relaxed),
            latency_s_sum: c.latency.sum() as f64 / 1e9,
            latency_s_max: c.latency.max() as f64 / 1e9,
            latency_p50_s: c.latency.quantile(0.50) as f64 / 1e9,
            latency_p95_s: c.latency.quantile(0.95) as f64 / 1e9,
            latency_p99_s: c.latency.quantile(0.99) as f64 / 1e9,
            queue_s_sum: c.queue.sum() as f64 / 1e9,
        };
        let g = self.gate.lock().unwrap();
        ServeMetrics {
            point: snap(&self.classes[0]),
            region: snap(&self.classes[1]),
            analytic: snap(&self.classes[2]),
            spatial_box: snap(&self.classes[3]),
            radius: snap(&self.classes[4]),
            knn: snap(&self.classes[5]),
            diff: snap(&self.classes[6]),
            peak_in_flight: g.peak_in_flight,
            peak_queued: g.peak_queued,
        }
    }
}

/// Result of one closed-loop load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub clients: usize,
    /// Requests issued across all clients (completed + shed + errors).
    pub requests: u64,
    pub secs: f64,
    /// Successful replies per second.
    pub throughput: f64,
    pub metrics: ServeMetrics,
}

/// Deterministic request mix for one client: mostly points, some region
/// summaries, a few quantile surfaces, and a sprinkle of spatial box /
/// radius / kNN queries — the north-star read blend. (Diff requests are
/// not in the generic mix; they need a second run attached.) Shared by
/// the in-process [`closed_loop`] and the socket-driven
/// [`net::closed_loop_net`], so the two drivers issue the same blend.
pub(crate) fn next_request(rng: &mut Rng, dims: &CubeDims, slices: &[usize]) -> Request {
    let z = slices[rng.below(slices.len())];
    let slice_pts = dims.slice_points() as u64;
    match rng.below(16) {
        0..=9 => Request::Point(PointId(z as u64 * slice_pts + rng.below(slice_pts as usize) as u64)),
        10 | 11 => {
            let x0 = rng.below((dims.nx / 2).max(1));
            let y0 = rng.below((dims.ny / 2).max(1));
            Request::Region(RegionQuery {
                z,
                x0,
                x1: (x0 + dims.nx / 2).min(dims.nx - 1),
                y0,
                y1: (y0 + dims.ny / 2).min(dims.ny - 1),
            })
        }
        12 => {
            let y0 = rng.below((dims.ny / 2).max(1));
            Request::QuantileMean(
                RegionQuery {
                    z,
                    x0: 0,
                    x1: (dims.nx / 4).min(dims.nx - 1),
                    y0,
                    y1: (y0 + dims.ny / 4).min(dims.ny - 1),
                },
                0.5,
            )
        }
        13 => {
            let x0 = rng.below((dims.nx / 2).max(1));
            let y0 = rng.below((dims.ny / 2).max(1));
            Request::Box(BoxQuery {
                x0,
                x1: (x0 + dims.nx / 2).min(dims.nx - 1),
                y0,
                y1: (y0 + dims.ny / 2).min(dims.ny - 1),
                z0: z.saturating_sub(1),
                z1: (z + 1).min(dims.nz - 1),
            })
        }
        14 => Request::Radius(RadiusQuery {
            x: rng.below(dims.nx),
            y: rng.below(dims.ny),
            z,
            radius: 1.0 + rng.below(4) as f64,
        }),
        _ => Request::Knn(KnnQuery {
            x: rng.below(dims.nx),
            y: rng.below(dims.ny),
            z,
            k: 1 + rng.below(16),
        }),
    }
}

/// Drive the front door with `clients` synchronous clients, each
/// issuing `requests_per_client` requests back-to-back (closed loop: a
/// client's next request waits for its previous reply or shed). Clients
/// are plain OS threads — they model external callers, not pool work;
/// the admitted queries inside still fan out help-first on the shared
/// host pool. Shed requests count as issued, not completed.
pub fn closed_loop(
    front: &ServeFront,
    clients: usize,
    requests_per_client: usize,
    seed: u64,
) -> LoadReport {
    let clients = clients.max(1);
    let slices = front.engine().store().slices();
    assert!(!slices.is_empty(), "closed_loop needs a non-empty store");
    let dims = front.engine().dims();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for k in 0..clients {
            let slices = &slices;
            let dims = &dims;
            s.spawn(move || {
                let mut rng = Rng::new(seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(k as u64 + 1)));
                for _ in 0..requests_per_client {
                    let req = next_request(&mut rng, dims, slices);
                    // Shed and query errors are the driver's signal to
                    // keep going — a real client would back off and
                    // retry; the closed loop just issues its next
                    // request.
                    let _ = front.submit(req);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let metrics = front.metrics();
    let requests = (clients * requests_per_client) as u64;
    LoadReport {
        clients,
        requests,
        secs,
        throughput: if secs > 0.0 {
            metrics.total_completed() as f64 / secs
        } else {
            0.0
        },
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_names_and_request_classification() {
        assert_eq!(Request::Point(PointId(0)).class(), Class::Point);
        let q = RegionQuery { z: 0, x0: 0, x1: 1, y0: 0, y1: 1 };
        assert_eq!(Request::Region(q).class(), Class::Region);
        assert_eq!(Request::QuantileMean(q, 0.5).class(), Class::Analytic);
        let b = BoxQuery { x0: 0, x1: 1, y0: 0, y1: 1, z0: 0, z1: 0 };
        assert_eq!(Request::Box(b).class(), Class::Box);
        assert_eq!(Request::DiffRun(b).class(), Class::Diff);
        let r = RadiusQuery { x: 0, y: 0, z: 0, radius: 1.0 };
        assert_eq!(Request::Radius(r).class(), Class::Radius);
        assert_eq!(Request::Knn(KnnQuery { x: 0, y: 0, z: 0, k: 3 }).class(), Class::Knn);
        for (i, c) in Class::ALL.into_iter().enumerate() {
            assert_eq!(c as usize, i, "class discriminants index the counter array");
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn class_metrics_avg_handles_zero() {
        let m = ClassMetrics::default();
        assert_eq!(m.avg_latency_s(), 0.0);
    }
}
