//! Wire form of the serve protocol: length-prefixed JSON frames.
//!
//! One frame is a 4-byte little-endian payload length followed by one
//! UTF-8 JSON document, capped at [`MAX_FRAME`] bytes (a corrupt or
//! hostile length prefix must not allocate unbounded memory). Requests
//! carry an `"op"` discriminator; responses carry a `"status"` of
//! `"ok"`, `"shed"` (admission control said no — a *typed* rejection,
//! the connection stays usable) or `"error"` (typed query error).
//!
//! Fidelity contract: a reply decoded from the wire is **bit-identical**
//! to the in-process reply. Integers ride through JSON numbers exactly
//! (all quantities here are far below 2^53); `f64`s rely on Rust's
//! shortest-roundtrip float formatting; `f32`s widen to `f64` exactly
//! and narrow back exactly. `tests/serve_net.rs` pins this end to end
//! for every request class, and the codec tests below pin raw
//! encode∘decode identity.

use std::io::{Read, Write};

use crate::cube::{CubeDims, PointId};
use crate::pdfstore::{PdfRecord, RegionQuery, RegionSummary, ERROR_HIST_BINS};
use crate::serve::{Reply, Request, Served};
use crate::spatial::{BoxQuery, KnnQuery, RadiusQuery, RunDiff};
use crate::stats::DistType;
use crate::util::json::Json;
use crate::{PdfflowError, Result};

/// Frame payload cap (1 MiB): larger requests are malformed, larger
/// replies mean the caller asked for a result set that belongs in a
/// batch export, not a serving hot path.
pub const MAX_FRAME: usize = 1 << 20;

/// Store facts a client needs before it can generate requests
/// (`{"op":"meta"}` — the socket closed-loop driver bootstraps on it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeMeta {
    pub dims: CubeDims,
    /// Persisted slice indices of the served run.
    pub slices: Vec<usize>,
    /// Run label (catalog key) being served.
    pub run: String,
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn unum(n: usize) -> Json {
    Json::Num(n as f64)
}

fn bad(what: &str) -> PdfflowError {
    PdfflowError::Format(format!("wire: missing or malformed field `{what}`"))
}

fn get_usize(j: &Json, k: &str) -> Result<usize> {
    j.get(k).and_then(Json::as_usize).ok_or_else(|| bad(k))
}

fn get_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| bad(k))
}

fn get_u64(j: &Json, k: &str) -> Result<u64> {
    j.get(k).and_then(Json::as_f64).map(|n| n as u64).ok_or_else(|| bad(k))
}

// ---------------------------------------------------------------- frames

/// Write one frame: `u32` little-endian length + JSON bytes.
pub fn write_frame(w: &mut impl Write, doc: &Json) -> std::io::Result<()> {
    let payload = doc.to_string().into_bytes();
    debug_assert!(payload.len() <= MAX_FRAME, "oversized frame produced locally");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)
}

/// Read one frame. `Ok(None)` on clean EOF at a frame boundary; frames
/// over [`MAX_FRAME`] or unparsable payloads are `InvalidData` errors.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Json>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {MAX_FRAME}"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

// -------------------------------------------------------------- requests

fn region_fields(q: &RegionQuery) -> Vec<(&'static str, Json)> {
    vec![
        ("z", unum(q.z)),
        ("x0", unum(q.x0)),
        ("x1", unum(q.x1)),
        ("y0", unum(q.y0)),
        ("y1", unum(q.y1)),
    ]
}

fn box_fields(q: &BoxQuery) -> Vec<(&'static str, Json)> {
    vec![
        ("x0", unum(q.x0)),
        ("x1", unum(q.x1)),
        ("y0", unum(q.y0)),
        ("y1", unum(q.y1)),
        ("z0", unum(q.z0)),
        ("z1", unum(q.z1)),
    ]
}

fn region_of(j: &Json) -> Result<RegionQuery> {
    Ok(RegionQuery {
        z: get_usize(j, "z")?,
        x0: get_usize(j, "x0")?,
        x1: get_usize(j, "x1")?,
        y0: get_usize(j, "y0")?,
        y1: get_usize(j, "y1")?,
    })
}

fn box_of(j: &Json) -> Result<BoxQuery> {
    Ok(BoxQuery {
        x0: get_usize(j, "x0")?,
        x1: get_usize(j, "x1")?,
        y0: get_usize(j, "y0")?,
        y1: get_usize(j, "y1")?,
        z0: get_usize(j, "z0")?,
        z1: get_usize(j, "z1")?,
    })
}

/// Encode one query request (`op` discriminated).
pub fn encode_request(req: &Request) -> Json {
    match *req {
        Request::Point(id) => {
            Json::obj(vec![("op", Json::Str("point".into())), ("id", num(id.0 as f64))])
        }
        Request::Region(q) => {
            let mut f = vec![("op", Json::Str("region".into()))];
            f.extend(region_fields(&q));
            Json::obj(f)
        }
        Request::QuantileMean(q, p) => {
            let mut f = vec![("op", Json::Str("quantile_mean".into()))];
            f.extend(region_fields(&q));
            f.push(("p", num(p)));
            Json::obj(f)
        }
        Request::Box(q) => {
            let mut f = vec![("op", Json::Str("box".into()))];
            f.extend(box_fields(&q));
            Json::obj(f)
        }
        Request::Radius(q) => Json::obj(vec![
            ("op", Json::Str("radius".into())),
            ("x", unum(q.x)),
            ("y", unum(q.y)),
            ("z", unum(q.z)),
            ("radius", num(q.radius)),
        ]),
        Request::Knn(q) => Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("x", unum(q.x)),
            ("y", unum(q.y)),
            ("z", unum(q.z)),
            ("k", unum(q.k)),
        ]),
        Request::DiffRun(q) => {
            let mut f = vec![("op", Json::Str("diff_run".into()))];
            f.extend(box_fields(&q));
            Json::obj(f)
        }
    }
}

/// The non-query control frames a server must also understand.
#[derive(Clone, Debug)]
pub enum ControlOrQuery {
    Query(Request),
    /// `{"op":"meta"}` — describe the served store.
    Meta,
    /// `{"op":"shutdown"}` — ack, then stop the server gracefully.
    Shutdown,
}

/// Decode one inbound frame into a query or control operation.
pub fn decode_request(j: &Json) -> Result<ControlOrQuery> {
    let op = j.get("op").and_then(Json::as_str).ok_or_else(|| bad("op"))?;
    let req = match op {
        "meta" => return Ok(ControlOrQuery::Meta),
        "shutdown" => return Ok(ControlOrQuery::Shutdown),
        "point" => Request::Point(PointId(get_u64(j, "id")?)),
        "region" => Request::Region(region_of(j)?),
        "quantile_mean" => Request::QuantileMean(region_of(j)?, get_f64(j, "p")?),
        "box" => Request::Box(box_of(j)?),
        "radius" => Request::Radius(RadiusQuery {
            x: get_usize(j, "x")?,
            y: get_usize(j, "y")?,
            z: get_usize(j, "z")?,
            radius: get_f64(j, "radius")?,
        }),
        "knn" => Request::Knn(KnnQuery {
            x: get_usize(j, "x")?,
            y: get_usize(j, "y")?,
            z: get_usize(j, "z")?,
            k: get_usize(j, "k")?,
        }),
        "diff_run" => Request::DiffRun(box_of(j)?),
        other => {
            return Err(PdfflowError::Format(format!("wire: unknown op `{other}`")));
        }
    };
    Ok(ControlOrQuery::Query(req))
}

// --------------------------------------------------------------- replies

fn encode_record(r: &PdfRecord) -> Json {
    Json::obj(vec![
        ("point", num(r.point.0 as f64)),
        ("dist", unum(r.dist.id())),
        // f32 → f64 widening is exact; narrowed back on decode.
        ("error", num(r.error as f64)),
        (
            "params",
            Json::Arr(r.params.iter().map(|&p| num(p as f64)).collect()),
        ),
    ])
}

fn decode_record(j: &Json) -> Result<PdfRecord> {
    let params = j.get("params").and_then(Json::as_arr).ok_or_else(|| bad("params"))?;
    if params.len() != 3 {
        return Err(bad("params"));
    }
    let mut p = [0f32; 3];
    for (slot, v) in p.iter_mut().zip(params) {
        *slot = v.as_f64().ok_or_else(|| bad("params"))? as f32;
    }
    Ok(PdfRecord {
        point: PointId(get_u64(j, "point")?),
        dist: DistType::from_id(get_usize(j, "dist")?)
            .ok_or_else(|| bad("dist"))?,
        error: get_f64(j, "error")? as f32,
        params: p,
    })
}

fn encode_counts(c: &[u64]) -> Json {
    Json::Arr(c.iter().map(|&n| num(n as f64)).collect())
}

fn decode_counts<const N: usize>(j: &Json, k: &str) -> Result<[u64; N]> {
    let arr = j.get(k).and_then(Json::as_arr).ok_or_else(|| bad(k))?;
    if arr.len() != N {
        return Err(bad(k));
    }
    let mut out = [0u64; N];
    for (slot, v) in out.iter_mut().zip(arr) {
        *slot = v.as_f64().ok_or_else(|| bad(k))? as u64;
    }
    Ok(out)
}

fn encode_summary(s: &RegionSummary) -> Json {
    Json::obj(vec![
        ("n_points", unum(s.n_points)),
        ("avg_error", num(s.avg_error)),
        ("max_error", num(s.max_error)),
        ("type_counts", encode_counts(&s.type_counts)),
        ("error_hist", encode_counts(&s.error_hist)),
    ])
}

fn decode_summary(j: &Json) -> Result<RegionSummary> {
    Ok(RegionSummary {
        n_points: get_usize(j, "n_points")?,
        avg_error: get_f64(j, "avg_error")?,
        max_error: get_f64(j, "max_error")?,
        type_counts: decode_counts::<10>(j, "type_counts")?,
        error_hist: decode_counts::<ERROR_HIST_BINS>(j, "error_hist")?,
    })
}

fn encode_cells(cells: &[(usize, usize, usize)]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|&(x, y, z)| Json::Arr(vec![unum(x), unum(y), unum(z)]))
            .collect(),
    )
}

fn decode_cells(j: &Json, k: &str) -> Result<Vec<(usize, usize, usize)>> {
    let arr = j.get(k).and_then(Json::as_arr).ok_or_else(|| bad(k))?;
    arr.iter()
        .map(|c| {
            let c = c.as_arr().filter(|c| c.len() == 3).ok_or_else(|| bad(k))?;
            let at = |i: usize| c[i].as_usize().ok_or_else(|| bad(k));
            Ok((at(0)?, at(1)?, at(2)?))
        })
        .collect()
}

fn encode_diff(d: &RunDiff) -> Json {
    Json::obj(vec![
        ("n_compared", num(d.n_compared as f64)),
        ("only_a", num(d.only_a as f64)),
        ("only_b", num(d.only_b as f64)),
        ("type_changed", num(d.type_changed as f64)),
        ("type_counts_a", encode_counts(&d.type_counts_a)),
        ("type_counts_b", encode_counts(&d.type_counts_b)),
        ("err_delta_sum", num(d.err_delta_sum)),
        ("max_err_delta", num(d.max_err_delta as f64)),
        ("changed_cells", encode_cells(&d.changed_cells)),
        (
            "grid",
            Json::obj(vec![
                ("nx", unum(d.grid.dims.nx)),
                ("ny", unum(d.grid.dims.ny)),
                ("nz", unum(d.grid.dims.nz)),
                ("sx", unum(d.grid.sx)),
                ("sy", unum(d.grid.sy)),
                ("sz", unum(d.grid.sz)),
            ]),
        ),
    ])
}

fn decode_diff(j: &Json) -> Result<RunDiff> {
    let g = j.get("grid").ok_or_else(|| bad("grid"))?;
    let dims = CubeDims::new(get_usize(g, "nx")?, get_usize(g, "ny")?, get_usize(g, "nz")?);
    let grid = crate::cube::CellGrid::new(
        dims,
        get_usize(g, "sx")?,
        get_usize(g, "sy")?,
        get_usize(g, "sz")?,
    );
    Ok(RunDiff {
        n_compared: get_usize(j, "n_compared")?,
        only_a: get_usize(j, "only_a")?,
        only_b: get_usize(j, "only_b")?,
        type_changed: get_usize(j, "type_changed")?,
        type_counts_a: decode_counts::<10>(j, "type_counts_a")?,
        type_counts_b: decode_counts::<10>(j, "type_counts_b")?,
        err_delta_sum: get_f64(j, "err_delta_sum")?,
        max_err_delta: get_f64(j, "max_err_delta")? as f32,
        changed_cells: decode_cells(j, "changed_cells")?,
        grid,
    })
}

fn encode_reply(r: &Reply) -> (&'static str, Json) {
    match r {
        Reply::Point(rec) => ("point", encode_record(rec)),
        Reply::Region(s) => ("region", encode_summary(s)),
        Reply::QuantileMean(v) => ("quantile_mean", Json::obj(vec![("value", num(*v))])),
        Reply::Box(s) => ("box", encode_summary(s)),
        Reply::Radius(recs) => ("radius", encode_records(recs)),
        Reply::Knn(recs) => ("knn", encode_records(recs)),
        Reply::DiffRun(d) => ("diff_run", encode_diff(d)),
    }
}

fn encode_records(recs: &[PdfRecord]) -> Json {
    Json::obj(vec![(
        "records",
        Json::Arr(recs.iter().map(encode_record).collect()),
    )])
}

fn decode_records(j: &Json) -> Result<Vec<PdfRecord>> {
    let arr = j.get("records").and_then(Json::as_arr).ok_or_else(|| bad("records"))?;
    arr.iter().map(decode_record).collect()
}

// ------------------------------------------------------------- responses

/// Encode a successful reply frame.
pub fn encode_served(s: &Served) -> Json {
    let (class, body) = encode_reply(&s.reply);
    Json::obj(vec![
        ("status", Json::Str("ok".into())),
        ("class", Json::Str(class.into())),
        ("degraded", Json::Bool(s.degraded)),
        ("reply", body),
    ])
}

/// Encode a failed request: admission sheds become `status:"shed"` (a
/// typed, retryable rejection — the connection stays open), everything
/// else `status:"error"` with the error kind preserved.
pub fn encode_error(e: &PdfflowError) -> Json {
    if e.is_overload() {
        return Json::obj(vec![
            ("status", Json::Str("shed".into())),
            ("error", Json::Str(e.to_string())),
        ]);
    }
    let kind = match e {
        PdfflowError::Format(_) => "format",
        PdfflowError::InvalidArg(_) => "invalid_arg",
        PdfflowError::Io(_) => "io",
        _ => "other",
    };
    Json::obj(vec![
        ("status", Json::Str("error".into())),
        ("kind", Json::Str(kind.into())),
        ("error", Json::Str(e.to_string())),
    ])
}

/// Encode the `{"op":"meta"}` response.
pub fn encode_meta(m: &ServeMeta) -> Json {
    Json::obj(vec![
        ("status", Json::Str("ok".into())),
        (
            "meta",
            Json::obj(vec![
                ("nx", unum(m.dims.nx)),
                ("ny", unum(m.dims.ny)),
                ("nz", unum(m.dims.nz)),
                ("slices", Json::Arr(m.slices.iter().map(|&z| unum(z)).collect())),
                ("run", Json::Str(m.run.clone())),
            ]),
        ),
    ])
}

/// Decode a meta response (client side).
pub fn decode_meta(j: &Json) -> Result<ServeMeta> {
    let m = j.get("meta").ok_or_else(|| bad("meta"))?;
    let slices = m
        .get("slices")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("slices"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| bad("slices")))
        .collect::<Result<Vec<usize>>>()?;
    Ok(ServeMeta {
        dims: CubeDims::new(get_usize(m, "nx")?, get_usize(m, "ny")?, get_usize(m, "nz")?),
        slices,
        run: m.get("run").and_then(Json::as_str).ok_or_else(|| bad("run"))?.to_string(),
    })
}

/// Decode a query response (client side): `ok` frames become [`Served`],
/// `shed` frames become [`PdfflowError::Overloaded`], `error` frames
/// are re-typed from their `kind`.
pub fn decode_response(j: &Json) -> Result<Served> {
    let status = j.get("status").and_then(Json::as_str).ok_or_else(|| bad("status"))?;
    match status {
        "ok" => {}
        "shed" => {
            let msg = j.get("error").and_then(Json::as_str).unwrap_or("shed");
            // Strip the error-display prefix the server serialized with.
            let msg = msg.strip_prefix("overloaded: ").unwrap_or(msg);
            return Err(PdfflowError::Overloaded(msg.to_string()));
        }
        "error" => {
            let msg = j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown server error")
                .to_string();
            return Err(match j.get("kind").and_then(Json::as_str) {
                Some("invalid_arg") => PdfflowError::InvalidArg(msg),
                Some("io") => PdfflowError::Io(std::io::Error::other(msg)),
                _ => PdfflowError::Format(msg),
            });
        }
        other => {
            return Err(PdfflowError::Format(format!("wire: unknown status `{other}`")));
        }
    }
    let degraded = j.get("degraded").and_then(Json::as_bool).unwrap_or(false);
    let class = j.get("class").and_then(Json::as_str).ok_or_else(|| bad("class"))?;
    let body = j.get("reply").ok_or_else(|| bad("reply"))?;
    let reply = match class {
        "point" => Reply::Point(decode_record(body)?),
        "region" => Reply::Region(decode_summary(body)?),
        "quantile_mean" => Reply::QuantileMean(get_f64(body, "value")?),
        "box" => Reply::Box(decode_summary(body)?),
        "radius" => Reply::Radius(decode_records(body)?),
        "knn" => Reply::Knn(decode_records(body)?),
        "diff_run" => Reply::DiffRun(decode_diff(body)?),
        other => {
            return Err(PdfflowError::Format(format!("wire: unknown class `{other}`")));
        }
    };
    Ok(Served { reply, degraded })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> PdfRecord {
        PdfRecord {
            point: PointId(i),
            dist: DistType::from_id((i % 10) as usize).unwrap(),
            // Bit-awkward values on purpose: exercise shortest-roundtrip
            // float formatting, not just pretty decimals.
            error: 0.1f32 + (i as f32) / 3.0,
            params: [1.0 / 3.0, -(i as f32) / 7.0, f32::MIN_POSITIVE],
        }
    }

    fn roundtrip_request(req: Request) {
        let encoded = encode_request(&req);
        let text = encoded.to_string();
        let parsed = Json::parse(&text).unwrap();
        match decode_request(&parsed).unwrap() {
            ControlOrQuery::Query(back) => {
                assert_eq!(format!("{req:?}"), format!("{back:?}"), "request mutated on wire")
            }
            other => panic!("query decoded as control frame {other:?}"),
        }
    }

    fn roundtrip_served(s: Served) {
        let text = encode_served(&s).to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = decode_response(&parsed).unwrap();
        assert_eq!(back.degraded, s.degraded);
        assert_eq!(format!("{:?}", back.reply), format!("{:?}", s.reply), "reply mutated on wire");
    }

    #[test]
    fn requests_roundtrip_bit_identically() {
        let region = RegionQuery { z: 2, x0: 1, x1: 30, y0: 0, y1: 15 };
        let bx = BoxQuery { x0: 0, x1: 7, y0: 1, y1: 9, z0: 1, z1: 3 };
        roundtrip_request(Request::Point(PointId(123_456)));
        roundtrip_request(Request::Region(region));
        roundtrip_request(Request::QuantileMean(region, 0.05 + 0.9 / 7.0));
        roundtrip_request(Request::Box(bx));
        roundtrip_request(Request::Radius(RadiusQuery { x: 3, y: 4, z: 1, radius: 2.5 + 1.0 / 3.0 }));
        roundtrip_request(Request::Knn(KnnQuery { x: 9, y: 2, z: 0, k: 17 }));
        roundtrip_request(Request::DiffRun(bx));
    }

    #[test]
    fn replies_roundtrip_bit_identically() {
        let summary = RegionSummary {
            n_points: 512,
            avg_error: 0.123_456_789_012_345,
            max_error: 2.0 / 3.0,
            type_counts: [1, 0, 3, 0, 0, 7, 0, 0, 0, 501],
            error_hist: [64, 64, 64, 64, 64, 64, 64, 48],
        };
        roundtrip_served(Served { reply: Reply::Point(rec(5)), degraded: false });
        roundtrip_served(Served { reply: Reply::Region(summary.clone()), degraded: true });
        roundtrip_served(Served {
            reply: Reply::QuantileMean(1.0 / 3.0),
            degraded: false,
        });
        roundtrip_served(Served { reply: Reply::Box(summary), degraded: false });
        roundtrip_served(Served {
            reply: Reply::Radius((0..5).map(rec).collect()),
            degraded: false,
        });
        roundtrip_served(Served {
            reply: Reply::Knn((10..13).map(rec).collect()),
            degraded: true,
        });
        let dims = CubeDims::new(16, 8, 4);
        roundtrip_served(Served {
            reply: Reply::DiffRun(RunDiff {
                n_compared: 100,
                only_a: 3,
                only_b: 0,
                type_changed: 9,
                type_counts_a: [10; 10],
                type_counts_b: [9, 11, 10, 10, 10, 10, 10, 10, 10, 10],
                err_delta_sum: 0.5 + 1.0 / 7.0,
                max_err_delta: 0.25,
                changed_cells: vec![(0, 1, 2), (3, 0, 1)],
                grid: crate::cube::CellGrid::new(dims, 2, 2, 2),
            }),
            degraded: false,
        });
    }

    #[test]
    fn errors_map_to_typed_responses() {
        let shed = encode_error(&PdfflowError::Overloaded("queue full (2 in flight)".into()));
        let parsed = Json::parse(&shed.to_string()).unwrap();
        let back = decode_response(&parsed).unwrap_err();
        assert!(back.is_overload(), "shed must decode as Overloaded, got {back:?}");
        assert_eq!(back.to_string(), "overloaded: queue full (2 in flight)");

        let fmt = encode_error(&PdfflowError::Format("bad window".into()));
        let back = decode_response(&Json::parse(&fmt.to_string()).unwrap()).unwrap_err();
        assert!(matches!(back, PdfflowError::Format(_)));

        let arg = encode_error(&PdfflowError::InvalidArg("no such slice".into()));
        let back = decode_response(&Json::parse(&arg.to_string()).unwrap()).unwrap_err();
        assert!(matches!(back, PdfflowError::InvalidArg(_)));
    }

    #[test]
    fn frames_roundtrip_and_reject_oversize() {
        let doc = encode_request(&Request::Point(PointId(9)));
        let mut buf = Vec::new();
        write_frame(&mut buf, &doc).unwrap();
        write_frame(&mut buf, &Json::obj(vec![("op", Json::Str("meta".into()))])).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap().to_string(), doc.to_string());
        assert!(matches!(
            decode_request(&read_frame(&mut r).unwrap().unwrap()).unwrap(),
            ControlOrQuery::Meta
        ));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF is None");

        let mut evil = Vec::new();
        evil.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(read_frame(&mut &evil[..]).is_err());
    }

    #[test]
    fn meta_roundtrips() {
        let m = ServeMeta {
            dims: CubeDims::new(64, 32, 8),
            slices: vec![0, 2, 5],
            run: "baseline_4_default".into(),
        };
        let parsed = Json::parse(&encode_meta(&m).to_string()).unwrap();
        assert_eq!(decode_meta(&parsed).unwrap(), m);
    }
}
