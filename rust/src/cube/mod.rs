//! Spatial cube geometry (paper §3).
//!
//! A cube area has `nz` horizontal slices; each slice has `ny` lines; each
//! line has `nx` points (the paper's Set1 is 251 × 501 × 501 = nx 251,
//! ny 501, nz 501). A *window* is a run of consecutive lines inside one
//! slice (paper §4.2 principle 4: the sliding window unit for loading and
//! PDF computation).

/// Cube dimensions: points per line, lines per slice, slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CubeDims {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
}

impl CubeDims {
    pub fn new(nx: usize, ny: usize, nz: usize) -> Self {
        CubeDims { nx, ny, nz }
    }

    /// Total points in the cube.
    pub fn n_points(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Points in one slice.
    pub fn slice_points(&self) -> usize {
        self.nx * self.ny
    }

    /// Flat point id of (x, y, z) — z-major, then line, then point, which
    /// is also the on-disk value order in dataset files.
    pub fn point_id(&self, x: usize, y: usize, z: usize) -> PointId {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        PointId(((z * self.ny + y) * self.nx + x) as u64)
    }

    /// Inverse of [`point_id`].
    pub fn coords(&self, id: PointId) -> (usize, usize, usize) {
        let i = id.0 as usize;
        let x = i % self.nx;
        let y = (i / self.nx) % self.ny;
        let z = i / (self.nx * self.ny);
        (x, y, z)
    }

    /// Byte offset of a point's value inside one dataset file body.
    pub fn value_offset(&self, id: PointId) -> u64 {
        id.0 * 4
    }

    /// All point ids of `lines` consecutive lines of slice `z` starting at
    /// line `y0` (a window's points, in id order).
    pub fn window_points(&self, w: &Window) -> Vec<PointId> {
        let mut out = Vec::with_capacity(w.lines * self.nx);
        for y in w.y0..w.y0 + w.lines {
            for x in 0..self.nx {
                out.push(self.point_id(x, y, w.z));
            }
        }
        out
    }

    /// Split slice `z` into consecutive non-overlapping windows of
    /// `lines_per_window` lines (last window may be shorter). Paper §4.2:
    /// "any two windows have no intersection".
    pub fn windows(&self, z: usize, lines_per_window: usize) -> Vec<Window> {
        assert!(lines_per_window > 0, "window must have at least one line");
        let mut out = Vec::new();
        let mut y0 = 0;
        while y0 < self.ny {
            let lines = lines_per_window.min(self.ny - y0);
            out.push(Window { z, y0, lines });
            y0 += lines;
        }
        out
    }
}

/// Flat point identifier (the RDD key in the paper's key-value pairs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PointId(pub u64);

/// A run of consecutive lines inside one slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Window {
    pub z: usize,
    pub y0: usize,
    pub lines: usize,
}

impl Window {
    pub fn n_points(&self, dims: &CubeDims) -> usize {
        self.lines * dims.nx
    }

    /// Contiguous byte range of this window inside one dataset file body.
    pub fn byte_range(&self, dims: &CubeDims) -> (u64, usize) {
        let first = dims.point_id(0, self.y0, self.z);
        (first.0 * 4, self.lines * dims.nx * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> CubeDims {
        CubeDims::new(251, 501, 501) // paper Set1
    }

    #[test]
    fn point_id_roundtrip() {
        let d = dims();
        for &(x, y, z) in &[(0, 0, 0), (250, 500, 500), (17, 42, 201), (1, 0, 500)] {
            let id = d.point_id(x, y, z);
            assert_eq!(d.coords(id), (x, y, z));
        }
    }

    #[test]
    fn ids_are_disk_order() {
        let d = CubeDims::new(3, 2, 2);
        let mut expect = 0u64;
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..3 {
                    assert_eq!(d.point_id(x, y, z).0, expect);
                    expect += 1;
                }
            }
        }
    }

    #[test]
    fn counts() {
        let d = dims();
        assert_eq!(d.n_points(), 251 * 501 * 501);
        assert_eq!(d.slice_points(), 251 * 501);
    }

    #[test]
    fn windows_partition_slice() {
        let d = dims();
        let ws = d.windows(201, 25);
        // Non-overlapping, ordered, covering all 501 lines.
        let mut covered = 0;
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.z, 201);
            assert_eq!(w.y0, covered);
            covered += w.lines;
            if i + 1 < ws.len() {
                assert_eq!(w.lines, 25);
            }
        }
        assert_eq!(covered, 501);
        assert_eq!(ws.len(), 21); // ceil(501/25)
        assert_eq!(ws.last().unwrap().lines, 1); // 501 = 20*25 + 1
    }

    #[test]
    fn windows_exact_division() {
        let d = CubeDims::new(10, 100, 5);
        let ws = d.windows(0, 20);
        assert_eq!(ws.len(), 5);
        assert!(ws.iter().all(|w| w.lines == 20));
    }

    #[test]
    fn window_points_are_contiguous_ids() {
        let d = CubeDims::new(4, 10, 3);
        let w = Window { z: 1, y0: 2, lines: 2 };
        let pts = d.window_points(&w);
        assert_eq!(pts.len(), 8);
        for pair in pts.windows(2) {
            assert_eq!(pair[1].0, pair[0].0 + 1);
        }
        let (off, len) = w.byte_range(&d);
        assert_eq!(off, pts[0].0 * 4);
        assert_eq!(len, 8 * 4);
    }

    #[test]
    #[should_panic(expected = "window must have at least one line")]
    fn zero_window_panics() {
        dims().windows(0, 0);
    }
}
