//! Spatial cube geometry (paper §3).
//!
//! A cube area has `nz` horizontal slices; each slice has `ny` lines; each
//! line has `nx` points (the paper's Set1 is 251 × 501 × 501 = nx 251,
//! ny 501, nz 501). A *window* is a run of consecutive lines inside one
//! slice (paper §4.2 principle 4: the sliding window unit for loading and
//! PDF computation).

/// Cube dimensions: points per line, lines per slice, slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CubeDims {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
}

impl CubeDims {
    pub fn new(nx: usize, ny: usize, nz: usize) -> Self {
        CubeDims { nx, ny, nz }
    }

    /// Total points in the cube.
    pub fn n_points(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Points in one slice.
    pub fn slice_points(&self) -> usize {
        self.nx * self.ny
    }

    /// Flat point id of (x, y, z) — z-major, then line, then point, which
    /// is also the on-disk value order in dataset files.
    pub fn point_id(&self, x: usize, y: usize, z: usize) -> PointId {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        PointId(((z * self.ny + y) * self.nx + x) as u64)
    }

    /// Inverse of [`point_id`].
    pub fn coords(&self, id: PointId) -> (usize, usize, usize) {
        let i = id.0 as usize;
        let x = i % self.nx;
        let y = (i / self.nx) % self.ny;
        let z = i / (self.nx * self.ny);
        (x, y, z)
    }

    /// Byte offset of a point's value inside one dataset file body.
    pub fn value_offset(&self, id: PointId) -> u64 {
        id.0 * 4
    }

    /// All point ids of `lines` consecutive lines of slice `z` starting at
    /// line `y0` (a window's points, in id order).
    pub fn window_points(&self, w: &Window) -> Vec<PointId> {
        let mut out = Vec::with_capacity(w.lines * self.nx);
        for y in w.y0..w.y0 + w.lines {
            for x in 0..self.nx {
                out.push(self.point_id(x, y, w.z));
            }
        }
        out
    }

    /// Split slice `z` into consecutive non-overlapping windows of
    /// `lines_per_window` lines (last window may be shorter). Paper §4.2:
    /// "any two windows have no intersection".
    pub fn windows(&self, z: usize, lines_per_window: usize) -> Vec<Window> {
        assert!(lines_per_window > 0, "window must have at least one line");
        let mut out = Vec::new();
        let mut y0 = 0;
        while y0 < self.ny {
            let lines = lines_per_window.min(self.ny - y0);
            out.push(Window { z, y0, lines });
            y0 += lines;
        }
        out
    }
}

/// Flat point identifier (the RDD key in the paper's key-value pairs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PointId(pub u64);

/// Uniform grid of 3D cells over a cube — the coordinate layer of the
/// spatial tier ([`crate::spatial`]). Cells are `sx × sy × sz`-point
/// boxes (edge cells truncated to the cube boundary) addressed z-major
/// like point ids. Cell ↔ window math lives here because a [`Window`]
/// is a y-run of one slice: it overlaps exactly the cell rows whose
/// y-range intersects its lines, in the z-layer of its slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellGrid {
    pub dims: CubeDims,
    /// Cell side along x (points per cell).
    pub sx: usize,
    /// Cell side along y (lines per cell).
    pub sy: usize,
    /// Cell side along z (slices per cell).
    pub sz: usize,
}

impl CellGrid {
    pub fn new(dims: CubeDims, sx: usize, sy: usize, sz: usize) -> CellGrid {
        assert!(sx > 0 && sy > 0 && sz > 0, "cell sides must be positive");
        CellGrid { dims, sx, sy, sz }
    }

    /// Default grid for a cube: about 8 cells per axis, at least one
    /// point per cell side.
    pub fn default_for(dims: CubeDims) -> CellGrid {
        let side = |n: usize| n.div_ceil(8).max(1);
        CellGrid::new(dims, side(dims.nx), side(dims.ny), side(dims.nz))
    }

    /// Cell counts per axis.
    pub fn ncx(&self) -> usize {
        self.dims.nx.div_ceil(self.sx)
    }

    pub fn ncy(&self) -> usize {
        self.dims.ny.div_ceil(self.sy)
    }

    pub fn ncz(&self) -> usize {
        self.dims.nz.div_ceil(self.sz)
    }

    pub fn n_cells(&self) -> usize {
        self.ncx() * self.ncy() * self.ncz()
    }

    /// Cell coordinates of a point.
    pub fn cell_of(&self, x: usize, y: usize, z: usize) -> (usize, usize, usize) {
        debug_assert!(x < self.dims.nx && y < self.dims.ny && z < self.dims.nz);
        (x / self.sx, y / self.sy, z / self.sz)
    }

    /// Flat cell index — z-major, mirroring [`CubeDims::point_id`].
    pub fn cell_index(&self, (cx, cy, cz): (usize, usize, usize)) -> usize {
        debug_assert!(cx < self.ncx() && cy < self.ncy() && cz < self.ncz());
        (cz * self.ncy() + cy) * self.ncx() + cx
    }

    /// Inverse of [`cell_index`](Self::cell_index).
    pub fn cell_at(&self, idx: usize) -> (usize, usize, usize) {
        let cx = idx % self.ncx();
        let cy = (idx / self.ncx()) % self.ncy();
        let cz = idx / (self.ncx() * self.ncy());
        (cx, cy, cz)
    }

    /// Inclusive point ranges of one cell: `((x0,x1),(y0,y1),(z0,z1))`,
    /// truncated at the cube boundary.
    pub fn cell_bounds(
        &self,
        (cx, cy, cz): (usize, usize, usize),
    ) -> ((usize, usize), (usize, usize), (usize, usize)) {
        let side = |c: usize, s: usize, n: usize| (c * s, ((c + 1) * s - 1).min(n - 1));
        (
            side(cx, self.sx, self.dims.nx),
            side(cy, self.sy, self.dims.ny),
            side(cz, self.sz, self.dims.nz),
        )
    }

    /// Cell rows a window overlaps: inclusive cy range + the cz layer.
    /// A window spans every x, so its cell set is the full cx row of
    /// each returned (cy, cz) — the reason the spatial index buckets by
    /// (cy, cz) and resolves the x axis per record.
    pub fn cells_of_window(&self, w: &Window) -> (std::ops::RangeInclusive<usize>, usize) {
        (w.y0 / self.sy..=w.y1() / self.sy, w.z / self.sz)
    }
}

/// A run of consecutive lines inside one slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Window {
    pub z: usize,
    pub y0: usize,
    pub lines: usize,
}

impl Window {
    pub fn n_points(&self, dims: &CubeDims) -> usize {
        self.lines * dims.nx
    }

    /// Last line of the window, inclusive.
    pub fn y1(&self) -> usize {
        self.y0 + self.lines - 1
    }

    /// Contiguous byte range of this window inside one dataset file body.
    pub fn byte_range(&self, dims: &CubeDims) -> (u64, usize) {
        let first = dims.point_id(0, self.y0, self.z);
        (first.0 * 4, self.lines * dims.nx * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> CubeDims {
        CubeDims::new(251, 501, 501) // paper Set1
    }

    #[test]
    fn point_id_roundtrip() {
        let d = dims();
        for &(x, y, z) in &[(0, 0, 0), (250, 500, 500), (17, 42, 201), (1, 0, 500)] {
            let id = d.point_id(x, y, z);
            assert_eq!(d.coords(id), (x, y, z));
        }
    }

    #[test]
    fn ids_are_disk_order() {
        let d = CubeDims::new(3, 2, 2);
        let mut expect = 0u64;
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..3 {
                    assert_eq!(d.point_id(x, y, z).0, expect);
                    expect += 1;
                }
            }
        }
    }

    #[test]
    fn counts() {
        let d = dims();
        assert_eq!(d.n_points(), 251 * 501 * 501);
        assert_eq!(d.slice_points(), 251 * 501);
    }

    #[test]
    fn windows_partition_slice() {
        let d = dims();
        let ws = d.windows(201, 25);
        // Non-overlapping, ordered, covering all 501 lines.
        let mut covered = 0;
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.z, 201);
            assert_eq!(w.y0, covered);
            covered += w.lines;
            if i + 1 < ws.len() {
                assert_eq!(w.lines, 25);
            }
        }
        assert_eq!(covered, 501);
        assert_eq!(ws.len(), 21); // ceil(501/25)
        assert_eq!(ws.last().unwrap().lines, 1); // 501 = 20*25 + 1
    }

    #[test]
    fn windows_exact_division() {
        let d = CubeDims::new(10, 100, 5);
        let ws = d.windows(0, 20);
        assert_eq!(ws.len(), 5);
        assert!(ws.iter().all(|w| w.lines == 20));
    }

    #[test]
    fn window_points_are_contiguous_ids() {
        let d = CubeDims::new(4, 10, 3);
        let w = Window { z: 1, y0: 2, lines: 2 };
        let pts = d.window_points(&w);
        assert_eq!(pts.len(), 8);
        for pair in pts.windows(2) {
            assert_eq!(pair[1].0, pair[0].0 + 1);
        }
        let (off, len) = w.byte_range(&d);
        assert_eq!(off, pts[0].0 * 4);
        assert_eq!(len, 8 * 4);
    }

    #[test]
    #[should_panic(expected = "window must have at least one line")]
    fn zero_window_panics() {
        dims().windows(0, 0);
    }

    #[test]
    fn cell_grid_index_roundtrip_and_counts() {
        let g = CellGrid::new(CubeDims::new(10, 7, 5), 3, 2, 2);
        assert_eq!((g.ncx(), g.ncy(), g.ncz()), (4, 4, 3));
        assert_eq!(g.n_cells(), 48);
        for idx in 0..g.n_cells() {
            assert_eq!(g.cell_index(g.cell_at(idx)), idx);
        }
        // Every point lands in exactly the cell whose bounds contain it.
        for z in 0..5 {
            for y in 0..7 {
                for x in 0..10 {
                    let c = g.cell_of(x, y, z);
                    let ((x0, x1), (y0, y1), (z0, z1)) = g.cell_bounds(c);
                    assert!(x0 <= x && x <= x1 && y0 <= y && y <= y1 && z0 <= z && z <= z1);
                }
            }
        }
        // Edge cells truncate to the cube boundary.
        assert_eq!(g.cell_bounds((3, 3, 2)), ((9, 9), (6, 6), (4, 4)));
    }

    #[test]
    fn cell_grid_default_covers_cube() {
        let g = CellGrid::default_for(CubeDims::new(251, 501, 501));
        assert!(g.ncx() * g.sx >= 251 && g.ncy() * g.sy >= 501);
        let tiny = CellGrid::default_for(CubeDims::new(2, 3, 1));
        assert_eq!((tiny.sx, tiny.sy, tiny.sz), (1, 1, 1));
    }

    #[test]
    fn window_cell_rows() {
        let d = CubeDims::new(6, 20, 4);
        let g = CellGrid::new(d, 2, 4, 2);
        let w = Window { z: 3, y0: 6, lines: 5 }; // lines 6..=10 → cy 1..=2
        let (cys, cz) = g.cells_of_window(&w);
        assert_eq!((cys, cz), (1..=2, 1));
        assert_eq!(w.y1(), 10);
    }
}
