//! CART decision tree — the Spark-MLlib analog (paper §5.3).
//!
//! The paper trains a decision tree on previously generated output (per
//! point: mean, std → distribution type) and broadcasts it to the workers
//! so the ML method fits only the predicted type. We implement the same
//! model class MLlib uses: binary CART with gini impurity, quantile-based
//! candidate thresholds capped at `max_bins` per feature (MLlib's
//! `maxBins`), depth cap (`maxDepth`), and the paper's hyper-parameter
//! tuning loop on a train/validation split (§5.3.1).

pub mod forest;

use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

/// Hyper-parameters (the paper tunes `depth` and `maxBins`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TreeParams {
    pub max_depth: usize,
    pub max_bins: usize,
    /// Minimum samples to keep splitting (MLlib minInstancesPerNode).
    pub min_leaf: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 8,
            max_bins: 32,
            min_leaf: 4,
        }
    }
}

/// One labeled training example: feature vector → class id.
#[derive(Clone, Debug)]
pub struct Sample {
    pub features: Vec<f64>,
    pub label: usize,
}

#[derive(Clone, Debug, PartialEq)]
enum Node {
    Leaf {
        class: usize,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A trained decision tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub n_features: usize,
    pub n_classes: usize,
    pub params: TreeParams,
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

fn majority(counts: &[usize]) -> usize {
    counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

impl DecisionTree {
    /// Train on `samples` (all feature vectors must share a length).
    pub fn train(samples: &[Sample], params: TreeParams) -> Result<DecisionTree> {
        if samples.is_empty() {
            return Err(PdfflowError::InvalidArg("empty training set".into()));
        }
        let n_features = samples[0].features.len();
        if samples.iter().any(|s| s.features.len() != n_features) {
            return Err(PdfflowError::InvalidArg("ragged feature vectors".into()));
        }
        let n_classes = samples.iter().map(|s| s.label).max().unwrap_or(0) + 1;
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_features,
            n_classes,
            params,
        };
        let idx: Vec<usize> = (0..samples.len()).collect();
        tree.build(samples, idx, 0);
        Ok(tree)
    }

    fn build(&mut self, samples: &[Sample], idx: Vec<usize>, depth: usize) -> usize {
        let mut counts = vec![0usize; self.n_classes];
        for &i in &idx {
            counts[samples[i].label] += 1;
        }
        let node_impurity = gini(&counts, idx.len());
        let make_leaf = depth >= self.params.max_depth
            || idx.len() < self.params.min_leaf * 2
            || node_impurity == 0.0;
        if !make_leaf {
            if let Some((feature, threshold)) = self.best_split(samples, &idx, node_impurity) {
                let (l, r): (Vec<usize>, Vec<usize>) = idx
                    .iter()
                    .partition(|&&i| samples[i].features[feature] <= threshold);
                if !l.is_empty() && !r.is_empty() {
                    let slot = self.nodes.len();
                    self.nodes.push(Node::Leaf { class: 0 }); // placeholder
                    let left = self.build(samples, l, depth + 1);
                    let right = self.build(samples, r, depth + 1);
                    self.nodes[slot] = Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    };
                    return slot;
                }
            }
        }
        let slot = self.nodes.len();
        self.nodes.push(Node::Leaf {
            class: majority(&counts),
        });
        slot
    }

    /// Best (feature, threshold) by gini gain over `max_bins` quantile
    /// candidate thresholds per feature (MLlib binning).
    fn best_split(
        &self,
        samples: &[Sample],
        idx: &[usize],
        node_impurity: f64,
    ) -> Option<(usize, f64)> {
        let n = idx.len();
        let mut best: Option<(f64, usize, f64)> = None; // (gain, feature, thr)
        for f in 0..self.n_features {
            let mut vals: Vec<f64> = idx.iter().map(|&i| samples[i].features[f]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            if vals.len() < 2 {
                continue;
            }
            let bins = self.params.max_bins.min(vals.len() - 1).max(1);
            for b in 1..=bins {
                let pos = b * (vals.len() - 1) / (bins + 1).max(1);
                let pos = pos.min(vals.len() - 2);
                let thr = 0.5 * (vals[pos] + vals[pos + 1]);
                let mut lc = vec![0usize; self.n_classes];
                let mut rc = vec![0usize; self.n_classes];
                let (mut ln, mut rn) = (0usize, 0usize);
                for &i in idx {
                    if samples[i].features[f] <= thr {
                        lc[samples[i].label] += 1;
                        ln += 1;
                    } else {
                        rc[samples[i].label] += 1;
                        rn += 1;
                    }
                }
                if ln == 0 || rn == 0 {
                    continue;
                }
                let gain = node_impurity
                    - (ln as f64 / n as f64) * gini(&lc, ln)
                    - (rn as f64 / n as f64) * gini(&rc, rn);
                if best.map_or(true, |(g, _, _)| gain > g) {
                    best = Some((gain, f, thr));
                }
            }
        }
        best.filter(|(g, _, _)| *g > 1e-12).map(|(_, f, t)| (f, t))
    }

    /// Predict the class of one feature vector.
    pub fn predict(&self, features: &[f64]) -> usize {
        let mut at = 0usize;
        loop {
            match &self.nodes[at] {
                Node::Leaf { class } => return *class,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    at = if features[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    /// Wrong-prediction rate on a labeled set (the paper's "model error").
    pub fn error_rate(&self, samples: &[Sample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let wrong = samples
            .iter()
            .filter(|s| self.predict(&s.features) != s.label)
            .count();
        wrong as f64 / samples.len() as f64
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], at: usize) -> usize {
            match &nodes[at] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(nodes, *left).max(d(nodes, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            d(&self.nodes, 0)
        }
    }

    /// Serialized size in bytes (for broadcast cost accounting).
    pub fn broadcast_bytes(&self) -> u64 {
        (self.nodes.len() * 32) as u64
    }

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| match n {
                Node::Leaf { class } => Json::obj(vec![("class", Json::Num(*class as f64))]),
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => Json::obj(vec![
                    ("feature", Json::Num(*feature as f64)),
                    ("threshold", Json::Num(*threshold)),
                    ("left", Json::Num(*left as f64)),
                    ("right", Json::Num(*right as f64)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("n_features", Json::Num(self.n_features as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("max_depth", Json::Num(self.params.max_depth as f64)),
            ("max_bins", Json::Num(self.params.max_bins as f64)),
            ("min_leaf", Json::Num(self.params.min_leaf as f64)),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DecisionTree> {
        let num = |j: &Json, k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| PdfflowError::Format(format!("tree json missing {k}")))
        };
        let nodes_json = j
            .get("nodes")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| PdfflowError::Format("tree json missing nodes".into()))?;
        let mut nodes = Vec::with_capacity(nodes_json.len());
        for nj in nodes_json {
            if let Some(c) = nj.get("class") {
                nodes.push(Node::Leaf {
                    class: c.as_usize().unwrap_or(0),
                });
            } else {
                nodes.push(Node::Split {
                    feature: num(nj, "feature")? as usize,
                    threshold: num(nj, "threshold")?,
                    left: num(nj, "left")? as usize,
                    right: num(nj, "right")? as usize,
                });
            }
        }
        Ok(DecisionTree {
            nodes,
            n_features: num(j, "n_features")? as usize,
            n_classes: num(j, "n_classes")? as usize,
            params: TreeParams {
                max_depth: num(j, "max_depth")? as usize,
                max_bins: num(j, "max_bins")? as usize,
                min_leaf: num(j, "min_leaf")? as usize,
            },
        })
    }
}

/// Hyper-parameter tuning (paper §5.3.1): random train/validation split,
/// grid over (depth, maxBins), pick the smallest values whose validation
/// error stops improving. Returns (params, validation error).
pub fn tune(
    samples: &[Sample],
    depths: &[usize],
    bins: &[usize],
    seed: u64,
) -> Result<(TreeParams, f64)> {
    let mut idx: Vec<usize> = (0..samples.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let split = (samples.len() * 7) / 10;
    let train: Vec<Sample> = idx[..split].iter().map(|&i| samples[i].clone()).collect();
    let valid: Vec<Sample> = idx[split..].iter().map(|&i| samples[i].clone()).collect();
    let mut best: Option<(TreeParams, f64)> = None;
    for &d in depths {
        for &b in bins {
            let params = TreeParams {
                max_depth: d,
                max_bins: b,
                ..TreeParams::default()
            };
            let tree = DecisionTree::train(&train, params)?;
            let err = tree.error_rate(&valid);
            // Strict improvement required: prefers the smallest (d, b) at
            // equal error, per the paper's choice rule.
            if best.map_or(true, |(_, e)| err < e - 1e-9) {
                best = Some((params, err));
            }
        }
    }
    best.ok_or_else(|| PdfflowError::InvalidArg("empty tuning grid".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two well-separated classes in (mean, std) space.
    fn blobs(n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let label = i % 2;
                let (cx, cy) = if label == 0 { (1.0, 1.0) } else { (5.0, 3.0) };
                Sample {
                    features: vec![rng.normal(cx, 0.3), rng.normal(cy, 0.3)],
                    label,
                }
            })
            .collect()
    }

    #[test]
    fn separable_classes_are_learned() {
        let data = blobs(400, 1);
        let tree = DecisionTree::train(&data, TreeParams::default()).unwrap();
        assert!(tree.error_rate(&data) < 0.02);
        assert!(tree.depth() >= 1);
    }

    #[test]
    fn generalizes_to_held_out() {
        let tree = DecisionTree::train(&blobs(400, 2), TreeParams::default()).unwrap();
        let test = blobs(200, 3);
        assert!(tree.error_rate(&test) < 0.05);
    }

    #[test]
    fn pure_training_set_yields_single_leaf() {
        let data: Vec<Sample> = (0..50)
            .map(|i| Sample {
                features: vec![i as f64, 0.0],
                label: 2,
            })
            .collect();
        let tree = DecisionTree::train(&data, TreeParams::default()).unwrap();
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.predict(&[17.0, 0.0]), 2);
        assert_eq!(tree.error_rate(&data), 0.0);
    }

    #[test]
    fn depth_cap_is_respected() {
        let data = blobs(400, 4);
        for cap in [1, 2, 3] {
            let tree = DecisionTree::train(
                &data,
                TreeParams {
                    max_depth: cap,
                    ..TreeParams::default()
                },
            )
            .unwrap();
            assert!(tree.depth() <= cap, "depth {} > cap {cap}", tree.depth());
        }
    }

    #[test]
    fn four_class_problem() {
        let mut rng = Rng::new(5);
        let centers = [(0.0, 0.0), (4.0, 0.0), (0.0, 4.0), (4.0, 4.0)];
        let data: Vec<Sample> = (0..800)
            .map(|i| {
                let label = i % 4;
                let (cx, cy) = centers[label];
                Sample {
                    features: vec![rng.normal(cx, 0.4), rng.normal(cy, 0.4)],
                    label,
                }
            })
            .collect();
        let tree = DecisionTree::train(&data, TreeParams::default()).unwrap();
        assert!(tree.error_rate(&data) < 0.03);
        assert_eq!(tree.n_classes, 4);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(DecisionTree::train(&[], TreeParams::default()).is_err());
        let ragged = vec![
            Sample {
                features: vec![1.0],
                label: 0,
            },
            Sample {
                features: vec![1.0, 2.0],
                label: 1,
            },
        ];
        assert!(DecisionTree::train(&ragged, TreeParams::default()).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let data = blobs(300, 6);
        let tree = DecisionTree::train(&data, TreeParams::default()).unwrap();
        let json = tree.to_json().to_string();
        let back = DecisionTree::from_json(&Json::parse(&json).unwrap()).unwrap();
        for s in &data {
            assert_eq!(tree.predict(&s.features), back.predict(&s.features));
        }
        assert_eq!(back.n_classes, tree.n_classes);
    }

    #[test]
    fn tuning_picks_a_working_config() {
        let data = blobs(500, 7);
        let (params, err) = tune(&data, &[1, 2, 4, 8], &[4, 16, 32], 42).unwrap();
        assert!(err < 0.1, "tuned err {err}");
        assert!(params.max_depth >= 1);
    }

    #[test]
    fn max_bins_one_still_trains() {
        let data = blobs(100, 8);
        let tree = DecisionTree::train(
            &data,
            TreeParams {
                max_bins: 1,
                ..TreeParams::default()
            },
        )
        .unwrap();
        // Single candidate threshold per feature still separates blobs.
        assert!(tree.error_rate(&data) < 0.2);
    }
}
