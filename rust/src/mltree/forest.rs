//! Random forest — the natural MLlib upgrade the paper leaves on the
//! table (§5.3.1 considers only a single decision tree; MLlib ships a
//! RandomForest with the same API). Bootstrap-resampled CART trees with
//! majority voting; the `forest-vs-tree` ablation bench measures whether
//! the ensemble lowers the model error enough to matter for the ML
//! method's average Eq.6 error.

use crate::mltree::{DecisionTree, Sample, TreeParams};
use crate::util::prng::Rng;
use crate::Result;

/// Random-forest hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap fraction per tree.
    pub sample_fraction: f64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 10,
            tree: TreeParams::default(),
            sample_fraction: 0.8,
        }
    }
}

/// An ensemble of CART trees with majority voting.
#[derive(Clone, Debug)]
pub struct RandomForest {
    trees: Vec<DecisionTree>,
    pub n_classes: usize,
}

impl RandomForest {
    pub fn train(samples: &[Sample], params: ForestParams, seed: u64) -> Result<RandomForest> {
        let mut rng = Rng::new(seed);
        let take = ((samples.len() as f64 * params.sample_fraction) as usize).max(1);
        let mut trees = Vec::with_capacity(params.n_trees);
        let mut n_classes = 0;
        for _ in 0..params.n_trees {
            // Bootstrap: sample WITH replacement.
            let boot: Vec<Sample> = (0..take)
                .map(|_| samples[rng.below(samples.len())].clone())
                .collect();
            let tree = DecisionTree::train(&boot, params.tree)?;
            n_classes = n_classes.max(tree.n_classes);
            trees.push(tree);
        }
        Ok(RandomForest { trees, n_classes })
    }

    /// Majority vote over the ensemble (ties break to the lower class id,
    /// deterministically).
    pub fn predict(&self, features: &[f64]) -> usize {
        let mut votes = vec![0usize; self.n_classes.max(1)];
        for t in &self.trees {
            let c = t.predict(features);
            if c < votes.len() {
                votes[c] += 1;
            }
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|(i, &v)| (v, usize::MAX - i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Wrong-prediction rate (comparable to `DecisionTree::error_rate`).
    pub fn error_rate(&self, samples: &[Sample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let wrong = samples
            .iter()
            .filter(|s| self.predict(&s.features) != s.label)
            .count();
        wrong as f64 / samples.len() as f64
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Broadcast size (sum of member trees).
    pub fn broadcast_bytes(&self) -> u64 {
        self.trees.iter().map(|t| t.broadcast_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy_blobs(n: usize, noise: f64, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let label = i % 3;
                let cx = label as f64 * 2.0;
                // A slice of label noise makes the ensemble matter.
                let label = if rng.f64() < noise {
                    rng.below(3)
                } else {
                    label
                };
                Sample {
                    features: vec![cx + rng.std_normal() * 0.6, rng.std_normal()],
                    label,
                }
            })
            .collect()
    }

    #[test]
    fn forest_learns_separable_data() {
        let data = noisy_blobs(600, 0.0, 1);
        let f = RandomForest::train(&data, ForestParams::default(), 42).unwrap();
        assert_eq!(f.n_trees(), 10);
        assert!(f.error_rate(&data) < 0.1, "err {}", f.error_rate(&data));
    }

    #[test]
    fn forest_not_worse_than_single_tree_on_noisy_heldout() {
        let train = noisy_blobs(800, 0.15, 2);
        let test = noisy_blobs(400, 0.0, 3); // clean labels for evaluation
        let tree = DecisionTree::train(&train, TreeParams::default()).unwrap();
        let forest = RandomForest::train(&train, ForestParams::default(), 42).unwrap();
        assert!(
            forest.error_rate(&test) <= tree.error_rate(&test) + 0.02,
            "forest {} vs tree {}",
            forest.error_rate(&test),
            tree.error_rate(&test)
        );
    }

    #[test]
    fn prediction_is_deterministic() {
        let data = noisy_blobs(300, 0.1, 4);
        let f = RandomForest::train(&data, ForestParams::default(), 7).unwrap();
        for s in data.iter().take(20) {
            assert_eq!(f.predict(&s.features), f.predict(&s.features));
        }
    }

    #[test]
    fn single_tree_forest_matches_bootstrap_tree_behaviour() {
        let data = noisy_blobs(300, 0.0, 5);
        let f = RandomForest::train(
            &data,
            ForestParams {
                n_trees: 1,
                sample_fraction: 1.0,
                ..ForestParams::default()
            },
            9,
        )
        .unwrap();
        assert_eq!(f.n_trees(), 1);
        assert!(f.error_rate(&data) < 0.15);
    }
}
