//! The native compute backend: the pure-Rust statistics oracle from
//! [`crate::stats`], evaluated in thread-parallel point batches.
//!
//! Points are split into `batch`-sized chunks; each chunk is one task
//! on the shared [`HostPool`] — the same global thread budget the
//! executor and query engine draw from, so a backend call nested inside
//! an executor window task adds **zero** threads (no more
//! `executor_threads x workers` multiplication; `workers` is only a
//! width cap on how much of the budget one call may use). Kernels write
//! straight into disjoint row slices of the one preallocated output
//! buffer, so there is no per-chunk collect-then-copy, and each chunk
//! reuses one scratch set (pre-converted f64 observations, quantile
//! subsample, Eq. 5 histogram + interval edges) across all of its
//! points — a single f32→f64 conversion pass per point and no per-point
//! allocation. Results are bitwise independent of the batch size, the
//! worker width and the pool budget.
//!
//! This backend is the default: it needs no AOT artifacts, no Python and
//! no XLA toolchain, which is what lets the whole test tier run on any
//! machine. The XLA engine (behind the `xla` feature) is the measured
//! accelerator the benches compare against.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::stats::{self, DistType, PointStats};
use crate::{PdfflowError, Result};

use super::adaptive::AdaptiveController;
use super::hostpool::HostPool;
use super::{Backend, BackendMetrics, OutMatrix};

/// Process-wide backend counters (`backend.executions`,
/// `backend.rows`) — summed over every backend instance, so exporters
/// see the host's total kernel traffic.
fn global_counters() -> &'static (
    Arc<crate::telemetry::Counter>,
    Arc<crate::telemetry::Counter>,
) {
    static C: std::sync::OnceLock<(
        Arc<crate::telemetry::Counter>,
        Arc<crate::telemetry::Counter>,
    )> = std::sync::OnceLock::new();
    C.get_or_init(|| {
        let r = crate::telemetry::Registry::global();
        (r.counter("backend.executions"), r.counter("backend.rows"))
    })
}

/// Per-chunk scratch, reused across every point of the chunk: the
/// f64-converted observation vector, the quantile subsample, and the
/// Eq. 5 histogram + interval edges.
struct Scratch {
    vals: Vec<f64>,
    quant: Vec<f64>,
    hist: Vec<f64>,
    edges: Vec<f64>,
}

impl Scratch {
    fn new(bins: usize) -> Scratch {
        Scratch {
            vals: Vec::new(),
            quant: Vec::new(),
            hist: vec![0.0; bins],
            edges: vec![0.0; bins],
        }
    }
}

/// Pure-Rust batched backend (see module docs).
pub struct NativeBackend {
    workers: usize,
    batch: usize,
    bins: usize,
    pool: Arc<HostPool>,
    metrics: Mutex<BackendMetrics>,
    /// Optional occupancy-adaptive chunk/fan-out controller. `None`
    /// (every constructor's default) keeps the fixed `batch`/`workers`
    /// widths — the mode the chunk-count-pinning tests rely on; the
    /// pipeline turns it on via `pipeline.adaptive_batch`.
    adaptive: Option<AdaptiveController>,
}

impl NativeBackend {
    /// Default configuration: full shared-pool width, 256-point batches,
    /// the canonical 32 Eq. 5 intervals.
    pub fn new() -> NativeBackend {
        Self::with_options(super::hostpool::default_budget(), 256, stats::DEFAULT_BINS)
    }

    /// Backend on the global [`HostPool`]; `workers` caps how many pool
    /// slots one batched call may draw, it spawns nothing.
    pub fn with_options(workers: usize, batch: usize, bins: usize) -> NativeBackend {
        Self::with_pool(Arc::clone(HostPool::global()), workers, batch, bins)
    }

    /// Backend on an explicit pool (tests pin budgets this way).
    pub fn with_pool(
        pool: Arc<HostPool>,
        workers: usize,
        batch: usize,
        bins: usize,
    ) -> NativeBackend {
        NativeBackend {
            workers: workers.max(1),
            batch: batch.max(1),
            bins: bins.max(1),
            pool,
            metrics: Mutex::new(BackendMetrics::default()),
            adaptive: None,
        }
    }

    /// Switch this backend from fixed widths to the occupancy-adaptive
    /// controller (seeded at the configured `batch`/`workers`, which
    /// also anchor its clamps). Output bytes are unaffected — chunk
    /// geometry is pinned bitwise-irrelevant by the invariance tests —
    /// only scheduling granularity changes.
    pub fn enable_adaptive(&mut self) {
        self.adaptive = Some(AdaptiveController::new(self.batch, self.workers));
    }

    /// True when the occupancy-adaptive controller is steering widths.
    pub fn adaptive(&self) -> bool {
        self.adaptive.is_some()
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Shared batched driver: validate the shape, preallocate the whole
    /// output matrix, hand each chunk a disjoint `&mut` row-slice of it,
    /// and fan the chunks out over the shared pool — kernels write rows
    /// in place, so nothing is collected or copied afterwards.
    fn run_batched<F>(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        out_cols: usize,
        kernel: F,
    ) -> Result<OutMatrix>
    where
        F: Fn(&[f32], &mut Scratch, &mut [f32]) + Sync,
    {
        if values.len() != n_points * obs {
            return Err(PdfflowError::InvalidArg(format!(
                "values len {} != {} points x {} obs",
                values.len(),
                n_points,
                obs
            )));
        }
        if n_points > 0 && obs < 2 {
            return Err(PdfflowError::InvalidArg(format!(
                "need at least 2 observations per point, got {obs}"
            )));
        }
        let t0 = Instant::now();
        // Chunk geometry for this call: fixed knobs, or whatever the
        // adaptive controller chose after folding in the pool meters
        // accumulated since the previous call (i.e. the last window).
        let (batch, width) = match &self.adaptive {
            Some(ctl) => {
                ctl.observe(&self.pool.metrics());
                (ctl.batch(), ctl.fanout())
            }
            None => (self.batch, self.workers),
        };
        let n_chunks = n_points.div_ceil(batch);
        let mut data = vec![0f32; n_points * out_cols];
        if n_points > 0 {
            let chunks: Vec<(usize, &mut [f32])> =
                data.chunks_mut(batch * out_cols).enumerate().collect();
            self.pool.parallel_map(chunks, width, |(c, out)| {
                let lo = c * batch;
                let hi = (lo + batch).min(n_points);
                let mut scratch = Scratch::new(self.bins);
                for (i, p) in (lo..hi).enumerate() {
                    kernel(
                        &values[p * obs..(p + 1) * obs],
                        &mut scratch,
                        &mut out[i * out_cols..(i + 1) * out_cols],
                    );
                }
            });
        }
        let dt = t0.elapsed().as_secs_f64();
        {
            // Process totals for exporters; instance-local `metrics`
            // below stays the per-backend source of truth.
            let (execs, rows) = global_counters();
            execs.add(n_chunks as u64);
            rows.add(n_points as u64);
        }
        let mut m = self.metrics.lock().unwrap();
        m.executions += n_chunks as u64;
        m.rows_processed += n_points as u64;
        m.exec_seconds += dt;
        Ok(OutMatrix {
            n_rows: n_points,
            n_cols: out_cols,
            data,
        })
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Canonical type order as a `static` (an associated const sliced by a
/// runtime index would not promote to `'static`).
static ALL_TYPES: [DistType; 10] = DistType::ALL;

/// First `n` candidate types in canonical order (4 → the paper's
/// input-parameter families, 10 → the full set).
fn candidate_set(n_types: usize) -> Result<&'static [DistType]> {
    if n_types == 0 || n_types > ALL_TYPES.len() {
        return Err(PdfflowError::InvalidArg(format!(
            "n_types {n_types} not in 1..={}",
            ALL_TYPES.len()
        )));
    }
    Ok(&ALL_TYPES[..n_types])
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn run_stats(&self, values: &[f32], n_points: usize, obs: usize) -> Result<OutMatrix> {
        self.run_batched(values, n_points, obs, 12, |v, scratch, out| {
            let s = PointStats::of_converted(v, &mut scratch.vals, &mut scratch.quant);
            // STATS_COLS order — the manifest contract.
            out[0] = s.mean as f32;
            out[1] = s.std as f32;
            out[2] = s.min as f32;
            out[3] = s.max as f32;
            out[4] = s.skew as f32;
            out[5] = s.kurt_ex as f32;
            out[6] = s.meanlog as f32;
            out[7] = s.stdlog as f32;
            out[8] = s.q25 as f32;
            out[9] = s.q50 as f32;
            out[10] = s.q75 as f32;
            out[11] = s.pos_frac as f32;
        })
    }

    fn run_fit_all(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        n_types: usize,
    ) -> Result<OutMatrix> {
        let candidates = candidate_set(n_types)?;
        self.run_batched(values, n_points, obs, 5, |v, scratch, out| {
            // Fused per-point pipeline: one f32→f64 conversion feeds the
            // moments pass, the histogram and the Eq. 5 edges; the edges
            // are shared by every candidate type in the argmin.
            let s = PointStats::of_converted(v, &mut scratch.vals, &mut scratch.quant);
            stats::histogram_f64_into(&scratch.vals, s.min, s.max, &mut scratch.hist);
            stats::fill_edges(s.min, s.max, &mut scratch.edges);
            let best =
                stats::fit_best_prepared(&s, &scratch.hist, &scratch.edges, v.len(), candidates);
            out[0] = best.dist.id() as f32;
            out[1] = best.error as f32;
            out[2] = best.params[0] as f32;
            out[3] = best.params[1] as f32;
            out[4] = best.params[2] as f32;
        })
    }

    fn run_fit_single(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        dist: DistType,
    ) -> Result<OutMatrix> {
        self.run_batched(values, n_points, obs, 4, |v, scratch, out| {
            let s = PointStats::of_converted(v, &mut scratch.vals, &mut scratch.quant);
            let f = stats::fit_single_prepared(
                &scratch.vals,
                &s,
                dist,
                &mut scratch.hist,
                &mut scratch.edges,
            );
            out[0] = f.error as f32;
            out[1] = f.params[0] as f32;
            out[2] = f.params[1] as f32;
            out[3] = f.params[2] as f32;
        })
    }

    fn metrics(&self) -> BackendMetrics {
        *self.metrics.lock().unwrap()
    }

    fn reset_metrics(&self) {
        *self.metrics.lock().unwrap() = BackendMetrics::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gamma_batch(n: usize, obs: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * obs).map(|_| rng.gamma(3.0, 2.0) as f32).collect()
    }

    #[test]
    fn shapes_and_metrics() {
        let b = NativeBackend::with_options(2, 8, 32);
        let values = gamma_batch(20, 50, 1);
        let stats = b.run_stats(&values, 20, 50).unwrap();
        assert_eq!((stats.n_rows, stats.n_cols), (20, 12));
        let all = b.run_fit_all(&values, 20, 50, 10).unwrap();
        assert_eq!((all.n_rows, all.n_cols), (20, 5));
        let single = b.run_fit_single(&values, 20, 50, DistType::Gamma).unwrap();
        assert_eq!((single.n_rows, single.n_cols), (20, 4));
        let m = b.metrics();
        // 20 points in batches of 8 → 3 executions per call, 3 calls.
        assert_eq!(m.executions, 9);
        assert_eq!(m.rows_processed, 60);
        assert_eq!(m.rows_padded, 0);
        b.reset_metrics();
        assert_eq!(b.metrics().rows_processed, 0);
    }

    #[test]
    fn rejects_bad_shapes() {
        let b = NativeBackend::with_options(1, 8, 32);
        let values = vec![1.0f32; 100];
        assert!(b.run_stats(&values, 2, 100).is_err());
        assert!(b.run_stats(&values, 1, 99).is_err());
        assert!(b.run_fit_all(&values, 1, 100, 0).is_err());
        assert!(b.run_fit_all(&values, 1, 100, 11).is_err());
        assert!(b.run_stats(&[1.0], 1, 1).is_err(), "needs 2+ observations");
    }

    #[test]
    fn empty_batch_is_empty_matrix() {
        let b = NativeBackend::with_options(2, 8, 32);
        let out = b.run_fit_all(&[], 0, 100, 4).unwrap();
        assert_eq!((out.n_rows, out.n_cols), (0, 5));
        assert!(out.data.is_empty());
        assert_eq!(b.metrics().executions, 0);
    }

    #[test]
    fn results_independent_of_batch_and_workers() {
        let values = gamma_batch(70, 40, 2);
        let reference = NativeBackend::with_options(1, 1024, 32)
            .run_fit_all(&values, 70, 40, 10)
            .unwrap();
        for (workers, batch) in [(1, 1), (4, 7), (8, 64), (3, 70)] {
            let out = NativeBackend::with_options(workers, batch, 32)
                .run_fit_all(&values, 70, 40, 10)
                .unwrap();
            assert_eq!(out.data, reference.data, "workers={workers} batch={batch}");
        }
    }

    #[test]
    fn simd_width_edge_cases_hit_scalar_remainder() {
        // Observation vectors around the 4-lane SIMD width (width−1,
        // width, width+1, non-multiple tails) and single-point batches
        // must produce exactly what the scalar oracle produces — the
        // vector kernels' remainder loops ARE the scalar loops.
        let b = NativeBackend::with_options(2, 8, 32);
        for obs in [2usize, 3, 4, 5, 7, 8, 9, 13, 33] {
            for n_points in [1usize, 3, 4, 5, 7] {
                let values = gamma_batch(n_points, obs, 40 + obs as u64);
                let out = b.run_fit_all(&values, n_points, obs, 10).unwrap();
                assert_eq!((out.n_rows, out.n_cols), (n_points, 5));
                let st = b.run_stats(&values, n_points, obs).unwrap();
                for p in 0..n_points {
                    let v = &values[p * obs..(p + 1) * obs];
                    let best =
                        crate::stats::fit_best(v, &DistType::ALL, crate::stats::DEFAULT_BINS);
                    assert_eq!(out.data[p * 5], best.dist.id() as f32, "obs={obs} p={p}");
                    assert_eq!(out.data[p * 5 + 1], best.error as f32, "obs={obs} p={p}");
                    let s = PointStats::of(v);
                    assert_eq!(st.data[p * 12], s.mean as f32, "obs={obs} p={p} mean");
                    assert_eq!(st.data[p * 12 + 2], s.min as f32, "obs={obs} p={p} min");
                    assert_eq!(st.data[p * 12 + 3], s.max as f32, "obs={obs} p={p} max");
                }
            }
        }
        // Empty observation vectors stay rejected, empty batches empty.
        assert!(b.run_stats(&[], 1, 0).is_err());
        assert!(b.run_stats(&[1.0], 1, 1).is_err());
        assert!(b.run_stats(&[], 0, 0).unwrap().data.is_empty());
    }

    #[test]
    fn adaptive_controller_does_not_change_output_bits() {
        let values = gamma_batch(150, 40, 5);
        let reference = NativeBackend::with_options(4, 32, 32)
            .run_fit_all(&values, 150, 40, 10)
            .unwrap();
        let mut b = NativeBackend::with_options(4, 32, 32);
        b.enable_adaptive();
        assert!(b.adaptive());
        // Several calls so the controller actually moves between them.
        for round in 0..4 {
            let out = b.run_fit_all(&values, 150, 40, 10).unwrap();
            assert_eq!(out.data, reference.data, "round {round}");
        }
        let st_ref = NativeBackend::with_options(4, 32, 32)
            .run_stats(&values, 150, 40)
            .unwrap();
        let st = b.run_stats(&values, 150, 40).unwrap();
        assert_eq!(st.data, st_ref.data);
    }

    #[test]
    fn results_independent_of_pool_budget() {
        // The acceptance contract: output bytes are identical whatever
        // the host thread budget is.
        let values = gamma_batch(60, 48, 3);
        let reference = NativeBackend::with_options(4, 16, 32)
            .run_fit_all(&values, 60, 48, 10)
            .unwrap();
        for budget in [1usize, 2, 6] {
            let pool = HostPool::new(budget);
            let b = NativeBackend::with_pool(Arc::clone(&pool), 4, 16, 32);
            let out = b.run_fit_all(&values, 60, 48, 10).unwrap();
            assert_eq!(out.data, reference.data, "budget={budget}");
            let st = b.run_stats(&values, 60, 48).unwrap();
            let st_ref = NativeBackend::with_options(2, 32, 32)
                .run_stats(&values, 60, 48)
                .unwrap();
            assert_eq!(st.data, st_ref.data, "stats budget={budget}");
            pool.stop();
        }
    }
}
