//! PJRT execution engine (the `xla` feature): loads the HLO-text
//! artifacts produced by `python/compile/aot.py`, compiles them once on
//! the PJRT CPU client, and executes point batches from the
//! coordinator's hot path.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serialized protos carry 64-bit instruction ids that this XLA
//! build rejects; the text parser reassigns ids (see aot.py docstring and
//! /opt/xla-example/README.md).
//!
//! NOTE: [`super::Backend`] is now `Send + Sync` (the window pipeline
//! shares the backend across executor threads). The PJRT client's
//! buffers are Rc-based, so re-enabling this engine requires a
//! synchronization wrapper (one mutexed client, or a client per worker)
//! before the `impl Backend for Engine` below satisfies the bound. The
//! `compile_error!` below states this up front instead of letting the
//! build die on a wall of E0277 auto-trait errors.

compile_error!(
    "the `xla` feature needs porting: `runtime::Backend` is now `Send + Sync` (the window \
     pipeline shares one backend across executor threads), but `Engine` wraps the Rc-based \
     PJRT client. Serialize access (e.g. a mutexed client, or one client per worker), remove \
     this compile_error!, and re-enable the `xla` dependency in rust/Cargo.toml."
);

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::stats::DistType;
use crate::{PdfflowError, Result};

use super::manifest::{ArtifactInfo, ArtifactKind, Manifest};
use super::{Backend, BackendMetrics, OutMatrix};

/// The runtime engine: one compiled executable per artifact, compiled
/// lazily on first use and cached for the process lifetime.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    metrics: Mutex<BackendMetrics>,
}

impl Engine {
    /// Create the PJRT CPU client and load the manifest under `dir`.
    pub fn load_default(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            executables: Mutex::new(HashMap::new()),
            metrics: Mutex::new(BackendMetrics::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for an artifact.
    fn executable(&self, info: &ArtifactInfo) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.lock().unwrap().get(&info.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.manifest.path_of(info);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| PdfflowError::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.metrics.lock().unwrap().compile_seconds += t0.elapsed().as_secs_f64();
        self.executables
            .lock()
            .unwrap()
            .insert(info.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (startup warm-up, keeps compile time out of
    /// measured stages).
    pub fn warm(&self, info: &ArtifactInfo) -> Result<()> {
        self.executable(info).map(|_| ())
    }

    /// Execute an artifact over `n_points` observation vectors laid out
    /// point-major in `values` (`n_points * info.obs` floats). Points are
    /// chunked into batches of `info.batch`; the final partial batch is
    /// padded with copies of its last row (padding rows are discarded).
    pub fn run(&self, info: &ArtifactInfo, values: &[f32], n_points: usize) -> Result<OutMatrix> {
        if values.len() != n_points * info.obs {
            return Err(PdfflowError::InvalidArg(format!(
                "values len {} != {} points x {} obs",
                values.len(),
                n_points,
                info.obs
            )));
        }
        let exe = self.executable(info)?;
        let b = info.batch;
        let mut out = Vec::with_capacity(n_points * info.out_cols);
        let mut padded_rows = 0u64;
        let mut batch_buf = vec![0f32; b * info.obs];
        let t0 = Instant::now();
        let mut at = 0usize;
        while at < n_points {
            let take = b.min(n_points - at);
            let src = &values[at * info.obs..(at + take) * info.obs];
            let literal = if take == b {
                xla::Literal::vec1(src)
            } else {
                // Pad with the last real row (guard-safe values).
                batch_buf[..src.len()].copy_from_slice(src);
                let last = &src[(take - 1) * info.obs..take * info.obs].to_vec();
                for p in take..b {
                    batch_buf[p * info.obs..(p + 1) * info.obs].copy_from_slice(last);
                }
                padded_rows += (b - take) as u64;
                xla::Literal::vec1(&batch_buf)
            }
            .reshape(&[b as i64, info.obs as i64])?;
            let result = exe.execute::<xla::Literal>(&[literal])?[0][0].to_literal_sync()?;
            let tuple = result.to_tuple1()?;
            let rows: Vec<f32> = tuple.to_vec::<f32>()?;
            if rows.len() != b * info.out_cols {
                return Err(PdfflowError::Artifact(format!(
                    "{}: expected {} outputs, got {}",
                    info.name,
                    b * info.out_cols,
                    rows.len()
                )));
            }
            out.extend_from_slice(&rows[..take * info.out_cols]);
            at += take;
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut m = self.metrics.lock().unwrap();
        m.executions += n_points.div_ceil(b) as u64;
        m.rows_processed += n_points as u64;
        m.rows_padded += padded_rows;
        m.exec_seconds += dt;
        Ok(OutMatrix {
            n_rows: n_points,
            n_cols: info.out_cols,
            data: out,
        })
    }

    fn stats_info(&self, obs: usize) -> Result<ArtifactInfo> {
        self.manifest
            .find(ArtifactKind::Stats, None, None, obs)
            .cloned()
            .ok_or_else(|| PdfflowError::Artifact(format!("no stats artifact for obs={obs}")))
    }

    fn fit_all_info(&self, obs: usize, n_types: usize) -> Result<ArtifactInfo> {
        self.manifest
            .find(ArtifactKind::FitAll, None, Some(n_types), obs)
            .cloned()
            .ok_or_else(|| {
                PdfflowError::Artifact(format!("no fit_all{n_types} artifact for obs={obs}"))
            })
    }

    fn fit_single_info(&self, obs: usize, dist: DistType) -> Result<ArtifactInfo> {
        self.manifest
            .find(ArtifactKind::FitSingle, Some(dist), None, obs)
            .cloned()
            .ok_or_else(|| {
                PdfflowError::Artifact(format!(
                    "no fit_single {} artifact for obs={obs}",
                    dist.name()
                ))
            })
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn run_stats(&self, values: &[f32], n_points: usize, obs: usize) -> Result<OutMatrix> {
        let info = self.stats_info(obs)?;
        self.run(&info, values, n_points)
    }

    fn run_fit_all(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        n_types: usize,
    ) -> Result<OutMatrix> {
        let info = self.fit_all_info(obs, n_types)?;
        self.run(&info, values, n_points)
    }

    fn run_fit_single(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        dist: DistType,
    ) -> Result<OutMatrix> {
        let info = self.fit_single_info(obs, dist)?;
        self.run(&info, values, n_points)
    }

    /// Pre-compile every artifact for one observation count (what a run
    /// over a dataset with `obs` simulations may touch). Keeps PJRT
    /// compilation out of the measured pipeline stages, like Spark's
    /// executor warm-up.
    fn warm_all_for(&self, obs: usize) -> Result<()> {
        let infos: Vec<ArtifactInfo> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.obs == obs)
            .cloned()
            .collect();
        for info in infos {
            self.warm(&info)?;
        }
        Ok(())
    }

    fn metrics(&self) -> BackendMetrics {
        *self.metrics.lock().unwrap()
    }

    fn reset_metrics(&self) {
        *self.metrics.lock().unwrap() = BackendMetrics::default();
    }
}
