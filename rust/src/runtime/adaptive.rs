//! Occupancy-adaptive batch sizing for the native backend.
//!
//! The backend's chunk width (`pipeline.batch`) and fan-out width
//! (`pipeline.workers`) are static knobs; the right values depend on
//! observation length, candidate count and how loaded the host already
//! is. This controller closes the loop using the pool meters the
//! telemetry layer already maintains: between backend calls (i.e.
//! between pipeline windows) it reads the [`PoolMetrics`] deltas —
//! tickets run, busy seconds, per-worker busy histogram — and steers
//! the chunk width toward a mean per-ticket cost inside
//! [`TARGET_LOW_NS`, `TARGET_HIGH_NS`]:
//!
//! - tickets cheaper than the low water mark are mostly scheduling
//!   overhead → double the batch (and once the batch is maxed, halve
//!   the fan-out so fewer slots contend for the tiny queue);
//! - tickets above the high water mark starve the tail (the last chunk
//!   pins one worker while the rest idle) → halve the batch and restore
//!   full fan-out;
//! - a skewed per-worker busy histogram (one worker > [`SKEW_FACTOR`] ×
//!   the mean) is the same tail-starvation signal seen sideways → halve
//!   the batch;
//! - in-band tickets restore the fan-out cap and leave the batch alone.
//!
//! Decisions are clamped to `[min_batch, max_batch]` (never excluding
//! the configured seed width) and published as telemetry:
//! `backend.batch_width` / `backend.fanout_width` gauges and a
//! `backend.adapt_events` counter, so `pdfflow telemetry validate` and
//! the Prometheus export see every move the controller makes.
//!
//! **Determinism.** The backend's output bytes are pinned bitwise
//! independent of batch size, worker width and pool budget
//! (`results_independent_of_batch_and_workers` /
//! `_pool_budget` / the thread-invariance suite), so the controller can
//! only change *when* rows are computed, never *what* they contain.
//! Pin `pipeline.adaptive_batch = false` (config) to keep the fixed
//! widths, e.g. when comparing chunk-count-sensitive metrics across
//! runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::{Counter, Gauge, Registry};

use super::hostpool::PoolMetrics;

/// Mean per-ticket cost below which chunks are considered too fine
/// (scheduling overhead dominates): 0.25 ms.
pub const TARGET_LOW_NS: f64 = 250_000.0;
/// Mean per-ticket cost above which chunks are considered too coarse
/// (tail starvation dominates): 20 ms.
pub const TARGET_HIGH_NS: f64 = 20_000_000.0;
/// One worker busier than `SKEW_FACTOR ×` the per-worker mean marks a
/// skewed ticket histogram.
pub const SKEW_FACTOR: f64 = 4.0;
/// Default hard clamp on the adapted chunk width (the configured seed
/// width widens the clamp if it falls outside).
pub const MIN_BATCH: usize = 16;
/// See [`MIN_BATCH`].
pub const MAX_BATCH: usize = 16384;

/// Occupancy deltas are measured against the previous observation.
#[derive(Default)]
struct Baseline {
    tickets: u64,
    busy_s: f64,
    worker_busy: Vec<f64>,
}

/// The between-windows batch/fan-out controller (see module docs).
pub struct AdaptiveController {
    min_batch: usize,
    max_batch: usize,
    /// Configured fan-out cap; the controller only ever narrows it.
    cap: usize,
    batch: AtomicUsize,
    fanout: AtomicUsize,
    last: Mutex<Baseline>,
    adapt_events: Arc<Counter>,
    batch_gauge: Arc<Gauge>,
    fanout_gauge: Arc<Gauge>,
}

impl AdaptiveController {
    /// Controller seeded at the configured chunk width and fan-out cap.
    /// Registers its telemetry handles immediately so the metric
    /// families exist (at the seed values) even before the first
    /// adaptation.
    pub fn new(seed_batch: usize, workers: usize) -> AdaptiveController {
        let seed = seed_batch.max(1);
        let cap = workers.max(1);
        let r = Registry::global();
        let batch_gauge = r.gauge("backend.batch_width");
        let fanout_gauge = r.gauge("backend.fanout_width");
        batch_gauge.set(seed as f64);
        fanout_gauge.set(cap as f64);
        AdaptiveController {
            min_batch: MIN_BATCH.min(seed),
            max_batch: MAX_BATCH.max(seed),
            cap,
            batch: AtomicUsize::new(seed),
            fanout: AtomicUsize::new(cap),
            last: Mutex::new(Baseline::default()),
            adapt_events: r.counter("backend.adapt_events"),
            batch_gauge,
            fanout_gauge,
        }
    }

    /// Current chunk width.
    pub fn batch(&self) -> usize {
        self.batch.load(Ordering::Relaxed)
    }

    /// Current fan-out width (≤ the configured cap).
    pub fn fanout(&self) -> usize {
        self.fanout.load(Ordering::Relaxed)
    }

    /// Fold one pool-meter observation into the controller. Called at
    /// the top of every batched backend call; concurrent callers skip
    /// the observation instead of blocking (the widths they read are
    /// whatever the last completed observation chose).
    pub fn observe(&self, m: &PoolMetrics) {
        let Ok(mut last) = self.last.try_lock() else {
            return;
        };
        let d_tickets = m.tickets_run.saturating_sub(last.tickets);
        let d_busy = (m.busy_seconds - last.busy_s).max(0.0);
        let mut skewed = false;
        if m.per_worker.len() == last.worker_busy.len() {
            let deltas: Vec<f64> = m
                .per_worker
                .iter()
                .zip(&last.worker_busy)
                .map(|(w, prev)| (w.busy_s - prev).max(0.0))
                .collect();
            let active = deltas.iter().filter(|&&d| d > 0.0).count();
            if active >= 2 {
                let sum: f64 = deltas.iter().sum();
                let mean = sum / deltas.len() as f64;
                let max = deltas.iter().cloned().fold(0.0, f64::max);
                skewed = mean > 0.0 && max > SKEW_FACTOR * mean;
            }
        }
        last.tickets = m.tickets_run;
        last.busy_s = m.busy_seconds;
        last.worker_busy.clear();
        last.worker_busy.extend(m.per_worker.iter().map(|w| w.busy_s));
        drop(last);
        if d_tickets == 0 {
            return; // nothing ran on pool workers since last look
        }
        let mean_ns = d_busy * 1e9 / d_tickets as f64;
        let batch = self.batch();
        let fanout = self.fanout();
        let (mut new_batch, mut new_fanout) = (batch, fanout);
        if mean_ns < TARGET_LOW_NS {
            if batch < self.max_batch {
                new_batch = (batch * 2).min(self.max_batch);
            } else if fanout > 1 {
                // Chunks are maxed and still cheap: the work item itself
                // is tiny, so stop spreading it across the whole budget.
                new_fanout = (fanout / 2).max(1);
            }
        } else if mean_ns > TARGET_HIGH_NS || skewed {
            new_batch = (batch / 2).max(self.min_batch);
            new_fanout = self.cap;
        } else {
            new_fanout = self.cap;
        }
        if new_batch != batch || new_fanout != fanout {
            self.batch.store(new_batch, Ordering::Relaxed);
            self.fanout.store(new_fanout, Ordering::Relaxed);
            self.adapt_events.inc();
            self.batch_gauge.set(new_batch as f64);
            self.fanout_gauge.set(new_fanout as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::hostpool::WorkerMetrics;

    fn meters(tickets: u64, busy_s: f64, per_worker: Vec<WorkerMetrics>) -> PoolMetrics {
        PoolMetrics {
            budget: 4,
            workers: per_worker.len(),
            tickets_run: tickets,
            busy_seconds: busy_s,
            per_worker,
            ..PoolMetrics::default()
        }
    }

    fn even_workers(busy_each: f64) -> Vec<WorkerMetrics> {
        (0..3)
            .map(|_| WorkerMetrics {
                tickets: 1,
                busy_s: busy_each,
            })
            .collect()
    }

    #[test]
    fn cheap_tickets_grow_batch_then_narrow_fanout() {
        let c = AdaptiveController::new(64, 8);
        let mut tickets = 0u64;
        let mut busy = 0.0f64;
        let mut grow_steps = 0;
        // 50 µs mean tickets: far under the low water mark.
        while c.batch() < MAX_BATCH {
            tickets += 100;
            busy += 100.0 * 50e-6; // 100 tickets × 50 µs
            c.observe(&meters(tickets, busy, even_workers(busy / 3.0)));
            grow_steps += 1;
            assert!(grow_steps < 64, "batch never reached the max clamp");
        }
        assert_eq!(c.fanout(), 8, "fan-out untouched while batch can grow");
        // Still cheap at the max batch: fan-out halves toward 1.
        tickets += 100;
        busy += 100.0 * 50e-6;
        c.observe(&meters(tickets, busy, even_workers(busy / 3.0)));
        assert_eq!(c.batch(), MAX_BATCH);
        assert_eq!(c.fanout(), 4);
    }

    #[test]
    fn expensive_tickets_shrink_batch_and_restore_fanout() {
        let c = AdaptiveController::new(256, 8);
        // Drive fan-out down first with cheap tickets at a pinned batch.
        let mut t = 0u64;
        let mut b = 0.0f64;
        for _ in 0..40 {
            t += 50;
            b += 50.0 * 10e-6;
            c.observe(&meters(t, b, even_workers(b / 3.0)));
        }
        assert!(c.fanout() < 8);
        let shrunk_from = c.batch();
        // One 100 ms-mean observation: halve the batch, restore width.
        t += 10;
        b += 10.0 * 0.1;
        c.observe(&meters(t, b, even_workers(b / 3.0)));
        assert_eq!(c.batch(), (shrunk_from / 2).max(MIN_BATCH));
        assert_eq!(c.fanout(), 8);
    }

    #[test]
    fn batch_clamps_at_min_and_seed_widens_clamp() {
        let c = AdaptiveController::new(4, 2);
        // Seed below MIN_BATCH widens the low clamp to the seed.
        let mut t = 0u64;
        let mut b = 0.0f64;
        for _ in 0..20 {
            t += 10;
            b += 10.0 * 0.1; // 100 ms tickets, forever too coarse
            c.observe(&meters(t, b, even_workers(b / 3.0)));
        }
        assert_eq!(c.batch(), 4, "never adapts below the configured seed");
    }

    #[test]
    fn zero_ticket_delta_changes_nothing() {
        let c = AdaptiveController::new(128, 4);
        let m = meters(0, 0.0, even_workers(0.0));
        c.observe(&m);
        c.observe(&m);
        assert_eq!(c.batch(), 128);
        assert_eq!(c.fanout(), 4);
    }

    #[test]
    fn skewed_worker_histogram_halves_batch() {
        let c = AdaptiveController::new(512, 4);
        // Prime the baseline (worker deltas need a previous snapshot of
        // the same worker count before skew can be judged).
        let idle: Vec<WorkerMetrics> = vec![WorkerMetrics::default(); 8];
        c.observe(&meters(0, 0.0, idle));
        // In-band mean (1 ms) but one of eight workers carries ~all of
        // the busy time: max delta ≈ 7.4 × the per-worker mean.
        let mut lopsided = vec![WorkerMetrics::default(); 8];
        for w in &mut lopsided {
            *w = WorkerMetrics {
                tickets: 10,
                busy_s: 0.01,
            };
        }
        lopsided[0] = WorkerMetrics {
            tickets: 930,
            busy_s: 0.93,
        };
        c.observe(&meters(1000, 1.0, lopsided));
        assert_eq!(c.batch(), 256);
    }

    #[test]
    fn in_band_tickets_restore_fanout_only() {
        let c = AdaptiveController::new(128, 6);
        // Narrow the fan-out with cheap maxed-batch traffic first.
        let mut t = 0u64;
        let mut b = 0.0f64;
        for _ in 0..40 {
            t += 50;
            b += 50.0 * 10e-6;
            c.observe(&meters(t, b, even_workers(b / 3.0)));
        }
        let narrowed = c.fanout();
        assert!(narrowed < 6);
        let batch = c.batch();
        // One in-band (2 ms mean, even) observation restores the cap.
        t += 50;
        b += 50.0 * 2e-3;
        c.observe(&meters(t, b, even_workers(b / 3.0)));
        assert_eq!(c.fanout(), 6);
        assert_eq!(c.batch(), batch, "in-band leaves the batch alone");
    }

    #[test]
    fn decisions_are_published_as_telemetry() {
        let c = AdaptiveController::new(32, 4);
        let before = Registry::global().counter("backend.adapt_events").get();
        c.observe(&meters(100, 100.0 * 50e-9, even_workers(0.0)));
        let after = Registry::global().counter("backend.adapt_events").get();
        assert!(after > before, "adaptation must bump backend.adapt_events");
        // The gauges are process-global (other controllers in parallel
        // tests may write them too), so assert liveness, not the value.
        assert!(Registry::global().gauge("backend.batch_width").get() >= 1.0);
        assert!(Registry::global().gauge("backend.fanout_width").get() >= 1.0);
    }
}
