//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (names, shapes, kinds, type order, Eq.5 bin count).

use std::path::{Path, PathBuf};

use crate::stats::DistType;
use crate::util::json::Json;
use crate::{PdfflowError, Result};

/// Kinds of AOT graphs (mirrors `model.GraphSpec.kind`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(B,N) -> (B,12)` point statistics.
    Stats,
    /// `(B,N) -> (B,4)` one-type fit: [err, p0, p1, p2].
    FitSingle,
    /// `(B,N) -> (B,5)` argmin fit: [type_id, err, p0, p1, p2].
    FitAll,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "stats" => Ok(ArtifactKind::Stats),
            "fit_single" => Ok(ArtifactKind::FitSingle),
            "fit_all" => Ok(ArtifactKind::FitAll),
            other => Err(PdfflowError::Artifact(format!("unknown kind {other:?}"))),
        }
    }
}

/// One AOT-compiled graph on disk.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    /// Distribution type for FitSingle artifacts.
    pub dist: Option<DistType>,
    /// Candidate-set size for FitAll artifacts (4 or 10).
    pub n_types: Option<usize>,
    pub batch: usize,
    pub obs: usize,
    pub out_cols: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub l_bins: usize,
    pub penalty_error: f64,
    pub stats_cols: Vec<String>,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            PdfflowError::Artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(PdfflowError::Artifact)?;
        let arr = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| PdfflowError::Artifact("manifest missing artifacts".into()))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let s = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| PdfflowError::Artifact(format!("artifact missing {k}")))
            };
            let n = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| PdfflowError::Artifact(format!("artifact missing {k}")))
            };
            let dist = match a.get("type") {
                Some(Json::Str(name)) => Some(DistType::from_name(name).ok_or_else(|| {
                    PdfflowError::Artifact(format!("unknown distribution {name:?}"))
                })?),
                _ => None,
            };
            let n_types = a.get("n_types").and_then(|v| v.as_usize());
            artifacts.push(ArtifactInfo {
                name: s("name")?,
                file: s("file")?,
                kind: ArtifactKind::parse(&s("kind")?)?,
                dist,
                n_types,
                batch: n("batch")?,
                obs: n("obs")?,
                out_cols: n("out_cols")?,
            });
        }
        // Validate the type order matches rust's canonical DistType order.
        if let Some(types) = j.get("types").and_then(|t| t.as_arr()) {
            for (i, t) in types.iter().enumerate() {
                let name = t.as_str().unwrap_or("");
                match DistType::from_id(i) {
                    Some(d) if d.name() == name => {}
                    _ => {
                        return Err(PdfflowError::Artifact(format!(
                            "type order mismatch at {i}: manifest {name:?}"
                        )))
                    }
                }
            }
        }
        Ok(Manifest {
            dir,
            l_bins: j.get("l_bins").and_then(|v| v.as_usize()).unwrap_or(32),
            penalty_error: j
                .get("penalty_error")
                .and_then(|v| v.as_f64())
                .unwrap_or(2.0),
            stats_cols: j
                .get("stats_cols")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|s| s.as_str().map(|x| x.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
            artifacts,
        })
    }

    /// Artifacts of a kind for an observation count, any batch size.
    pub fn find(
        &self,
        kind: ArtifactKind,
        dist: Option<DistType>,
        n_types: Option<usize>,
        obs: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.obs == obs && a.dist == dist && a.n_types == n_types
            })
            .max_by_key(|a| a.batch)
    }

    /// Column index in the stats artifact output.
    pub fn stats_col(&self, name: &str) -> Option<usize> {
        self.stats_cols.iter().position(|c| c == name)
    }

    /// Observation counts covered by the artifact set.
    pub fn obs_variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.artifacts.iter().map(|a| a.obs).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn path_of(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests against the real artifact set only run with the xla feature,
    // whose workflow (`make artifacts`) produces artifacts/manifest.json;
    // the default (native) build has no artifact directory at all.
    #[cfg(feature = "xla")]
    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[cfg(feature = "xla")]
    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(artifacts_dir()).expect("run `make artifacts` first");
        assert_eq!(m.l_bins, 32);
        assert!(m.artifacts.len() >= 13);
        assert_eq!(m.stats_col("mean"), Some(0));
        assert_eq!(m.stats_col("std"), Some(1));
        assert!(m.obs_variants().contains(&100));
    }

    #[cfg(feature = "xla")]
    #[test]
    fn find_resolves_each_kind() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let stats = m.find(ArtifactKind::Stats, None, None, 100).unwrap();
        assert_eq!(stats.out_cols, 12);
        let single = m
            .find(ArtifactKind::FitSingle, Some(DistType::Gamma), None, 100)
            .unwrap();
        assert_eq!(single.out_cols, 4);
        let all4 = m.find(ArtifactKind::FitAll, None, Some(4), 100).unwrap();
        assert_eq!(all4.out_cols, 5);
        assert!(m.find(ArtifactKind::FitAll, None, Some(7), 100).is_none());
        assert!(m.find(ArtifactKind::Stats, None, None, 12345).is_none());
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("pdfflow-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "l_bins": 32,
              "penalty_error": 2.0,
              "stats_cols": ["mean", "std"],
              "types": ["normal", "uniform"],
              "artifacts": [
                {"name": "stats_b64_o100", "file": "stats.hlo.txt", "kind": "stats",
                 "batch": 64, "obs": 100, "out_cols": 12},
                {"name": "fit_single_gamma", "file": "g.hlo.txt", "kind": "fit_single",
                 "type": "gamma", "batch": 64, "obs": 100, "out_cols": 4},
                {"name": "fit_all4", "file": "a4.hlo.txt", "kind": "fit_all",
                 "n_types": 4, "batch": 64, "obs": 100, "out_cols": 5}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.stats_col("std"), Some(1));
        let stats = m.find(ArtifactKind::Stats, None, None, 100).unwrap();
        assert_eq!(stats.out_cols, 12);
        assert!(m
            .find(ArtifactKind::FitSingle, Some(DistType::Gamma), None, 100)
            .is_some());
        assert!(m.find(ArtifactKind::FitAll, None, Some(10), 100).is_none());
        assert_eq!(m.obs_variants(), vec![100]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
