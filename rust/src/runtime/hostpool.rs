//! The shared host thread pool: **one process-wide compute budget** for
//! every parallel layer of the coordinator.
//!
//! Before this module, each layer owned its own threads: the executor
//! spawned scoped workers per stage, the native backend spawned scoped
//! workers per *batched call* (once per chunk fan-out, per window), and
//! the query engine spawned its own on top. The `executor_threads` and
//! backend `workers` knobs therefore composed *multiplicatively* — on an
//! N-core host the defaults could put `N x N` runnable threads on the
//! scheduler. The [`HostPool`] ends that: a fixed budget of persistent
//! workers serves every layer, and the per-layer knobs become *width
//! caps* (how much of the shared budget a stage may draw), not thread
//! counts.
//!
//! ## Design
//!
//! A pool with budget `B` spawns `B - 1` persistent worker threads; the
//! calling thread supplies the remaining slot by **helping** drain its
//! own batch (help-first scheduling). Work is submitted as *tickets*: a
//! ticket is a type-erased claim loop over a [`ScopeCtx`] that lives on
//! the submitting caller's stack (or in its [`ScopeHandle`]). Workers
//! pop tickets from a shared queue; each ticket claims item indices
//! from the batch's atomic cursor until the batch is exhausted. Because
//! the caller *also* claims items, a batch always makes progress even
//! when every pool worker is busy elsewhere — nested fan-out (a backend
//! call inside an executor task) can never deadlock, it just runs on
//! the threads it can get, bounded by the one global budget.
//!
//! Safety of the lifetime erasure rests on one invariant, enforced by
//! [`ScopeHandle`]: the scope owner does not return until every ticket
//! it enqueued has either been **revoked** (removed from the queue
//! before any worker claimed it) or has **finished running**. A ticket
//! that a worker has already popped is never revoked — the owner waits
//! for it — so the context pointer inside a running ticket is always
//! live.
//!
//! Panics inside batch items are caught at the claim loop (persistent
//! workers must survive them), recorded in the scope, and re-raised on
//! the owner's thread by [`ScopeHandle::join`] — the same fail-fast
//! stage semantics the scoped-thread implementation had.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// A caught panic payload, re-raised on the scope owner's thread.
pub type PanicPayload = Box<dyn std::any::Any + Send>;

/// The default host budget: the `PDFFLOW_THREADS` environment override
/// when set to a positive integer, else all host cores.
pub fn default_budget() -> usize {
    std::env::var("PDFFLOW_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Budget requested via [`configure`] before the global pool was built.
static REQUESTED_BUDGET: AtomicUsize = AtomicUsize::new(0);

/// Request a global-pool budget (CLI `--host-threads`, config
/// `pipeline.host_threads`). Effective only before the global pool's
/// first use; returns the budget actually in force, so callers can
/// report when a live pool kept its original size.
pub fn configure(budget: usize) -> usize {
    REQUESTED_BUDGET.store(budget.max(1), Ordering::Relaxed);
    HostPool::global().budget()
}

thread_local! {
    static ON_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True on a pool worker thread. Blocking coordination stages (the
/// executor's sequenced sink) check this and fall back to inline
/// execution rather than parking a budgeted worker on a sink loop.
pub fn on_pool_worker() -> bool {
    ON_POOL_WORKER.with(|c| c.get())
}

/// Per-worker slice of the pool counters: one bar of the busy-time
/// histogram the verbose CLI prints (a skewed histogram means one
/// worker is pinned on long tickets while the rest idle).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerMetrics {
    /// Tickets this worker executed.
    pub tickets: u64,
    /// Wall-clock seconds this worker spent inside tickets.
    pub busy_s: f64,
}

/// Aggregate pool observability counters.
#[derive(Clone, Debug, Default)]
pub struct PoolMetrics {
    /// Total compute budget (workers + the helping caller slot).
    pub budget: usize,
    /// Persistent worker threads spawned (== budget - 1).
    pub workers: usize,
    /// Workers currently executing a ticket.
    pub busy: usize,
    /// Maximum concurrently-busy workers ever observed.
    pub peak_busy: usize,
    /// Tickets executed by pool workers (caller helping is not a ticket).
    pub tickets_run: u64,
    /// Wall-clock seconds pool workers spent inside tickets.
    pub busy_seconds: f64,
    /// Deepest ticket queue ever observed.
    pub peak_queue_depth: usize,
    /// Batch items drained by a thread other than the scope's
    /// submitter — work *stolen* from the caller by the help-first
    /// scheduler's pool workers.
    pub items_stolen: u64,
    /// Batch items the submitting callers drained themselves.
    pub items_helped: u64,
    /// Per-worker busy-time histogram (one entry per persistent worker).
    pub per_worker: Vec<WorkerMetrics>,
}

/// A type-erased pointer to a live [`ScopeCtx`] plus its monomorphized
/// entry point. See the module docs for the liveness invariant.
struct Ticket {
    ctx: *const (),
    run: unsafe fn(*const ()),
}

// Safety: the pointee is a `ScopeCtx<F>` with `F: Sync`, kept alive by
// its owning `ScopeHandle` until this ticket finishes or is revoked.
unsafe impl Send for Ticket {}

struct TicketLedger {
    enqueued: usize,
    finished: usize,
}

/// Shared state of one scoped batch: the work closure, the item claim
/// cursor, and the ticket ledger the owner joins on.
struct ScopeCtx<F> {
    f: *const F,
    n: usize,
    /// The pool this scope draws from — alive for the whole scope (the
    /// [`ScopeHandle`] borrows it), used only to attribute drained item
    /// counts to the steal/help meters.
    pool: *const HostPool,
    /// Thread that submitted the scope: items it drains itself are
    /// *helped*, items any other thread drains are *stolen* — accurate
    /// even for scopes submitted from inside a pool ticket.
    submitter: std::thread::ThreadId,
    cursor: AtomicUsize,
    cancelled: AtomicBool,
    tickets: Mutex<TicketLedger>,
    tickets_cv: Condvar,
    panic: Mutex<Option<PanicPayload>>,
}

// Safety: every field except `f` is Sync; `f` points at an
// `F: Fn(usize) + Sync` owned by the scope owner, which outlives every
// ticket (ScopeHandle revokes or joins them before releasing the
// borrow).
unsafe impl<F: Sync> Sync for ScopeCtx<F> {}

impl<F: Fn(usize) + Sync> ScopeCtx<F> {
    /// The claim loop: run items until the cursor is exhausted or the
    /// batch is cancelled by a panic. Runs on the owner (helping) and on
    /// pool workers (via tickets).
    fn drain(&self) {
        // Safety: see the module-level liveness invariant.
        let f = unsafe { &*self.f };
        let mut ran = 0u64;
        loop {
            if self.cancelled.load(Ordering::Relaxed) {
                break;
            }
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            ran += 1;
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
            if let Err(p) = r {
                // First panic wins; remaining items are cancelled and
                // the payload re-raises at the owner's join.
                self.cancelled.store(true, Ordering::Relaxed);
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        }
        if ran > 0 {
            // Attribute drained items: drained by the submitting thread
            // itself they are helped, drained by anyone else (a pool
            // worker running this scope's ticket) they were stolen.
            // Safety: the pool outlives the scope.
            let pool = unsafe { &*self.pool };
            let meter = if std::thread::current().id() == self.submitter {
                &pool.items_helped
            } else {
                &pool.items_stolen
            };
            meter.fetch_add(ran, Ordering::Relaxed);
        }
    }

    fn finish_ticket(&self) {
        let mut t = self.tickets.lock().unwrap();
        t.finished += 1;
        self.tickets_cv.notify_all();
    }
}

/// Monomorphized ticket entry point.
unsafe fn run_ticket<F: Fn(usize) + Sync>(ctx: *const ()) {
    let ctx = &*(ctx as *const ScopeCtx<F>);
    ctx.drain();
    ctx.finish_ticket();
}

/// Joins a scoped batch: revokes still-queued tickets and waits for
/// claimed ones, keeping the borrowed work closure alive meanwhile.
///
/// Crate-private on purpose: the safety of the lifetime erasure relies
/// on this handle's `Drop`/`join` actually running before the borrowed
/// closure goes away. A leaked handle (`std::mem::forget`) would leave
/// tickets holding a dangling context pointer, so the open-scope form
/// must not cross the crate boundary — external callers get the
/// closed, always-joined [`HostPool::scope_run`] / `parallel_map`.
pub(crate) struct ScopeHandle<'scope, F: Fn(usize) + Sync> {
    pool: &'scope HostPool,
    ctx: Box<ScopeCtx<F>>,
    joined: bool,
    _borrow: std::marker::PhantomData<&'scope F>,
}

impl<F: Fn(usize) + Sync> ScopeHandle<'_, F> {
    /// Run the claim loop on the calling thread (help-first: the caller
    /// is the budget slot the pool did not spawn).
    pub(crate) fn help(&self) {
        self.ctx.drain();
    }

    /// Revoke still-queued tickets, wait for claimed ones. Idempotent.
    fn finish(&mut self) {
        if self.joined {
            return;
        }
        self.joined = true;
        let ptr = &*self.ctx as *const ScopeCtx<F> as *const ();
        let removed = {
            let mut q = self.pool.queue.lock().unwrap();
            let before = q.len();
            q.retain(|t| t.ctx != ptr);
            before - q.len()
        };
        let mut t = self.ctx.tickets.lock().unwrap();
        t.finished += removed;
        while t.finished < t.enqueued {
            t = self.ctx.tickets_cv.wait(t).unwrap();
        }
    }

    /// Finish the scope and re-raise any panic captured from an item.
    pub(crate) fn join(mut self) {
        self.finish();
        let p = self.ctx.panic.lock().unwrap().take();
        if let Some(p) = p {
            std::panic::resume_unwind(p);
        }
    }
}

impl<F: Fn(usize) + Sync> Drop for ScopeHandle<'_, F> {
    fn drop(&mut self) {
        self.finish();
    }
}

/// One worker's always-on counters (the busy-time histogram source).
#[derive(Default)]
struct WorkerStat {
    tickets: AtomicU64,
    busy_nanos: AtomicU64,
}

/// The persistent work-stealing host pool (see module docs).
pub struct HostPool {
    budget: usize,
    spawned: usize,
    queue: Mutex<VecDeque<Ticket>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    busy: AtomicUsize,
    peak_busy: AtomicUsize,
    peak_queue: AtomicUsize,
    items_stolen: AtomicU64,
    items_helped: AtomicU64,
    /// Per-worker ticket/busy counters; the aggregate `tickets_run` /
    /// `busy_seconds` metrics are sums over these, so the histogram and
    /// its total can never disagree.
    worker_stats: Vec<WorkerStat>,
}

impl std::fmt::Debug for HostPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HostPool")
            .field("budget", &self.budget)
            .field("workers", &self.spawned)
            .finish()
    }
}

fn worker_loop(pool: Arc<HostPool>, k: usize) {
    ON_POOL_WORKER.with(|c| c.set(true));
    loop {
        let ticket = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if pool.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                q = pool.work_cv.wait(q).unwrap();
            }
        };
        let busy = pool.busy.fetch_add(1, Ordering::Relaxed) + 1;
        pool.peak_busy.fetch_max(busy, Ordering::Relaxed);
        let t0 = Instant::now();
        // Safety: the owning scope is still joined on this ticket
        // (revocation removes only *queued* tickets), so ctx is alive.
        unsafe { (ticket.run)(ticket.ctx) };
        let nanos = t0.elapsed().as_nanos() as u64;
        let stat = &pool.worker_stats[k];
        stat.tickets.fetch_add(1, Ordering::Relaxed);
        stat.busy_nanos.fetch_add(nanos, Ordering::Relaxed);
        ticket_hist().record(nanos);
        pool.busy.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Process-wide ticket-duration histogram (`pool.ticket_ns`). All
/// pools feed it — test pools included — so it measures the host's
/// overall task-size distribution; per-pool assertions stay on the
/// instance-local [`WorkerStat`] atomics above.
fn ticket_hist() -> &'static crate::telemetry::Histogram {
    static HIST: std::sync::OnceLock<std::sync::Arc<crate::telemetry::Histogram>> =
        std::sync::OnceLock::new();
    HIST.get_or_init(|| crate::telemetry::Registry::global().histogram("pool.ticket_ns"))
}

impl HostPool {
    /// A pool with `budget` total compute threads: `budget - 1`
    /// persistent workers are spawned eagerly, and the calling thread
    /// supplies the last slot by helping drain its own batches. Custom
    /// pools are for tests and embedders; production code shares
    /// [`HostPool::global`].
    pub fn new(budget: usize) -> Arc<HostPool> {
        let budget = budget.max(1);
        let workers = budget - 1;
        let pool = Arc::new(HostPool {
            budget,
            spawned: workers,
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            busy: AtomicUsize::new(0),
            peak_busy: AtomicUsize::new(0),
            peak_queue: AtomicUsize::new(0),
            items_stolen: AtomicU64::new(0),
            items_helped: AtomicU64::new(0),
            worker_stats: (0..workers).map(|_| WorkerStat::default()).collect(),
        });
        for k in 0..workers {
            let p = Arc::clone(&pool);
            std::thread::Builder::new()
                .name(format!("pdfflow-host-{k}"))
                .spawn(move || worker_loop(p, k))
                .expect("spawn host pool worker");
        }
        pool
    }

    /// The process-wide pool every layer shares. Built on first use with
    /// the [`configure`]d budget, else [`default_budget`]; lives for the
    /// process.
    pub fn global() -> &'static Arc<HostPool> {
        static GLOBAL: OnceLock<Arc<HostPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let requested = REQUESTED_BUDGET.load(Ordering::Relaxed);
            let budget = if requested > 0 {
                requested
            } else {
                default_budget()
            };
            HostPool::new(budget)
        })
    }

    /// Total compute budget (persistent workers + the caller slot).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Persistent worker threads owned by this pool — the thread census
    /// the no-oversubscription contract pins: always `budget - 1`, so
    /// workers plus one helping caller never exceed the budget.
    pub fn spawned_threads(&self) -> usize {
        self.spawned
    }

    fn max_workers(&self) -> usize {
        self.spawned
    }

    pub fn metrics(&self) -> PoolMetrics {
        let per_worker: Vec<WorkerMetrics> = self
            .worker_stats
            .iter()
            .map(|s| WorkerMetrics {
                tickets: s.tickets.load(Ordering::Relaxed),
                busy_s: s.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            })
            .collect();
        PoolMetrics {
            budget: self.budget,
            workers: self.spawned,
            busy: self.busy.load(Ordering::Relaxed),
            peak_busy: self.peak_busy.load(Ordering::Relaxed),
            tickets_run: per_worker.iter().map(|w| w.tickets).sum(),
            busy_seconds: per_worker.iter().map(|w| w.busy_s).sum(),
            peak_queue_depth: self.peak_queue.load(Ordering::Relaxed),
            items_stolen: self.items_stolen.load(Ordering::Relaxed),
            items_helped: self.items_helped.load(Ordering::Relaxed),
            per_worker,
        }
    }

    /// Stop the persistent workers once the queue drains (test pools
    /// only; the global pool lives for the process). Scoped batches
    /// still complete afterwards — the owner's helping thread drains
    /// them — just without extra parallelism.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _guard = self.queue.lock().unwrap();
        self.work_cv.notify_all();
    }

    /// Enqueue up to `tickets` claim loops over item indices `0..n` of
    /// `work`. The returned handle's `join` (or drop) revokes unclaimed
    /// tickets and blocks until claimed ones finish, so `work` and
    /// everything it borrows stay valid for the tickets' whole
    /// lifetime. Crate-private: see [`ScopeHandle`] — leaking the
    /// handle from safe external code would dangle the erased borrow.
    pub(crate) fn scope_tickets<'s, F>(
        &'s self,
        n: usize,
        tickets: usize,
        work: &'s F,
    ) -> ScopeHandle<'s, F>
    where
        F: Fn(usize) + Sync,
    {
        let tickets = tickets.min(self.max_workers()).min(n);
        let ctx = Box::new(ScopeCtx {
            f: work as *const F,
            n,
            pool: self as *const HostPool,
            submitter: std::thread::current().id(),
            cursor: AtomicUsize::new(0),
            cancelled: AtomicBool::new(false),
            tickets: Mutex::new(TicketLedger {
                enqueued: tickets,
                finished: 0,
            }),
            tickets_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        if tickets > 0 {
            let ptr = &*ctx as *const ScopeCtx<F> as *const ();
            let mut q = self.queue.lock().unwrap();
            for _ in 0..tickets {
                q.push_back(Ticket {
                    ctx: ptr,
                    run: run_ticket::<F>,
                });
            }
            let depth = q.len();
            drop(q);
            self.peak_queue.fetch_max(depth, Ordering::Relaxed);
            self.work_cv.notify_all();
        }
        ScopeHandle {
            pool: self,
            ctx,
            joined: false,
            _borrow: std::marker::PhantomData,
        }
    }

    /// Help-first parallel for over `0..n`: the caller claims items
    /// alongside up to `width - 1` pool workers, so the batch always
    /// progresses even on a saturated (or zero-worker) pool, and total
    /// live threads never exceed the pool budget. Panics in items are
    /// re-raised here after the batch quiesces.
    pub fn scope_run<F>(&self, n: usize, width: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let width = width.max(1).min(n);
        let handle = self.scope_tickets(n, width - 1, f);
        handle.help();
        handle.join();
    }

    /// Order-preserving parallel map drawing at most `width` slots from
    /// the shared budget (the caller's slot included). Panics propagate.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, width: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let width = width.max(1).min(n);
        if width == 1 || self.max_workers() == 0 {
            return items.into_iter().map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let run = |i: usize| {
            let item = slots[i].lock().unwrap().take().expect("item claimed twice");
            let r = f(item);
            *results[i].lock().unwrap() = Some(r);
        };
        self.scope_run(n, width, &run);
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("missing result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn parallel_map_preserves_order_and_runs_once() {
        let pool = HostPool::new(4);
        let counter = AtomicU64::new(0);
        let out = pool.parallel_map((0..500).collect::<Vec<_>>(), 4, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i * 2
        });
        assert_eq!(out, (0..500).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(counter.load(Ordering::Relaxed), 500);
        pool.stop();
    }

    #[test]
    fn census_is_budget_minus_one() {
        for budget in [1usize, 2, 5] {
            let pool = HostPool::new(budget);
            assert_eq!(pool.budget(), budget);
            assert_eq!(pool.spawned_threads(), budget - 1);
            // Workers + the helping caller never exceed the budget.
            assert!(pool.spawned_threads() < pool.budget().max(2));
            pool.stop();
        }
    }

    #[test]
    fn zero_worker_pool_runs_serially() {
        let pool = HostPool::new(1);
        let out = pool.parallel_map((0..64).collect::<Vec<_>>(), 8, |i| i + 1);
        assert_eq!(out.len(), 64);
        assert_eq!(out[63], 64);
        pool.stop();
    }

    #[test]
    fn panics_propagate_and_cancel_the_batch() {
        let pool = HostPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_map((0..64).collect::<Vec<_>>(), 3, |i| {
                if i == 11 {
                    panic!("item 11 exploded");
                }
                i
            })
        }));
        assert!(r.is_err());
        // The pool survives the panic and keeps serving.
        let out = pool.parallel_map(vec![1u32, 2, 3], 3, |x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
        pool.stop();
    }

    #[test]
    fn nested_batches_share_the_budget_without_deadlock() {
        let pool = HostPool::new(3);
        let out = pool.parallel_map((0..8u64).collect::<Vec<_>>(), 3, |i| {
            // Nested fan-out from inside a batch item: help-first
            // guarantees progress even when every worker is busy.
            let inner = pool.parallel_map((0..50u64).collect::<Vec<_>>(), 3, move |j| i * 100 + j);
            inner.iter().sum::<u64>()
        });
        let expect: Vec<u64> = (0..8u64).map(|i| (0..50u64).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(out, expect);
        assert!(pool.metrics().peak_busy <= pool.spawned_threads());
        pool.stop();
    }

    #[test]
    fn stopped_pool_still_completes_batches_via_helping() {
        let pool = HostPool::new(4);
        pool.stop();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let out = pool.parallel_map((0..40).collect::<Vec<_>>(), 4, |i| i);
        assert_eq!(out.len(), 40);
    }

    #[test]
    fn scope_tickets_revokes_unclaimed_work() {
        // Zero-worker pool: tickets would never be claimed; the handle
        // must revoke them and the caller must drain everything.
        let pool = HostPool::new(1);
        let hits = AtomicU64::new(0);
        let work = |_i: usize| {
            hits.fetch_add(1, Ordering::Relaxed);
        };
        let handle = pool.scope_tickets(10, 4, &work);
        handle.help();
        handle.join();
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        pool.stop();
    }

    #[test]
    fn steal_and_help_meters_account_every_item() {
        let pool = HostPool::new(3);
        let before = pool.metrics();
        pool.parallel_map((0..400).collect::<Vec<_>>(), 3, |i| {
            // A little work so the workers actually claim tickets.
            std::hint::black_box(i * 7)
        });
        let m = pool.metrics();
        let drained =
            (m.items_stolen + m.items_helped) - (before.items_stolen + before.items_helped);
        assert_eq!(drained, 400, "every item drained exactly once");
        // Per-worker histogram covers exactly the spawned workers and
        // sums to the aggregate ticket/busy counters.
        assert_eq!(m.per_worker.len(), pool.spawned_threads());
        let tickets: u64 = m.per_worker.iter().map(|w| w.tickets).sum();
        assert_eq!(tickets, m.tickets_run);
        let busy: f64 = m.per_worker.iter().map(|w| w.busy_s).sum();
        assert!((busy - m.busy_seconds).abs() < 1e-9);
        pool.stop();
    }

    #[test]
    fn zero_worker_pool_attributes_everything_to_helping() {
        // With no workers every ticket is revoked and the caller drains
        // the whole batch itself: all 10 items metered as helped, none
        // as stolen — deterministically.
        let pool = HostPool::new(1);
        let work = |_i: usize| {};
        let handle = pool.scope_tickets(10, 4, &work);
        handle.help();
        handle.join();
        let m = pool.metrics();
        assert_eq!(m.items_helped, 10);
        assert_eq!(m.items_stolen, 0);
        assert!(m.per_worker.is_empty());
        pool.stop();
    }

    #[test]
    fn global_pool_exists_and_is_bounded() {
        let pool = HostPool::global();
        assert!(pool.budget() >= 1);
        assert_eq!(pool.spawned_threads(), pool.budget() - 1);
        let out = pool.parallel_map(vec![1, 2, 3, 4], 4, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }
}
