//! Pluggable compute backends for the numeric hot path.
//!
//! The coordinator talks to the fitting kernels through the [`Backend`]
//! trait — batched execution of the three graph shapes the paper's hot
//! path needs (per-point statistics, argmin fit over a candidate set,
//! single-type fit), all returning the row-major [`OutMatrix`] contract:
//!
//! | call             | output row                          | cols |
//! |------------------|-------------------------------------|------|
//! | `run_stats`      | `STATS_COLS` (mean, std, …)         | 12   |
//! | `run_fit_all`    | `[type_id, err, p0, p1, p2]`        | 5    |
//! | `run_fit_single` | `[err, p0, p1, p2]`                 | 4    |
//!
//! Two implementations exist:
//!
//! * [`NativeBackend`] (default, always available) — evaluates the
//!   pure-Rust oracle in [`crate::stats`] over thread-parallel point
//!   batches with reusable per-batch scratch buffers. No artifacts, no
//!   Python, no XLA toolchain: `cargo test` runs on any machine.
//! * `Engine` (behind the `xla` cargo feature) — the PJRT engine that
//!   compiles and executes the HLO-text artifacts produced by
//!   `python/compile/aot.py` (JAX graphs with Pallas kernels). See
//!   `rust/README.md` for how to enable it.
//!
//! Backend selection: `BackendKind::from_name` ("native" / "xla"),
//! the `PDFFLOW_BACKEND` environment variable, the `backend` config
//! key, or the `--backend` CLI flag.

pub mod adaptive;
pub mod hostpool;
pub mod manifest;
pub mod native;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use adaptive::AdaptiveController;
pub use hostpool::{HostPool, PoolMetrics, WorkerMetrics};
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest};
pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_engine::Engine;

use crate::stats::DistType;
use crate::{PdfflowError, Result};

/// Cumulative execution metrics (per backend instance).
///
/// `rows_padded` and `compile_seconds` are only non-zero for backends
/// that pad fixed-shape batches / compile executables (the XLA engine);
/// the native backend reports them as 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackendMetrics {
    pub executions: u64,
    pub rows_processed: u64,
    pub rows_padded: u64,
    pub exec_seconds: f64,
    pub compile_seconds: f64,
}

/// Result of one batched run over `n` points: row-major
/// `(n_rows, n_cols)` f32 matrix.
#[derive(Clone, Debug)]
pub struct OutMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub data: Vec<f32>,
}

impl OutMatrix {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n_cols..(i + 1) * self.n_cols]
    }

    pub fn col(&self, c: usize) -> impl Iterator<Item = f32> + '_ {
        (0..self.n_rows).map(move |i| self.data[i * self.n_cols + c])
    }
}

/// A batched fitting-kernel executor (the L3 ↔ L2 boundary).
///
/// `values` is always point-major: `n_points * obs` f32 observations.
/// Implementations must produce identical row layouts so every caller
/// (pipeline, benches, tests) is backend-generic.
///
/// Backends are `Send + Sync`: the window pipeline shares one backend
/// across concurrent executor tasks. A backend wrapping a non-`Sync`
/// client (the PJRT engine's Rc-based buffers) must serialize access
/// internally (e.g. a mutexed client handle).
pub trait Backend: Send + Sync {
    /// Short stable identifier ("native", "xla") for logs and reports.
    fn name(&self) -> &'static str;

    /// Per-point statistics: `(n_points, 12)` in `STATS_COLS` order
    /// (mean, std, min, max, skew, kurt_ex, meanlog, stdlog, q25, q50,
    /// q75, pos_frac).
    fn run_stats(&self, values: &[f32], n_points: usize, obs: usize) -> Result<OutMatrix>;

    /// Algorithm 3: fit the first `n_types` candidate types per point,
    /// keep the argmin — `(n_points, 5)` rows `[type_id, err, p0, p1, p2]`.
    fn run_fit_all(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        n_types: usize,
    ) -> Result<OutMatrix>;

    /// Algorithm 4 body: fit exactly one type per point —
    /// `(n_points, 4)` rows `[err, p0, p1, p2]`.
    fn run_fit_single(
        &self,
        values: &[f32],
        n_points: usize,
        obs: usize,
        dist: DistType,
    ) -> Result<OutMatrix>;

    /// Pre-compile / pre-warm everything a run over `obs`-observation
    /// points may touch, keeping one-time costs out of measured stages
    /// (Spark analog: executor warm-up). No-op for backends that have
    /// nothing to compile.
    fn warm_all_for(&self, obs: usize) -> Result<()> {
        let _ = obs;
        Ok(())
    }

    fn metrics(&self) -> BackendMetrics;

    fn reset_metrics(&self);
}

/// Which backend implementation to construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust batched oracle (default; runs anywhere).
    Native,
    /// PJRT/XLA engine over AOT HLO artifacts (`--features xla`).
    Xla,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }

    pub fn from_name(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "xla" => Some(BackendKind::Xla),
            _ => None,
        }
    }

    /// The `PDFFLOW_BACKEND` environment override, if set. An unset
    /// variable is `Ok(None)`; a set-but-unparseable one is an error.
    pub fn from_env() -> Result<Option<BackendKind>> {
        match std::env::var("PDFFLOW_BACKEND") {
            Ok(s) => Self::from_name(s.trim()).map(Some).ok_or_else(|| {
                PdfflowError::Config(format!(
                    "PDFFLOW_BACKEND={s:?} is not a backend (expected native|xla)"
                ))
            }),
            Err(_) => Ok(None),
        }
    }

    /// The one resolution rule every entry point shares: an explicit
    /// value (CLI flag / API arg) wins and must parse; otherwise the
    /// `PDFFLOW_BACKEND` env applies (and must parse if set); otherwise
    /// native.
    pub fn resolve(explicit: Option<&str>) -> Result<BackendKind> {
        match explicit {
            Some(s) => Self::from_name(s).ok_or_else(|| {
                PdfflowError::Config(format!("unknown backend {s:?} (expected native|xla)"))
            }),
            None => Ok(Self::from_env()?.unwrap_or(BackendKind::Native)),
        }
    }
}

/// Construction knobs shared by every backend.
#[derive(Clone, Debug)]
pub struct BackendOptions {
    /// Points per execution batch (must match an artifact batch for XLA).
    pub batch: usize,
    /// Width cap on the native backend's chunk fan-out: how many slots
    /// of the shared [`HostPool`] budget one batched call may draw. Not
    /// a thread count — all parallelism comes from the one global pool.
    pub workers: usize,
    /// Eq. 5 interval count for the native backend (XLA bakes its own).
    pub bins: usize,
    /// Let the native backend adapt its chunk and fan-out widths from
    /// the pool occupancy meters between calls ([`AdaptiveController`];
    /// `batch`/`workers` become the seed and clamp anchors). Off by
    /// default so directly-constructed backends keep the fixed chunk
    /// geometry their tests pin; the pipeline enables it via
    /// `pipeline.adaptive_batch`.
    pub adaptive: bool,
}

impl Default for BackendOptions {
    fn default() -> Self {
        BackendOptions {
            batch: 256,
            workers: hostpool::default_budget(),
            bins: crate::stats::DEFAULT_BINS,
            adaptive: false,
        }
    }
}

/// Build a backend. `artifacts_dir` is only consulted by the XLA engine;
/// asking for [`BackendKind::Xla`] in a build without the `xla` feature
/// is a configuration error, not a crash.
pub fn make_backend(
    kind: BackendKind,
    artifacts_dir: &str,
    opts: &BackendOptions,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            let mut b = NativeBackend::with_options(opts.workers, opts.batch, opts.bins);
            if opts.adaptive {
                b.enable_adaptive();
            }
            Ok(Box::new(b))
        }
        #[cfg(feature = "xla")]
        BackendKind::Xla => Ok(Box::new(Engine::load_default(artifacts_dir)?)),
        #[cfg(not(feature = "xla"))]
        BackendKind::Xla => Err(PdfflowError::Config(format!(
            "backend 'xla' requested (artifacts at {artifacts_dir:?}) but this build has no \
             XLA support; enable the commented-out `xla` dependency in rust/Cargo.toml (and \
             set the feature to `xla = [\"dep:xla\"]`), run `make artifacts`, then rebuild \
             with `cargo build --features xla` — full walkthrough in rust/README.md"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_names_roundtrip() {
        for k in [BackendKind::Native, BackendKind::Xla] {
            assert_eq!(BackendKind::from_name(k.name()), Some(k));
        }
        assert_eq!(BackendKind::from_name("spark"), None);
    }

    #[test]
    fn out_matrix_rows_and_cols() {
        let m = OutMatrix {
            n_rows: 2,
            n_cols: 3,
            data: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        let col1: Vec<f32> = m.col(1).collect();
        assert_eq!(col1, vec![1.0, 4.0]);
    }

    #[test]
    fn resolve_explicit_wins_and_validates() {
        assert_eq!(
            BackendKind::resolve(Some("native")).unwrap(),
            BackendKind::Native
        );
        assert_eq!(BackendKind::resolve(Some("xla")).unwrap(), BackendKind::Xla);
        assert!(BackendKind::resolve(Some("spark")).is_err());
    }

    #[test]
    fn make_backend_native_always_works() {
        let b = make_backend(BackendKind::Native, "does-not-matter", &BackendOptions::default())
            .unwrap();
        assert_eq!(b.name(), "native");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn make_backend_xla_is_actionable_error_without_feature() {
        let err = make_backend(BackendKind::Xla, "artifacts", &BackendOptions::default())
            .unwrap_err();
        assert!(err.to_string().contains("xla"));
    }
}
