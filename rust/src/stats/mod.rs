//! Pure-rust statistics oracle: moments, histograms, the ten candidate
//! distribution fitters and the Eq. 5 error.
//!
//! This module mirrors `python/compile/distfit.py` exactly (same
//! estimators, same guards, same penalty). It serves three purposes:
//!
//! 1. **cross-check** — integration tests compare the PJRT-executed HLO
//!    artifacts against this implementation;
//! 2. **R-program substitute** — the paper calls an external R process to
//!    fit PDFs; the in-process oracle is our CPU fallback and is used by
//!    the benches' "external program" ablation;
//! 3. **feature extraction** — sampling and the decision tree consume the
//!    same `PointStats` this module computes.

pub mod density;
pub mod simd;
pub mod special;

use special::{betainc, erf, gammainc_p, gammaln};

/// Canonical type order — MUST match `distfit.TYPES` (the type id is the
/// decision-tree label and the `fit_all` output code).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DistType {
    Normal = 0,
    Uniform = 1,
    Exponential = 2,
    Lognormal = 3,
    Cauchy = 4,
    Gamma = 5,
    Geometric = 6,
    Logistic = 7,
    StudentT = 8,
    Weibull = 9,
}

impl DistType {
    pub const ALL: [DistType; 10] = [
        DistType::Normal,
        DistType::Uniform,
        DistType::Exponential,
        DistType::Lognormal,
        DistType::Cauchy,
        DistType::Gamma,
        DistType::Geometric,
        DistType::Logistic,
        DistType::StudentT,
        DistType::Weibull,
    ];

    /// The paper's 4-types candidate set (input-parameter families).
    pub const FOUR: [DistType; 4] = [
        DistType::Normal,
        DistType::Uniform,
        DistType::Exponential,
        DistType::Lognormal,
    ];

    pub fn from_id(id: usize) -> Option<DistType> {
        Self::ALL.get(id).copied()
    }

    pub fn id(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            DistType::Normal => "normal",
            DistType::Uniform => "uniform",
            DistType::Exponential => "exponential",
            DistType::Lognormal => "lognormal",
            DistType::Cauchy => "cauchy",
            DistType::Gamma => "gamma",
            DistType::Geometric => "geometric",
            DistType::Logistic => "logistic",
            DistType::StudentT => "student_t",
            DistType::Weibull => "weibull",
        }
    }

    pub fn from_name(name: &str) -> Option<DistType> {
        Self::ALL.iter().copied().find(|t| t.name() == name)
    }
}

/// Maximum possible Eq. 5 error; also the unsupported-type penalty.
pub const PENALTY_ERROR: f64 = 2.0;
/// Eq. 5 interval count (matches `distfit.DEFAULT_BINS`).
pub const DEFAULT_BINS: usize = 32;

const EPS: f64 = 1e-12;

/// Per-point statistics (the paper's "features": Algorithm 2 computes
/// mean/std at load time; the rest feed the estimators).
#[derive(Clone, Copy, Debug, Default)]
pub struct PointStats {
    pub mean: f64,
    pub std: f64,
    pub var: f64,
    pub min: f64,
    pub max: f64,
    pub skew: f64,
    pub kurt_ex: f64,
    pub meanlog: f64,
    pub stdlog: f64,
    pub q25: f64,
    pub q50: f64,
    pub q75: f64,
    pub pos_frac: f64,
}

impl PointStats {
    /// Compute from one observation vector.
    pub fn of(v: &[f32]) -> PointStats {
        Self::of_converted(v, &mut Vec::new(), &mut Vec::new())
    }

    /// The one accumulation implementation (every caller funnels here,
    /// so backend/oracle bit-parity cannot drift): converts `v` to f64
    /// exactly once into `vals` — left filled so batched callers reuse
    /// it for the histogram pass without re-converting — and uses
    /// `quant` as the quantile-subsample scratch. Both buffers may be
    /// empty `Vec`s; the native backend's inner loop passes per-chunk
    /// scratch so it allocates nothing per point.
    pub fn of_converted(v: &[f32], vals: &mut Vec<f64>, quant: &mut Vec<f64>) -> PointStats {
        let n = v.len();
        assert!(n >= 2, "need at least 2 observations");
        let nf = n as f64;
        // Conversion + min/max go through the SIMD layer (exact f32→f64
        // widening; min/max folding is order-independent, and the AVX2
        // path re-folds the NaN/±0.0 corner cases scalar-exactly). The
        // moment and log-sum accumulators below stay a sequential scalar
        // fold: their values depend on summation order, and the parity
        // contract pins them to these exact bits.
        let (mn, mx) = simd::convert_minmax(v, vals);
        let (mut s1, mut s2, mut s3, mut s4) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut sl, mut sl2) = (0.0f64, 0.0f64);
        let mut npos = 0usize;
        for &x in vals.iter() {
            let x2 = x * x;
            s1 += x;
            s2 += x2;
            s3 += x2 * x;
            s4 += x2 * x2;
            if x > 0.0 {
                let lx = x.ln();
                sl += lx;
                sl2 += lx * lx;
                npos += 1;
            }
        }
        let m1 = s1 / nf;
        let m2 = (s2 / nf - m1 * m1).max(0.0);
        let m3 = s3 / nf - 3.0 * m1 * s2 / nf + 2.0 * m1.powi(3);
        let m4 = s4 / nf - 4.0 * m1 * s3 / nf + 6.0 * m1 * m1 * s2 / nf - 3.0 * m1.powi(4);
        let var = m2 * nf / (nf - 1.0);
        let m2s = m2.max(EPS);
        let meanlog = sl / nf;
        let stdlog = (sl2 / nf - meanlog * meanlog).max(0.0).sqrt();
        // Quantiles via the same strided-subsample estimator the AOT
        // graphs use (distfit.QUANTILE_SUBSAMPLE = 256): observations are
        // i.i.d. across simulations, so the stride is a uniform subsample.
        let stride = n.div_ceil(256);
        quant.clear();
        quant.extend(vals.iter().copied().step_by(stride));
        let sorted = &mut quant[..];
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = sorted.len();
        let pct = |q: f64| -> f64 {
            let pos = q * (m - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        };
        PointStats {
            mean: m1,
            std: var.sqrt(),
            var,
            min: mn,
            max: mx,
            skew: m3 / m2s.powf(1.5),
            kurt_ex: m4 / (m2s * m2s) - 3.0,
            meanlog,
            stdlog,
            q25: pct(0.25),
            q50: pct(0.50),
            q75: pct(0.75),
            pos_frac: npos as f64 / nf,
        }
    }
}

/// A fitted PDF: type, parameters, Eq. 5 error.
#[derive(Clone, Copy, Debug)]
pub struct FitResult {
    pub dist: DistType,
    pub params: [f64; 3],
    pub error: f64,
}

/// Equal-width histogram between min and max (Eq. 5's Freq_k).
pub fn histogram(v: &[f32], mn: f64, mx: f64, bins: usize) -> Vec<f64> {
    let mut h = vec![0.0; bins];
    histogram_into(v, mn, mx, &mut h);
    h
}

/// [`histogram`] into a caller-owned buffer (`out.len()` bins), so the
/// batched backends can reuse one buffer across a whole point batch.
/// The bin index uses a precomputed inverse range (`bins / range`), one
/// multiply per value instead of a divide; [`histogram_f64_into`] MUST
/// use the identical formula, or backend/oracle parity drifts.
///
/// Note (cross-version): this formula replaced `((x-mn)/rng)*bins` in
/// the host-pool/fused-kernel PR — the two round differently for rare
/// exactly-on-boundary values, so fits persisted by older builds may
/// differ by one adjacent-bin reassignment. The contract has always
/// been oracle parity (both sides share this function), not stability
/// of historical bits.
pub fn histogram_into(v: &[f32], mn: f64, mx: f64, out: &mut [f64]) {
    simd::histogram_into(v, mn, mx, out)
}

/// [`histogram_into`] over already-converted f64 observations (the
/// fused backend path reuses the conversion done by
/// [`PointStats::of_converted`]). Formula identical to the f32 version
/// — f32→f64 conversion is exact, so the two are bit-compatible.
pub fn histogram_f64_into(vals: &[f64], mn: f64, mx: f64, out: &mut [f64]) {
    simd::histogram_f64_into(vals, mn, mx, out)
}

/// Fit one type: (params, supported). Mirrors `distfit._FITTERS`.
pub fn fit_params(t: DistType, s: &PointStats) -> ([f64; 3], bool) {
    match t {
        DistType::Normal => ([s.mean, s.std.max(EPS), 0.0], true),
        DistType::Uniform => ([s.min, s.max, 0.0], true),
        DistType::Exponential => ([1.0 / s.mean.max(EPS), 0.0, 0.0], s.min >= 0.0),
        DistType::Lognormal => ([s.meanlog, s.stdlog.max(EPS), 0.0], s.min > 0.0),
        DistType::Cauchy => ([s.q50, ((s.q75 - s.q25) * 0.5).max(EPS), 0.0], true),
        DistType::Gamma => {
            let var = s.var.max(EPS);
            let mean = s.mean.max(EPS);
            let k = (mean * mean / var).clamp(1e-3, 1e6);
            ([k, (var / mean).max(EPS), 0.0], s.min >= 0.0 && s.mean > 0.0)
        }
        DistType::Geometric => ([1.0 / (1.0 + s.mean).max(1.0 + EPS), 0.0, 0.0], s.min >= 0.0),
        DistType::Logistic => (
            [s.mean, (s.std * 3f64.sqrt() / std::f64::consts::PI).max(EPS), 0.0],
            true,
        ),
        DistType::StudentT => {
            let nu = (4.0 + 6.0 / s.kurt_ex.max(0.03)).clamp(2.1, 200.0);
            let scale = (s.var * (nu - 2.0) / nu).max(EPS).sqrt();
            ([s.mean, scale, nu], true)
        }
        DistType::Weibull => {
            let mean = s.mean.max(EPS);
            let cv = s.std.max(EPS) / mean;
            let k = cv.powf(-1.086).clamp(0.05, 50.0);
            let lam = mean / (gammaln(1.0 + 1.0 / k)).exp();
            ([k, lam.max(EPS), 0.0], s.min >= 0.0)
        }
    }
}

/// CDF of a fitted type at x. Mirrors the python `_cdf_*` functions.
pub fn cdf(t: DistType, p: &[f64; 3], x: f64) -> f64 {
    match t {
        DistType::Normal => 0.5 * (1.0 + erf((x - p[0]) / (p[1] * 2f64.sqrt() + EPS))),
        DistType::Uniform => ((x - p[0]) / (p[1] - p[0]).max(EPS)).clamp(0.0, 1.0),
        DistType::Exponential => {
            if x < 0.0 {
                0.0
            } else {
                1.0 - (-p[0] * x).exp()
            }
        }
        DistType::Lognormal => {
            if x <= 0.0 {
                0.0
            } else {
                0.5 * (1.0 + erf((x.max(EPS).ln() - p[0]) / (p[1] * 2f64.sqrt() + EPS)))
            }
        }
        DistType::Cauchy => ((x - p[0]) / p[1]).atan() / std::f64::consts::PI + 0.5,
        DistType::Gamma => gammainc_p(p[0], x.max(0.0) / p[1]),
        DistType::Geometric => {
            if x < 0.0 {
                0.0
            } else {
                let prob = p[0].clamp(EPS, 1.0 - EPS);
                1.0 - ((x.max(-1.0).floor() + 1.0) * (1.0 - prob).ln()).exp()
            }
        }
        DistType::Logistic => 1.0 / (1.0 + (-(x - p[0]) / p[1]).exp()),
        DistType::StudentT => {
            let z = (x - p[0]) / p[1];
            let nu = p[2];
            let w = nu / (nu + z * z);
            let tail = 0.5 * betainc(nu * 0.5, 0.5, w);
            if z < 0.0 {
                tail
            } else {
                1.0 - tail
            }
        }
        DistType::Weibull => 1.0 - (-(x.max(0.0) / p[1]).powf(p[0])).exp(),
    }
}

/// Fill `edges` (one per histogram bin) with the upper Eq. 5 interval
/// boundaries over [mn, mx]. Edges depend only on the point's range, so
/// the fused backend computes them once per point and shares them
/// across every candidate type instead of recomputing `bins` edges per
/// candidate — the formula matches the historical per-candidate one
/// exactly, so hoisting is bit-neutral.
pub fn fill_edges(mn: f64, mx: f64, edges: &mut [f64]) {
    simd::fill_edges(mn, mx, edges)
}

/// Eq. 5: histogram-vs-CDF discrepancy over `bins` equal intervals.
pub fn eq5_error(t: DistType, p: &[f64; 3], hist: &[f64], mn: f64, mx: f64, n_obs: usize) -> f64 {
    let mut edges = vec![0.0; hist.len()];
    fill_edges(mn, mx, &mut edges);
    eq5_error_with_edges(t, p, hist, &edges, mn, n_obs)
}

/// [`eq5_error`] with caller-precomputed interval edges (the no-alloc
/// hot path; `edges` comes from [`fill_edges`] over the same [mn, mx]).
pub fn eq5_error_with_edges(
    t: DistType,
    p: &[f64; 3],
    hist: &[f64],
    edges: &[f64],
    mn: f64,
    n_obs: usize,
) -> f64 {
    let mut err = 0.0;
    let mut prev = cdf(t, p, mn);
    for (h, &edge) in hist.iter().zip(edges) {
        let cur = cdf(t, p, edge);
        err += (h / n_obs as f64 - (cur - prev)).abs();
        prev = cur;
    }
    err
}

/// [`eq5_error_with_edges`] over an already-normalized histogram
/// (`hist_norm[k] = hist[k] / n_obs`). Bit-identical to the unnormalized
/// form — same dividends, same divisor, same fold order — but lets
/// [`fit_best_prepared`] pay the `bins` divisions once per point instead
/// of once per candidate type.
pub fn eq5_error_prenorm_with_edges(
    t: DistType,
    p: &[f64; 3],
    hist_norm: &[f64],
    edges: &[f64],
    mn: f64,
) -> f64 {
    let mut err = 0.0;
    let mut prev = cdf(t, p, mn);
    for (&hn, &edge) in hist_norm.iter().zip(edges) {
        let cur = cdf(t, p, edge);
        err += (hn - (cur - prev)).abs();
        prev = cur;
    }
    err
}

/// Fit one type on an observation vector (Algorithm 3 body for one type).
pub fn fit_single(v: &[f32], t: DistType, bins: usize) -> FitResult {
    let s = PointStats::of(v);
    fit_single_with_stats(v, &s, t, bins)
}

/// Same but with precomputed stats (avoids recomputing shared moments).
pub fn fit_single_with_stats(v: &[f32], s: &PointStats, t: DistType, bins: usize) -> FitResult {
    let mut hist = vec![0.0; bins];
    fit_single_with_hist(v, s, t, &mut hist)
}

/// Single-type fit body with caller-owned stats + histogram buffer (the
/// compat no-allocation path). `hist` is filled — only when the type's
/// support guard passes — with `hist.len()` Eq. 5 intervals.
pub fn fit_single_with_hist(
    v: &[f32],
    s: &PointStats,
    t: DistType,
    hist: &mut [f64],
) -> FitResult {
    let (params, supported) = fit_params(t, s);
    let error = if supported {
        histogram_into(v, s.min, s.max, hist);
        eq5_error(t, &params, hist, s.min, s.max, v.len())
    } else {
        PENALTY_ERROR
    };
    FitResult {
        dist: t,
        params,
        error,
    }
}

/// Fully fused single-type fit over a prepared point: pre-converted f64
/// observations (from [`PointStats::of_converted`]) plus caller scratch
/// histogram/edges buffers, filled only when the support guard passes.
/// Zero allocation, one conversion pass — the batched backend's path.
pub fn fit_single_prepared(
    vals: &[f64],
    s: &PointStats,
    t: DistType,
    hist: &mut [f64],
    edges: &mut [f64],
) -> FitResult {
    let (params, supported) = fit_params(t, s);
    let error = if supported {
        histogram_f64_into(vals, s.min, s.max, hist);
        fill_edges(s.min, s.max, edges);
        eq5_error_with_edges(t, &params, hist, edges, s.min, vals.len())
    } else {
        PENALTY_ERROR
    };
    FitResult {
        dist: t,
        params,
        error,
    }
}

/// Algorithm 3: fit every candidate type, keep the minimum-error PDF.
pub fn fit_best(v: &[f32], candidates: &[DistType], bins: usize) -> FitResult {
    let s = PointStats::of(v);
    let hist = histogram(v, s.min, s.max, bins);
    fit_best_with_hist(&s, &hist, v.len(), candidates)
}

/// Algorithm 3 argmin over precomputed stats + histogram (computes the
/// Eq. 5 edges once, then delegates to [`fit_best_prepared`]).
pub fn fit_best_with_hist(
    s: &PointStats,
    hist: &[f64],
    n_obs: usize,
    candidates: &[DistType],
) -> FitResult {
    let mut edges = vec![0.0; hist.len()];
    fill_edges(s.min, s.max, &mut edges);
    fit_best_prepared(s, hist, &edges, n_obs, candidates)
}

/// Algorithm 3 argmin body over precomputed stats + histogram + interval
/// edges — THE definition of the fit semantics (support guard → penalty,
/// Eq. 5 otherwise, first minimum wins). Every backend funnels through
/// this so the 1e-5 parity contract cannot drift; the edges are hoisted
/// out of the candidate loop (they depend only on the point's range).
pub fn fit_best_prepared(
    s: &PointStats,
    hist: &[f64],
    edges: &[f64],
    n_obs: usize,
    candidates: &[DistType],
) -> FitResult {
    // Normalize the histogram once and share it across every candidate
    // (bins divisions per point instead of bins × candidates) — the
    // quotients are the exact values the per-candidate loop would have
    // computed, so the Eq. 5 fold sees identical bits. Common bin
    // counts fit in a stack buffer; oversized configs take a heap copy.
    const STACK_BINS: usize = 64;
    let nf = n_obs as f64;
    let mut stack = [0.0f64; STACK_BINS];
    let mut heap = Vec::new();
    let hnorm: &[f64] = if hist.len() <= STACK_BINS {
        for (d, &h) in stack.iter_mut().zip(hist) {
            *d = h / nf;
        }
        &stack[..hist.len()]
    } else {
        heap.extend(hist.iter().map(|&h| h / nf));
        &heap
    };
    let mut best: Option<FitResult> = None;
    for &t in candidates {
        let (params, supported) = fit_params(t, s);
        let error = if supported {
            eq5_error_prenorm_with_edges(t, &params, hnorm, edges, s.min)
        } else {
            PENALTY_ERROR
        };
        let r = FitResult {
            dist: t,
            params,
            error,
        };
        if best.map_or(true, |b| r.error < b.error) {
            best = Some(r);
        }
    }
    best.expect("non-empty candidate set")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn draws(f: impl Fn(&mut Rng) -> f64, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| f(&mut rng) as f32).collect()
    }

    #[test]
    fn point_stats_basics() {
        let v: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let s = PointStats::of(&v);
        assert!((s.mean - 3.0).abs() < 1e-6);
        assert!((s.std - 1.5811388).abs() < 1e-5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.q50 - 3.0).abs() < 1e-6);
        assert_eq!(s.pos_frac, 1.0);
    }

    #[test]
    fn histogram_total_and_edges() {
        let v: Vec<f32> = vec![0.0, 0.1, 0.5, 0.99, 1.0];
        let h = histogram(&v, 0.0, 1.0, 4);
        assert_eq!(h.iter().sum::<f64>(), 5.0);
        assert_eq!(h[3], 2.0); // 0.99 and the max fall into the last bin
    }

    #[test]
    fn normal_fit_recovers_params() {
        let v = draws(|r| r.normal(10.0, 3.0), 4000, 1);
        let f = fit_single(&v, DistType::Normal, DEFAULT_BINS);
        assert!((f.params[0] - 10.0).abs() < 0.2, "{:?}", f.params);
        assert!((f.params[1] - 3.0).abs() < 0.2);
        assert!(f.error < 0.2, "error {}", f.error);
    }

    #[test]
    fn each_family_wins_its_own_data_10types() {
        // On clean big samples, the generating family should win (or tie
        // against a nesting family) in fit_best over all 10 types.
        let cases: Vec<(DistType, Vec<f32>)> = vec![
            (DistType::Uniform, draws(|r| r.uniform(2.0, 8.0), 4000, 2)),
            (DistType::Exponential, draws(|r| r.exponential(0.5), 4000, 3)),
            (DistType::Lognormal, draws(|r| r.lognormal(1.0, 0.6), 4000, 4)),
            (DistType::Gamma, draws(|r| r.gamma(3.0, 2.0), 4000, 6)),
        ];
        for (want, v) in cases {
            let best = fit_best(&v, &DistType::ALL, DEFAULT_BINS);
            let own = fit_single(&v, want, DEFAULT_BINS);
            // The winner must not beat the true family by much.
            assert!(
                own.error <= best.error + 0.05,
                "{want:?}: own {} vs best {:?} {}",
                own.error,
                best.dist,
                best.error
            );
        }
    }

    #[test]
    fn fit_best_is_min_over_singles() {
        let v = draws(|r| r.gamma(2.0, 1.5), 2000, 7);
        let best = fit_best(&v, &DistType::ALL, DEFAULT_BINS);
        for &t in &DistType::ALL {
            let f = fit_single(&v, t, DEFAULT_BINS);
            assert!(best.error <= f.error + 1e-12, "{t:?}");
        }
    }

    #[test]
    fn support_guards_penalize() {
        let v = draws(|r| r.normal(-50.0, 1.0), 500, 8);
        for t in [
            DistType::Exponential,
            DistType::Lognormal,
            DistType::Gamma,
            DistType::Geometric,
            DistType::Weibull,
        ] {
            assert_eq!(fit_single(&v, t, DEFAULT_BINS).error, PENALTY_ERROR, "{t:?}");
        }
        // But normal/logistic/cauchy/student/uniform still fit.
        assert!(fit_single(&v, DistType::Normal, DEFAULT_BINS).error < 0.5);
    }

    #[test]
    fn errors_bounded() {
        let v = draws(|r| r.std_normal(), 300, 9);
        for &t in &DistType::ALL {
            let e = fit_single(&v, t, DEFAULT_BINS).error;
            assert!((0.0..=PENALTY_ERROR).contains(&e), "{t:?} -> {e}");
        }
    }

    #[test]
    fn cdfs_are_monotone_and_bounded() {
        let v = draws(|r| r.gamma(2.0, 2.0), 1000, 10);
        let s = PointStats::of(&v);
        for &t in &DistType::ALL {
            let (p, ok) = fit_params(t, &s);
            if !ok {
                continue;
            }
            let mut prev = -1e-9;
            for i in 0..=50 {
                let x = s.min + (s.max - s.min) * i as f64 / 50.0;
                let c = cdf(t, &p, x);
                assert!((0.0..=1.0 + 1e-9).contains(&c), "{t:?} cdf({x})={c}");
                assert!(c >= prev - 1e-9, "{t:?} not monotone at {x}");
                prev = c;
            }
        }
    }

    #[test]
    fn ten_types_never_worse_than_four() {
        let v = draws(|r| r.student_t(5.0), 2000, 11);
        let e4 = fit_best(&v, &DistType::FOUR, DEFAULT_BINS).error;
        let e10 = fit_best(&v, &DistType::ALL, DEFAULT_BINS).error;
        assert!(e10 <= e4 + 1e-12);
    }

    #[test]
    fn type_ids_match_canonical_order() {
        assert_eq!(DistType::Normal.id(), 0);
        assert_eq!(DistType::Weibull.id(), 9);
        for (i, t) in DistType::ALL.iter().enumerate() {
            assert_eq!(t.id(), i);
            assert_eq!(DistType::from_id(i), Some(*t));
            assert_eq!(DistType::from_name(t.name()), Some(*t));
        }
        assert_eq!(DistType::from_id(10), None);
        assert_eq!(DistType::from_name("bogus"), None);
    }

    #[test]
    fn prepared_paths_are_bit_identical_to_compat_paths() {
        // The fused backend path (of_converted + histogram_f64_into +
        // fill_edges + *_prepared) must be bitwise equal to the compat
        // oracle path — this is the kernel-parity contract.
        let v = draws(|r| r.gamma(2.5, 1.5), 1500, 21);
        let mut vals = Vec::new();
        let mut quant = Vec::new();
        let s = PointStats::of_converted(&v, &mut vals, &mut quant);
        let s0 = PointStats::of(&v);
        assert_eq!(s.mean.to_bits(), s0.mean.to_bits());
        assert_eq!(s.skew.to_bits(), s0.skew.to_bits());
        assert_eq!(s.q50.to_bits(), s0.q50.to_bits());
        let mut h32 = vec![0.0; DEFAULT_BINS];
        let mut h64 = vec![0.0; DEFAULT_BINS];
        histogram_into(&v, s.min, s.max, &mut h32);
        histogram_f64_into(&vals, s.min, s.max, &mut h64);
        assert_eq!(h32, h64);
        let mut edges = vec![0.0; DEFAULT_BINS];
        fill_edges(s.min, s.max, &mut edges);
        for &t in &DistType::ALL {
            let a = fit_single_with_hist(&v, &s, t, &mut vec![0.0; DEFAULT_BINS]);
            let b = fit_single_prepared(&vals, &s, t, &mut h64, &mut edges);
            assert_eq!(a.error.to_bits(), b.error.to_bits(), "{t:?} error");
            for c in 0..3 {
                assert_eq!(a.params[c].to_bits(), b.params[c].to_bits(), "{t:?} p{c}");
            }
        }
        histogram_f64_into(&vals, s.min, s.max, &mut h64);
        let best_a = fit_best_with_hist(&s, &h32, v.len(), &DistType::ALL);
        let best_b = fit_best_prepared(&s, &h64, &edges, v.len(), &DistType::ALL);
        assert_eq!(best_a.dist, best_b.dist);
        assert_eq!(best_a.error.to_bits(), best_b.error.to_bits());
    }

    #[test]
    fn prenormalized_eq5_is_bit_identical() {
        // fit_best_prepared divides the histogram by n_obs once and
        // shares the quotients across candidates; the fold must see the
        // exact bits the per-candidate division produced.
        let v = draws(|r| r.lognormal(0.5, 0.8), 900, 22);
        let mut vals = Vec::new();
        let mut quant = Vec::new();
        let s = PointStats::of_converted(&v, &mut vals, &mut quant);
        let mut hist = vec![0.0; DEFAULT_BINS];
        histogram_f64_into(&vals, s.min, s.max, &mut hist);
        let mut edges = vec![0.0; DEFAULT_BINS];
        fill_edges(s.min, s.max, &mut edges);
        let hnorm: Vec<f64> = hist.iter().map(|&h| h / v.len() as f64).collect();
        for &t in &DistType::ALL {
            let (p, ok) = fit_params(t, &s);
            if !ok {
                continue;
            }
            let a = eq5_error_with_edges(t, &p, &hist, &edges, s.min, v.len());
            let b = eq5_error_prenorm_with_edges(t, &p, &hnorm, &edges, s.min);
            assert_eq!(a.to_bits(), b.to_bits(), "{t:?}");
        }
    }

    #[test]
    fn geometric_on_integer_data() {
        let v = draws(|r| r.geometric(0.4), 3000, 12);
        let f = fit_single(&v, DistType::Geometric, DEFAULT_BINS);
        assert!((f.params[0] - 0.4).abs() < 0.05, "{:?}", f.params);
    }
}
