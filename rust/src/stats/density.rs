//! PDF evaluation, modes and QOI extraction (paper §1).
//!
//! The paper's motivation for fitting PDFs at all: "once we have the PDF
//! of a point, we can calculate the QOI value that has the highest
//! possibility, with which we can compute the imprecision of each spatial
//! data set". This module evaluates the fitted densities, extracts the
//! mode (the maximum-likelihood QOI — e.g. 0 for an exponential PDF, the
//! mean for a normal one, exactly the §1 discussion) and produces the
//! per-point uncertainty summary the downstream geophysicist consumes.

use crate::stats::special::gammaln;
use crate::stats::{DistType, FitResult};

const EPS: f64 = 1e-300;

/// Probability density of a fitted type at x. Mirrors the CDFs in
/// `stats::cdf` (same parametrization).
pub fn pdf(t: DistType, p: &[f64; 3], x: f64) -> f64 {
    match t {
        DistType::Normal => {
            let z = (x - p[0]) / p[1];
            (-0.5 * z * z).exp() / (p[1] * (2.0 * std::f64::consts::PI).sqrt())
        }
        DistType::Uniform => {
            if x >= p[0] && x <= p[1] {
                1.0 / (p[1] - p[0]).max(EPS)
            } else {
                0.0
            }
        }
        DistType::Exponential => {
            if x < 0.0 {
                0.0
            } else {
                p[0] * (-p[0] * x).exp()
            }
        }
        DistType::Lognormal => {
            if x <= 0.0 {
                0.0
            } else {
                let z = (x.ln() - p[0]) / p[1];
                (-0.5 * z * z).exp()
                    / (x * p[1] * (2.0 * std::f64::consts::PI).sqrt())
            }
        }
        DistType::Cauchy => {
            let z = (x - p[0]) / p[1];
            1.0 / (std::f64::consts::PI * p[1] * (1.0 + z * z))
        }
        DistType::Gamma => {
            if x < 0.0 {
                return 0.0;
            }
            let (k, theta) = (p[0], p[1]);
            let lx = x.max(EPS);
            ((k - 1.0) * lx.ln() - lx / theta - k * theta.ln() - gammaln(k)).exp()
        }
        DistType::Geometric => {
            // Probability mass at floor(x) spread over the unit interval.
            if x < 0.0 {
                0.0
            } else {
                let prob = p[0].clamp(EPS, 1.0 - EPS);
                let k = x.floor();
                prob * (k * (1.0 - prob).ln()).exp()
            }
        }
        DistType::Logistic => {
            let z = (x - p[0]) / p[1];
            let e = (-z).exp();
            e / (p[1] * (1.0 + e) * (1.0 + e))
        }
        DistType::StudentT => {
            let (loc, scale, nu) = (p[0], p[1], p[2]);
            let z = (x - loc) / scale;
            let ln_c = gammaln((nu + 1.0) / 2.0)
                - gammaln(nu / 2.0)
                - 0.5 * (nu * std::f64::consts::PI).ln()
                - scale.ln();
            (ln_c - (nu + 1.0) / 2.0 * (1.0 + z * z / nu).ln()).exp()
        }
        DistType::Weibull => {
            if x < 0.0 {
                return 0.0;
            }
            let (k, lam) = (p[0], p[1]);
            let z = (x.max(EPS) / lam).powf(k);
            (k / lam) * (x.max(EPS) / lam).powf(k - 1.0) * (-z).exp()
        }
    }
}

/// Mode of a fitted PDF — the paper's maximum-possibility QOI value
/// (§1: "we should take the value zero as the QOI value" for an
/// exponential PDF). Closed-form for every candidate type.
pub fn mode(t: DistType, p: &[f64; 3]) -> f64 {
    match t {
        DistType::Normal | DistType::Cauchy | DistType::Logistic => p[0],
        DistType::StudentT => p[0],
        DistType::Uniform => 0.5 * (p[0] + p[1]), // any interior point; midpoint
        DistType::Exponential => 0.0,
        DistType::Geometric => 0.0,
        DistType::Lognormal => (p[0] - p[1] * p[1]).exp(),
        DistType::Gamma => {
            let (k, theta) = (p[0], p[1]);
            if k >= 1.0 {
                (k - 1.0) * theta
            } else {
                0.0
            }
        }
        DistType::Weibull => {
            let (k, lam) = (p[0], p[1]);
            if k > 1.0 {
                lam * ((k - 1.0) / k).powf(1.0 / k)
            } else {
                0.0
            }
        }
    }
}

/// Quantile (inverse CDF) of a fitted type: the value x with
/// `cdf(t, p, x) = q`. Used by the store's analytical queries ("give me
/// the median / P90 velocity of this region"). Solved by bracketed
/// bisection on the monotone CDF — closed forms exist for some families
/// but one numeric path keeps every type consistent with `stats::cdf`.
/// For the discrete Geometric family this converges to the CDF jump
/// point containing q.
pub fn quantile(t: DistType, p: &[f64; 3], q: f64) -> f64 {
    let q = q.clamp(1e-12, 1.0 - 1e-12);
    let center = mode(t, p);
    // A positive length scale for the initial bracket, per family.
    let scale = match t {
        DistType::Uniform => (p[1] - p[0]).abs(),
        DistType::Exponential | DistType::Geometric => 1.0 / p[0].abs().max(1e-12),
        DistType::Gamma => (p[0] * p[1]).abs(),
        DistType::Weibull => p[1].abs(),
        DistType::Lognormal => (p[0].exp() * p[1].max(0.1)).abs(),
        _ => p[1].abs(),
    }
    .max(1e-12);
    let (mut lo, mut hi) = (center, center);
    let mut step = scale;
    while crate::stats::cdf(t, p, lo) > q && step < 1e18 {
        lo -= step;
        step *= 2.0;
    }
    step = scale;
    while crate::stats::cdf(t, p, hi) < q && step < 1e18 {
        hi += step;
        step *= 2.0;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi {
            break; // float resolution reached
        }
        if crate::stats::cdf(t, p, mid) < q {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Per-point uncertainty summary (the paper's §1 deliverable).
#[derive(Clone, Copy, Debug)]
pub struct Qoi {
    pub dist: DistType,
    /// Maximum-possibility value (PDF mode).
    pub value: f64,
    /// Density at the mode (peakedness; higher = more certain).
    pub peak_density: f64,
    /// Eq.5 fit error — how much to trust the PDF itself.
    pub fit_error: f64,
}

/// Extract the QOI from a fit result.
pub fn qoi(fit: &FitResult) -> Qoi {
    let value = mode(fit.dist, &fit.params);
    Qoi {
        dist: fit.dist,
        value,
        peak_density: pdf(fit.dist, &fit.params, value),
        fit_error: fit.error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{cdf, fit_params, fit_single, PointStats, DEFAULT_BINS};
    use crate::util::prng::Rng;

    fn params_for(t: DistType, data: &[f32]) -> [f64; 3] {
        let s = PointStats::of(data);
        fit_params(t, &s).0
    }

    #[test]
    fn pdf_integrates_to_cdf_increments() {
        // Trapezoid integral of pdf over [a, b] must match CDF(b)-CDF(a)
        // for every continuous type.
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..4000).map(|_| rng.gamma(3.0, 2.0) as f32).collect();
        for &t in &DistType::ALL {
            if t == DistType::Geometric {
                continue; // discrete: density is a PMF spread, skip
            }
            let p = params_for(t, &data);
            let (a, b) = (1.0f64, 9.0f64);
            let n = 4000;
            let mut integral = 0.0;
            for i in 0..n {
                let x0 = a + (b - a) * i as f64 / n as f64;
                let x1 = a + (b - a) * (i + 1) as f64 / n as f64;
                integral += 0.5 * (pdf(t, &p, x0) + pdf(t, &p, x1)) * (x1 - x0);
            }
            let want = cdf(t, &p, b) - cdf(t, &p, a);
            assert!(
                (integral - want).abs() < 5e-3,
                "{t:?}: integral {integral} vs cdf diff {want}"
            );
        }
    }

    #[test]
    fn modes_are_argmax_of_pdf() {
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..4000).map(|_| rng.gamma(4.0, 1.5) as f32).collect();
        for &t in &DistType::ALL {
            if matches!(t, DistType::Uniform | DistType::Geometric) {
                continue; // flat / discrete
            }
            let p = params_for(t, &data);
            let m = mode(t, &p);
            let pm = pdf(t, &p, m);
            // Sample the density widely; nothing may beat the mode by more
            // than float slack.
            for i in 0..200 {
                let x = m - 10.0 + 0.1 * i as f64;
                assert!(
                    pdf(t, &p, x) <= pm + 1e-9,
                    "{t:?}: pdf({x}) = {} > pdf(mode {m}) = {pm}",
                    pdf(t, &p, x)
                );
            }
        }
    }

    #[test]
    fn exponential_qoi_is_zero() {
        // The paper's §1 example: exponential data's most likely value is 0.
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..2000).map(|_| rng.exponential(0.5) as f32).collect();
        let fit = fit_single(&data, DistType::Exponential, DEFAULT_BINS);
        let q = qoi(&fit);
        assert_eq!(q.value, 0.0);
        assert!(q.peak_density > 0.0);
    }

    #[test]
    fn normal_qoi_is_mean() {
        let mut rng = Rng::new(4);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal(7.0, 1.0) as f32).collect();
        let fit = fit_single(&data, DistType::Normal, DEFAULT_BINS);
        let q = qoi(&fit);
        assert!((q.value - 7.0).abs() < 0.2, "mode {}", q.value);
    }

    #[test]
    fn lognormal_mode_below_mean() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..4000).map(|_| rng.lognormal(1.0, 0.6) as f32).collect();
        let fit = fit_single(&data, DistType::Lognormal, DEFAULT_BINS);
        let q = qoi(&fit);
        let mean = PointStats::of(&data).mean;
        assert!(q.value < mean, "mode {} !< mean {mean}", q.value);
        assert!(q.value > 0.0);
    }

    #[test]
    fn quantile_inverts_cdf_for_every_continuous_type() {
        let mut rng = Rng::new(11);
        let data: Vec<f32> = (0..4000).map(|_| rng.gamma(3.0, 2.0) as f32).collect();
        let s = PointStats::of(&data);
        for &t in &DistType::ALL {
            if t == DistType::Geometric {
                continue; // discrete: CDF jumps, inverse is a step edge
            }
            let (p, ok) = fit_params(t, &s);
            if !ok {
                continue;
            }
            for q in [0.05, 0.25, 0.5, 0.75, 0.95] {
                let x = quantile(t, &p, q);
                let back = cdf(t, &p, x);
                assert!(
                    (back - q).abs() < 1e-6,
                    "{t:?}: cdf(quantile({q})) = {back}"
                );
            }
        }
    }

    #[test]
    fn quantile_known_values() {
        // Standard normal: median 0, P84 ≈ +1σ.
        let p = [0.0, 1.0, 0.0];
        assert!(quantile(DistType::Normal, &p, 0.5).abs() < 1e-9);
        assert!((quantile(DistType::Normal, &p, 0.8413447) - 1.0).abs() < 1e-4);
        // Uniform [2, 8]: P25 = 3.5.
        let u = [2.0, 8.0, 0.0];
        assert!((quantile(DistType::Uniform, &u, 0.25) - 3.5).abs() < 1e-9);
        // Exponential λ=0.5: median = ln(2)/λ.
        let e = [0.5, 0.0, 0.0];
        assert!((quantile(DistType::Exponential, &e, 0.5) - 2.0 * 2f64.ln()).abs() < 1e-9);
        // Quantiles are monotone in q.
        let g = [3.0, 2.0, 0.0];
        let (a, b, c) = (
            quantile(DistType::Gamma, &g, 0.1),
            quantile(DistType::Gamma, &g, 0.5),
            quantile(DistType::Gamma, &g, 0.9),
        );
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn peak_density_reflects_certainty() {
        let mut rng = Rng::new(6);
        let tight: Vec<f32> = (0..2000).map(|_| rng.normal(5.0, 0.5) as f32).collect();
        let wide: Vec<f32> = (0..2000).map(|_| rng.normal(5.0, 5.0) as f32).collect();
        let qt = qoi(&fit_single(&tight, DistType::Normal, DEFAULT_BINS));
        let qw = qoi(&fit_single(&wide, DistType::Normal, DEFAULT_BINS));
        assert!(qt.peak_density > 5.0 * qw.peak_density);
    }
}
