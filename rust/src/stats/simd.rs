//! Explicit-width SIMD kernels for the per-point fit hot loops, with an
//! always-available scalar fallback and runtime AVX2 dispatch.
//!
//! **Tolerance policy: zero.** Every routine here is pinned bit-identical
//! to the scalar oracle in `stats` — the backend-parity and
//! thread-invariance suites compare reports with `to_bits`, and persisted
//! segments are checksummed, so a lane-reassociated float is a
//! correctness bug, not a rounding footnote. That constraint decides
//! what gets vectorized:
//!
//! - **f32→f64 conversion** (`convert_minmax`): `vcvtps2pd` is exact.
//! - **min/max reduction**: associative and commutative for ordinary
//!   values, so lane folding is bit-neutral; the two cases where
//!   `vminpd`/`vmaxpd` diverge from Rust's `f64::min`/`max` (NaN
//!   operands, ±0.0 ties) are detected and re-folded with the exact
//!   scalar sequence — see `convert_minmax` below.
//! - **histogram bucket fill** (`histogram_into`/`histogram_f64_into`):
//!   the bin index is a pure elementwise expression and the `+1.0`
//!   count increments are exact small integers, order-independent.
//! - **Eq. 5 interval edges** (`fill_edges`): pure elementwise.
//!
//! The loops that stay scalar stay for a reason: the moment
//! accumulators (`s1..s4`, log sums) and the Eq. 5 error fold are
//! sequential sums whose value depends on evaluation order, and the
//! candidate CDFs call special functions (`erf`, `betainc`,
//! `gammainc_p`) with data-dependent branches. Vectorizing those means
//! reassociating, and reassociating means new bits. The fused fit path
//! instead buys its Eq. 5 win allocation-free: `fit_best_prepared`
//! normalizes the histogram once per point and shares it across all
//! candidates (bit-identical — same dividends, divisor, and fold order).
//!
//! Dispatch is controlled by `PDFFLOW_SIMD`:
//!
//! - `off` / `0` — never dispatch (alias of `scalar`; both run the
//!   canonical loops).
//! - `scalar` — force the scalar fallback even where AVX2 is available.
//! - `auto` (default, also any unrecognized value) — use AVX2 when the
//!   CPU reports it, scalar otherwise.
//!
//! Tests flip the mode programmatically with [`set_mode`]; because the
//! two paths are bit-identical, a concurrent test observing a mid-flight
//! mode change can not observe different results.

use std::sync::atomic::{AtomicU8, Ordering};

/// Kernel dispatch mode (see module docs for the `PDFFLOW_SIMD` values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Never dispatch to vector kernels (functionally identical to
    /// `Scalar`; kept distinct so the knob surface reads naturally).
    Off,
    /// Force the scalar fallback loops.
    Scalar,
    /// Runtime-dispatch: AVX2 where the CPU has it, scalar otherwise.
    Auto,
}

const UNRESOLVED: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_SCALAR: u8 = 2;
const MODE_AUTO: u8 = 3;

static MODE: AtomicU8 = AtomicU8::new(UNRESOLVED);

/// Current dispatch mode; resolves `PDFFLOW_SIMD` on first use.
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_OFF => SimdMode::Off,
        MODE_SCALAR => SimdMode::Scalar,
        MODE_AUTO => SimdMode::Auto,
        _ => {
            let env = std::env::var("PDFFLOW_SIMD")
                .map(|s| s.to_ascii_lowercase())
                .unwrap_or_default();
            let m = match env.as_str() {
                "off" | "0" => SimdMode::Off,
                "scalar" => SimdMode::Scalar,
                _ => SimdMode::Auto,
            };
            set_mode(m);
            m
        }
    }
}

/// Override the dispatch mode (tests use this for scalar-vs-SIMD
/// differential passes; safe because both paths are bit-identical).
pub fn set_mode(m: SimdMode) {
    let v = match m {
        SimdMode::Off => MODE_OFF,
        SimdMode::Scalar => MODE_SCALAR,
        SimdMode::Auto => MODE_AUTO,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// True when the AVX2 kernels are actually in use (mode is `Auto` and
/// the CPU reports the feature).
pub fn active() -> bool {
    mode() == SimdMode::Auto && avx2_available()
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// Histogram bin counts above this fall back to scalar so the f64→i32
/// index conversion can never leave i32 range. Real configs use 16–256
/// bins; this is a safety rail, not a tuning knob.
const MAX_SIMD_BINS: usize = 1 << 30;

/// Convert `v` to f64 into `vals` (cleared first) and return the
/// `(min, max)` of the converted values, bit-identical to the scalar
/// sequential fold `mn.min(x)` / `mx.max(x)` from `±INFINITY` seeds.
pub fn convert_minmax(v: &[f32], vals: &mut Vec<f64>) -> (f64, f64) {
    #[cfg(target_arch = "x86_64")]
    if active() && v.len() >= 8 {
        // SAFETY: dispatch is gated on runtime AVX2 detection.
        return unsafe { avx2::convert_minmax(v, vals) };
    }
    scalar::convert_minmax(v, vals)
}

/// Equal-width histogram fill over f32 observations (canonical formula
/// lives in [`scalar::histogram_into`]; AVX2 path is bit-identical).
pub fn histogram_into(v: &[f32], mn: f64, mx: f64, out: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if active() && v.len() >= 8 && !out.is_empty() && out.len() <= MAX_SIMD_BINS {
        // SAFETY: dispatch is gated on runtime AVX2 detection.
        unsafe { avx2::histogram_into(v, mn, mx, out) };
        return;
    }
    scalar::histogram_into(v, mn, mx, out)
}

/// [`histogram_into`] over already-converted f64 observations.
pub fn histogram_f64_into(vals: &[f64], mn: f64, mx: f64, out: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if active() && vals.len() >= 8 && !out.is_empty() && out.len() <= MAX_SIMD_BINS {
        // SAFETY: dispatch is gated on runtime AVX2 detection.
        unsafe { avx2::histogram_f64_into(vals, mn, mx, out) };
        return;
    }
    scalar::histogram_f64_into(vals, mn, mx, out)
}

/// Fill the Eq. 5 upper interval edges over `[mn, mx]`.
pub fn fill_edges(mn: f64, mx: f64, edges: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if active() && edges.len() >= 8 {
        // SAFETY: dispatch is gated on runtime AVX2 detection.
        unsafe { avx2::fill_edges(mn, mx, edges) };
        return;
    }
    scalar::fill_edges(mn, mx, edges)
}

/// The canonical scalar loops. These bodies ARE the semantics — the
/// AVX2 module reproduces them bit-for-bit, and `stats` delegates its
/// public functions here so there is exactly one scalar definition.
mod scalar {
    pub fn convert_minmax(v: &[f32], vals: &mut Vec<f64>) -> (f64, f64) {
        vals.clear();
        vals.extend(v.iter().map(|&x| x as f64));
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in vals.iter() {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        (mn, mx)
    }

    pub fn histogram_into(v: &[f32], mn: f64, mx: f64, out: &mut [f64]) {
        let bins = out.len();
        out.fill(0.0);
        let inv = bins as f64 / (mx - mn).max(1e-30);
        for &x in v {
            let idx = ((x as f64 - mn) * inv).floor();
            let idx = (idx.max(0.0) as usize).min(bins - 1);
            out[idx] += 1.0;
        }
    }

    pub fn histogram_f64_into(vals: &[f64], mn: f64, mx: f64, out: &mut [f64]) {
        let bins = out.len();
        out.fill(0.0);
        let inv = bins as f64 / (mx - mn).max(1e-30);
        for &x in vals {
            let idx = ((x - mn) * inv).floor();
            let idx = (idx.max(0.0) as usize).min(bins - 1);
            out[idx] += 1.0;
        }
    }

    pub fn fill_edges(mn: f64, mx: f64, edges: &mut [f64]) {
        let bins = edges.len() as f64;
        for (k, e) in edges.iter_mut().enumerate() {
            *e = mn + (mx - mn) * (k + 1) as f64 / bins;
        }
    }
}

/// AVX2 kernels. Every function is `target_feature(enable = "avx2")`
/// and only reachable through the runtime-detected dispatchers above.
///
/// Bit-parity arguments, per kernel:
///
/// - `convert_minmax`: `vcvtps2pd` is exact. `vminpd`/`vmaxpd` pick
///   `a < b ? a : b` (resp. `>`), which equals the true min/max for any
///   ordered, non-tied pair — lane folding is then bit-neutral because
///   min/max are associative and commutative. The two divergent cases
///   are (1) NaN operands, where the instructions return the second
///   operand while Rust's `f64::min`/`max` return the non-NaN side, and
///   (2) ±0.0 ties, where the instructions return the second operand's
///   zero regardless of sign. Case 1 is detected with an accumulated
///   unordered-compare mask; case 2 can only matter when the reduced
///   result is itself a zero. Either trigger re-folds the already
///   converted f64 slice with the exact scalar sequence, so the
///   returned bits always match the scalar oracle.
/// - `histogram_*`: the scalar index is
///   `(((x - mn) * inv).floor().max(0.0) as usize).min(bins - 1)`.
///   The vector path computes the same `floor((x - mn) * inv)`, clamps
///   with `vmaxpd(t, 0.0)` (returns `+0.0` for NaN or `-0.0` lanes,
///   exactly like `f64::max(NaN, 0.0)` / `(-0.0).max(0.0)`), then
///   clamps high in the f64 domain with `vminpd(t, bins - 1)` — which
///   maps `+inf` and huge finites to the top bin just as the saturating
///   `as usize` cast followed by `.min(bins - 1)` does — before the
///   (now always in-range, hence exact) f64→i32 conversion. The `+1.0`
///   increments are exact integer bumps in any order.
/// - `fill_edges`: `mn + (mx - mn) * k / bins` evaluated with the same
///   operation order per element; the lane counter advances by adding
///   4.0, exact for every representable index.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    unsafe fn reduce_min(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let m = _mm_min_pd(lo, hi);
        let s = _mm_min_sd(m, _mm_unpackhi_pd(m, m));
        _mm_cvtsd_f64(s)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn reduce_max(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let m = _mm_max_pd(lo, hi);
        let s = _mm_max_sd(m, _mm_unpackhi_pd(m, m));
        _mm_cvtsd_f64(s)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn convert_minmax(v: &[f32], vals: &mut Vec<f64>) -> (f64, f64) {
        let n = v.len();
        vals.clear();
        vals.resize(n, 0.0);
        let src = v.as_ptr();
        let dst = vals.as_mut_ptr();
        let mut vmn = _mm256_set1_pd(f64::INFINITY);
        let mut vmx = _mm256_set1_pd(f64::NEG_INFINITY);
        let mut unord = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 4 <= n {
            let d = _mm256_cvtps_pd(_mm_loadu_ps(src.add(i)));
            _mm256_storeu_pd(dst.add(i), d);
            vmn = _mm256_min_pd(vmn, d);
            vmx = _mm256_max_pd(vmx, d);
            unord = _mm256_or_pd(unord, _mm256_cmp_pd::<_CMP_UNORD_Q>(d, d));
            i += 4;
        }
        let (mut mn, mut mx) = (reduce_min(vmn), reduce_max(vmx));
        let saw_nan = _mm256_movemask_pd(unord) != 0;
        for (d, &xf) in vals[i..].iter_mut().zip(&v[i..]) {
            let x = xf as f64;
            *d = x;
            mn = mn.min(x);
            mx = mx.max(x);
        }
        // vminpd/vmaxpd diverge from f64::min/max only on NaN operands
        // or ±0.0 ties; a ±0.0 tie can only have affected the answer if
        // the answer IS a zero. Re-fold those rare cases exactly.
        if saw_nan || mn == 0.0 || mx == 0.0 {
            let (mut smn, mut smx) = (f64::INFINITY, f64::NEG_INFINITY);
            for &x in vals.iter() {
                smn = smn.min(x);
                smx = smx.max(x);
            }
            return (smn, smx);
        }
        (mn, mx)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn histogram_into(v: &[f32], mn: f64, mx: f64, out: &mut [f64]) {
        let bins = out.len();
        out.fill(0.0);
        let inv = bins as f64 / (mx - mn).max(1e-30);
        let vmn = _mm256_set1_pd(mn);
        let vinv = _mm256_set1_pd(inv);
        let vzero = _mm256_setzero_pd();
        let vtop = _mm256_set1_pd((bins - 1) as f64);
        let n = v.len();
        let src = v.as_ptr();
        let mut idx4 = [0i32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_cvtps_pd(_mm_loadu_ps(src.add(i)));
            let t = _mm256_floor_pd(_mm256_mul_pd(_mm256_sub_pd(x, vmn), vinv));
            let t = _mm256_max_pd(t, vzero);
            let t = _mm256_min_pd(t, vtop);
            let b4 = _mm256_cvttpd_epi32(t);
            _mm_storeu_si128(idx4.as_mut_ptr() as *mut __m128i, b4);
            for &b in &idx4 {
                *out.get_unchecked_mut(b as usize) += 1.0;
            }
            i += 4;
        }
        for &x in &v[i..] {
            let idx = ((x as f64 - mn) * inv).floor();
            let idx = (idx.max(0.0) as usize).min(bins - 1);
            out[idx] += 1.0;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn histogram_f64_into(vals: &[f64], mn: f64, mx: f64, out: &mut [f64]) {
        let bins = out.len();
        out.fill(0.0);
        let inv = bins as f64 / (mx - mn).max(1e-30);
        let vmn = _mm256_set1_pd(mn);
        let vinv = _mm256_set1_pd(inv);
        let vzero = _mm256_setzero_pd();
        let vtop = _mm256_set1_pd((bins - 1) as f64);
        let n = vals.len();
        let src = vals.as_ptr();
        let mut idx4 = [0i32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_loadu_pd(src.add(i));
            let t = _mm256_floor_pd(_mm256_mul_pd(_mm256_sub_pd(x, vmn), vinv));
            let t = _mm256_max_pd(t, vzero);
            let t = _mm256_min_pd(t, vtop);
            let b4 = _mm256_cvttpd_epi32(t);
            _mm_storeu_si128(idx4.as_mut_ptr() as *mut __m128i, b4);
            for &b in &idx4 {
                *out.get_unchecked_mut(b as usize) += 1.0;
            }
            i += 4;
        }
        for &x in &vals[i..] {
            let idx = ((x - mn) * inv).floor();
            let idx = (idx.max(0.0) as usize).min(bins - 1);
            out[idx] += 1.0;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn fill_edges(mn: f64, mx: f64, edges: &mut [f64]) {
        let n = edges.len();
        let bins = n as f64;
        let vmn = _mm256_set1_pd(mn);
        let vrange = _mm256_set1_pd(mx - mn);
        let vbins = _mm256_set1_pd(bins);
        let vfour = _mm256_set1_pd(4.0);
        let mut kv = _mm256_setr_pd(1.0, 2.0, 3.0, 4.0);
        let dst = edges.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let e = _mm256_add_pd(vmn, _mm256_div_pd(_mm256_mul_pd(vrange, kv), vbins));
            _mm256_storeu_pd(dst.add(i), e);
            kv = _mm256_add_pd(kv, vfour);
            i += 4;
        }
        for (k, e) in edges.iter_mut().enumerate().skip(i) {
            *e = mn + (mx - mn) * (k + 1) as f64 / bins;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn adversarial_vectors() -> Vec<Vec<f32>> {
        let mut rng = Rng::new(20260808);
        let mut out: Vec<Vec<f32>> = Vec::new();
        let lens = [
            0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 15, 16, 17, 31, 32, 33, 100, 257,
            1000,
        ];
        for &n in &lens {
            out.push((0..n).map(|_| rng.normal(0.0, 3.0) as f32).collect());
        }
        // All-equal, all-zero, mixed-sign-zero, and non-finite payloads.
        out.push(vec![7.25; 40]);
        out.push(vec![0.0; 40]);
        out.push(vec![0.0, -0.0, 0.0, -0.0, 1.0, -1.0, 0.0, -0.0, -0.0]);
        out.push(vec![-0.0; 9]);
        let mut weird: Vec<f32> = (0..37).map(|_| rng.uniform(-5.0, 5.0) as f32).collect();
        weird[3] = f32::NAN;
        weird[17] = f32::INFINITY;
        weird[29] = f32::NEG_INFINITY;
        weird[31] = f32::MIN_POSITIVE / 2.0; // subnormal
        out.push(weird);
        out.push(vec![f32::NAN; 13]);
        out
    }

    fn scalar_minmax(v: &[f32]) -> (Vec<f64>, f64, f64) {
        let mut vals = Vec::new();
        let (mn, mx) = super::scalar::convert_minmax(v, &mut vals);
        (vals, mn, mx)
    }

    #[test]
    fn env_mode_parsing_and_override() {
        let prev = mode();
        set_mode(SimdMode::Scalar);
        assert_eq!(mode(), SimdMode::Scalar);
        assert!(!active());
        set_mode(SimdMode::Off);
        assert!(!active());
        set_mode(SimdMode::Auto);
        assert_eq!(mode(), SimdMode::Auto);
        set_mode(prev);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_convert_minmax_is_bit_identical() {
        if !avx2_available() {
            return;
        }
        for (case, v) in adversarial_vectors().iter().enumerate() {
            let (svals, smn, smx) = scalar_minmax(v);
            let mut avals = Vec::new();
            let (amn, amx) = unsafe { super::avx2::convert_minmax(v, &mut avals) };
            assert_eq!(svals.len(), avals.len(), "case {case}");
            for (a, b) in svals.iter().zip(&avals) {
                assert_eq!(a.to_bits(), b.to_bits(), "case {case} converted value");
            }
            assert_eq!(smn.to_bits(), amn.to_bits(), "case {case} min");
            assert_eq!(smx.to_bits(), amx.to_bits(), "case {case} max");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_histograms_are_bit_identical() {
        if !avx2_available() {
            return;
        }
        for (case, v) in adversarial_vectors().iter().enumerate() {
            let (vals, mut mn, mut mx) = scalar_minmax(v);
            if !mn.is_finite() || !mx.is_finite() || mn > mx {
                // Degenerate ranges (empty / all-NaN / ±inf payloads):
                // pin a finite range so the bin formula is exercised on
                // the raw values, non-finite entries included.
                (mn, mx) = (-4.0, 4.0);
            }
            for bins in [1usize, 2, 3, 4, 5, 7, 8, 32, 33] {
                let mut s32 = vec![0.0; bins];
                let mut a32 = vec![0.0; bins];
                super::scalar::histogram_into(v, mn, mx, &mut s32);
                unsafe { super::avx2::histogram_into(v, mn, mx, &mut a32) };
                assert_eq!(s32, a32, "case {case} bins {bins} (f32)");
                let mut s64 = vec![0.0; bins];
                let mut a64 = vec![0.0; bins];
                super::scalar::histogram_f64_into(&vals, mn, mx, &mut s64);
                unsafe { super::avx2::histogram_f64_into(&vals, mn, mx, &mut a64) };
                assert_eq!(s64, a64, "case {case} bins {bins} (f64)");
                // Degenerate zero-width range: every value lands in one
                // bin through the huge 1e-30-guarded inverse.
                let mut sz = vec![0.0; bins];
                let mut az = vec![0.0; bins];
                super::scalar::histogram_f64_into(&vals, 1.5, 1.5, &mut sz);
                unsafe { super::avx2::histogram_f64_into(&vals, 1.5, 1.5, &mut az) };
                assert_eq!(sz, az, "case {case} bins {bins} (zero-width)");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_fill_edges_is_bit_identical() {
        if !avx2_available() {
            return;
        }
        for bins in [1usize, 2, 3, 4, 5, 7, 8, 9, 31, 32, 33, 100, 257] {
            for &(mn, mx) in &[(-3.5f64, 9.25f64), (0.0, 1.0), (-1e30, 1e30), (2.0, 2.0)] {
                let mut s = vec![0.0; bins];
                let mut a = vec![0.0; bins];
                super::scalar::fill_edges(mn, mx, &mut s);
                unsafe { super::avx2::fill_edges(mn, mx, &mut a) };
                for (x, y) in s.iter().zip(&a) {
                    assert_eq!(x.to_bits(), y.to_bits(), "bins {bins} range {mn}..{mx}");
                }
            }
        }
    }

    #[test]
    fn dispatch_matches_scalar_in_every_mode() {
        let prev = mode();
        for m in [SimdMode::Off, SimdMode::Scalar, SimdMode::Auto] {
            set_mode(m);
            for v in adversarial_vectors() {
                let (svals, smn, smx) = scalar_minmax(&v);
                let mut dvals = Vec::new();
                let (dmn, dmx) = convert_minmax(&v, &mut dvals);
                assert_eq!(smn.to_bits(), dmn.to_bits(), "{m:?} min");
                assert_eq!(smx.to_bits(), dmx.to_bits(), "{m:?} max");
                assert_eq!(svals.len(), dvals.len());
                let (mn, mx) = if smn.is_finite() && smx.is_finite() && smn <= smx {
                    (smn, smx)
                } else {
                    (-4.0, 4.0)
                };
                let mut sh = vec![0.0; 32];
                let mut dh = vec![0.0; 32];
                super::scalar::histogram_into(&v, mn, mx, &mut sh);
                histogram_into(&v, mn, mx, &mut dh);
                assert_eq!(sh, dh, "{m:?} f32 histogram");
                super::scalar::histogram_f64_into(&svals, mn, mx, &mut sh);
                histogram_f64_into(&dvals, mn, mx, &mut dh);
                assert_eq!(sh, dh, "{m:?} f64 histogram");
                let mut se = vec![0.0; 32];
                let mut de = vec![0.0; 32];
                super::scalar::fill_edges(mn, mx, &mut se);
                fill_edges(mn, mx, &mut de);
                assert_eq!(se, de, "{m:?} edges");
            }
        }
        set_mode(prev);
    }
}
