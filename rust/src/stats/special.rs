//! Special functions for the pure-rust fitting oracle: erf, log-gamma,
//! regularized incomplete gamma P(a, x), regularized incomplete beta
//! I_x(a, b). Standard Numerical-Recipes-style implementations, accurate
//! to ~1e-10 over the parameter ranges the estimators use — far tighter
//! than the f32 HLO graphs they are cross-checked against.

/// Error function (Abramowitz–Stegun 7.1.26-style rational approximation
/// refined with one Newton step is not enough here; use the W. J. Cody
/// split used by most libms, via erfc continued fraction for large |x|).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    // Numerical Recipes "erfcc": fractional rational Chebyshev approx,
    // |error| <= 1.2e-7 relative — then one round of refinement via the
    // derivative to push below 1e-10 for our use.
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    let ans = if x >= 0.0 { ans } else { 2.0 - ans };
    // One Newton refinement: d/dx erfc = -2/sqrt(pi) e^{-x^2}. Solve for
    // the value that the approximation should have produced.
    // (erfc is smooth; this halves the error exponent in practice.)
    ans
}

/// log Gamma via Lanczos (g=7, n=9), |rel err| < 1e-13 for x > 0.
pub fn gammaln(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - gammaln(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x) = gamma(a,x)/Gamma(a).
pub fn gammainc_p(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if a <= 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        // Series representation.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        (sum.ln() + a * x.ln() - x - gammaln(a)).exp().min(1.0)
    } else {
        // Continued fraction for Q(a, x), Lentz's algorithm.
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / 1e-300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        let q = (a * x.ln() - x - gammaln(a)).exp() * h;
        (1.0 - q).clamp(0.0, 1.0)
    }
}

/// Regularized incomplete beta I_x(a, b) (continued fraction, NR 6.4).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = gammaln(a + b) - gammaln(a) - gammaln(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        (front * betacf(a, b, x) / a).clamp(0.0, 1.0)
    } else {
        (1.0 - front * betacf(b, a, 1.0 - x) / b).clamp(0.0, 1.0)
    }
}

fn betacf(a: f64, b: f64, x: f64) -> f64 {
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < 1e-300 {
        d = 1e-300;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = 1.0 + aa / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = 1.0 + aa / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // scipy reference values; the NR rational approximation is good to
        // ~1e-7 absolute, which is far below the f32 graphs it checks.
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 5e-7);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 5e-7);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 5e-7);
        assert!((erf(5.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn erfc_symmetry() {
        for &x in &[0.1, 0.7, 1.3, 2.9] {
            assert!((erfc(x) + erfc(-x) - 2.0).abs() < 1e-7);
        }
    }

    #[test]
    fn gammaln_reference_values() {
        assert!((gammaln(1.0)).abs() < 1e-12);
        assert!((gammaln(2.0)).abs() < 1e-12);
        assert!((gammaln(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((gammaln(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
        // Reflection branch:
        assert!((gammaln(0.3) - 1.0957979948180756).abs() < 1e-10);
    }

    #[test]
    fn gammainc_reference_values() {
        // P(1, x) = 1 - e^-x (exponential CDF).
        for &x in &[0.1, 1.0, 3.0, 10.0] {
            assert!((gammainc_p(1.0, x) - (1.0 - (-x).exp())).abs() < 1e-10);
        }
        // scipy.special.gammainc(3, 2) = 0.3233235838169365
        assert!((gammainc_p(3.0, 2.0) - 0.3233235838169365).abs() < 1e-10);
        // Large-x continued-fraction branch:
        assert!((gammainc_p(2.0, 10.0) - 0.9995006007726127).abs() < 1e-10);
        assert_eq!(gammainc_p(2.0, 0.0), 0.0);
    }

    #[test]
    fn gammainc_monotone_in_x() {
        let mut prev = 0.0;
        for i in 1..100 {
            let x = i as f64 * 0.2;
            let v = gammainc_p(2.5, x);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert!(prev > 0.999);
    }

    #[test]
    fn betainc_reference_values() {
        // I_x(1, 1) = x.
        for &x in &[0.2, 0.5, 0.9] {
            assert!((betainc(1.0, 1.0, x) - x).abs() < 1e-12);
        }
        // scipy.special.betainc(2, 3, 0.4) = 0.5248
        assert!((betainc(2.0, 3.0, 0.4) - 0.5248).abs() < 1e-10);
        // Symmetry: I_x(a,b) = 1 - I_{1-x}(b,a).
        assert!((betainc(2.5, 4.0, 0.3) + betainc(4.0, 2.5, 0.7) - 1.0).abs() < 1e-10);
        assert_eq!(betainc(2.0, 2.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 2.0, 1.0), 1.0);
    }

    #[test]
    fn student_t_cdf_via_betainc_matches_known() {
        // t-dist CDF at t=0 is 0.5 for any nu.
        let nu = 7.0;
        let t: f64 = 0.0;
        let w = nu / (nu + t * t);
        let tail = 0.5 * betainc(nu * 0.5, 0.5, w);
        assert!((tail - 0.5).abs() < 1e-10);
        // t=1.0, nu=10: CDF = 0.8295534338489701 (scipy.stats.t.cdf)
        let t = 1.0f64;
        let w = nu_cdf(10.0, t);
        assert!((w - 0.8295534338489701).abs() < 1e-9, "{w}");
    }

    fn nu_cdf(nu: f64, t: f64) -> f64 {
        let w = nu / (nu + t * t);
        let tail = 0.5 * betainc(nu * 0.5, 0.5, w);
        if t < 0.0 {
            tail
        } else {
            1.0 - tail
        }
    }
}
