//! Figure drivers: one function per figure of the paper's evaluation
//! (Figs. 6-20 plus the §6.2/§6.3 in-text decision-tree numbers).
//!
//! Shared by the `pdfflow figure <id>` CLI subcommand and the
//! `cargo bench --bench figures` harness. Each driver generates (or
//! reuses) the scaled dataset analog, runs the pipeline, and prints
//! paper-style rows: real wall-clock on this host next to simulated
//! cluster time (the paper's axis). EXPERIMENTS.md records one run of
//! each and compares shapes against the paper.

use std::path::PathBuf;

use crate::cluster::{ClusterSpec, SimCluster};
use crate::config::{ExperimentConfig, PipelineConfig};
use crate::coordinator::{
    sampling::{full_slice_features, run_sampling},
    Method, Pipeline, Sampler, TypeSet,
};
use crate::coordinator::mlmodel;
use crate::cube::{CubeDims, PointId};
use crate::datagen::{DatasetSpec, SyntheticDataset};
use crate::pdfstore::{QueryEngine, QueryOptions};
use crate::runtime::{make_backend, Backend, BackendKind, BackendOptions};
use crate::storage::{DatasetReader, WindowCache};
use crate::util::prng::Rng;
use crate::util::timing::fmt_secs;
use crate::{PdfflowError, Result};

/// All figure ids, in paper order.
pub const FIGURES: &[&str] = &[
    "fig06", "fig07", "fig08", "fig09", "fig10", "fig11", "fig12", "fig13", "fig14",
    "fig15", "fig16", "fig17", "fig18", "fig19", "fig20", "treestats",
];

/// One scaling row of a `BENCH_*.json` record: a thread count and its
/// throughput (windows/s, queries/s, …), plus free-form extra columns.
pub struct BenchRow {
    pub threads: usize,
    pub throughput: f64,
    pub extra: Vec<(&'static str, crate::util::json::Json)>,
}

/// Repo-root path of a bench trajectory record: `BENCH_<name>.json`
/// next to ROADMAP.md, whatever directory cargo runs from.
pub fn bench_json_path(name: &str) -> PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest
        .parent()
        .unwrap_or(manifest)
        .join(format!("BENCH_{name}.json"))
}

/// Write a bench record in the shared cross-bench schema
/// `{bench, config, rows: [{threads, throughput, ...}], ...extra}` to
/// the repo root (see [`bench_json_path`]); returns the path written.
/// Both bench binaries and the tier-1 smoke tests emit through here, so
/// the perf trajectory files cannot drift apart in shape.
pub fn write_bench_json(
    name: &str,
    config: Vec<(&str, crate::util::json::Json)>,
    rows: Vec<BenchRow>,
    extra: Vec<(&str, crate::util::json::Json)>,
) -> Result<PathBuf> {
    use crate::util::json::Json;
    let rows: Vec<Json> = rows
        .into_iter()
        .map(|r| {
            let mut pairs = vec![
                ("threads", Json::Num(r.threads as f64)),
                ("throughput", Json::Num(r.throughput)),
            ];
            pairs.extend(r.extra);
            Json::obj(pairs)
        })
        .collect();
    // Provenance: every trajectory point is joinable with the telemetry
    // snapshots (same git_rev/build_profile keys) — perf claims in
    // ROADMAP must cite rows that carry these.
    let mut config = config;
    config.push(("git_rev", Json::Str(crate::telemetry::export::git_rev())));
    config.push((
        "build_profile",
        Json::Str(crate::telemetry::export::build_profile().to_string()),
    ));
    let mut pairs = vec![
        ("bench", Json::Str(name.to_string())),
        ("config", Json::obj(config)),
        ("rows", Json::Arr(rows)),
    ];
    pairs.extend(extra);
    let doc = Json::obj(pairs);
    // Fail fast on a record that would poison the trajectory: an empty
    // or malformed file is worse than a loud error at the writer.
    validate_bench_record(name, &doc)?;
    let path = bench_json_path(name);
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

/// Validate a bench record against the shared cross-bench schema
/// `{bench, config, rows: [{threads > 0, finite throughput > 0}]}` with
/// a **non-empty** rows array; returns the rows. Every writer
/// ([`write_bench_json`], [`upsert_bench_row`]) runs this before
/// touching disk, and `tests/bench_smoke.rs` re-runs it on what landed,
/// so BENCH_pipeline.json / BENCH_queries.json always carry usable
/// points.
pub fn validate_bench_record(
    name: &str,
    doc: &crate::util::json::Json,
) -> Result<Vec<crate::util::json::Json>> {
    let bad = |what: String| PdfflowError::Format(format!("bench record {name:?}: {what}"));
    match doc.get("bench").and_then(|b| b.as_str()) {
        Some(b) if b == name => {}
        other => return Err(bad(format!("bench field {other:?} != {name:?}"))),
    }
    if doc.get("config").is_none() {
        return Err(bad("missing config object".into()));
    }
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| bad("missing rows array".into()))?;
    if rows.is_empty() {
        return Err(bad("rows array is empty (no usable points)".into()));
    }
    for (i, row) in rows.iter().enumerate() {
        let threads = row
            .get("threads")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| bad(format!("row {i}: missing numeric threads")))?;
        let throughput = row
            .get("throughput")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| bad(format!("row {i}: missing numeric throughput")))?;
        if !threads.is_finite() || threads < 1.0 || !throughput.is_finite() || throughput <= 0.0 {
            return Err(bad(format!(
                "row {i}: threads {threads} / throughput {throughput} not usable"
            )));
        }
    }
    Ok(rows.to_vec())
}

/// Profile tag (`config.profile`) of the committed `BENCH_<name>.json`,
/// when the file exists and parses. The tier-1 smoke tests use this to
/// reject a `"placeholder"` record checked into the repo **before**
/// rewriting the file: the trajectory files must always carry measured
/// rows, never zero-throughput stand-ins.
pub fn committed_profile(name: &str) -> Option<String> {
    let text = std::fs::read_to_string(bench_json_path(name)).ok()?;
    let doc = crate::util::json::Json::parse(&text).ok()?;
    Some(doc.get("config")?.get("profile")?.as_str()?.to_string())
}

/// Parse `BENCH_<name>.json` from the repo root and validate it (see
/// [`validate_bench_record`]); returns the rows.
pub fn validate_bench_json(name: &str) -> Result<Vec<crate::util::json::Json>> {
    let path = bench_json_path(name);
    let text = std::fs::read_to_string(&path)?;
    let doc = crate::util::json::Json::parse(&text)
        .map_err(|e| PdfflowError::Format(format!("{}: {e}", path.display())))?;
    validate_bench_record(name, &doc)
}

/// Read-modify-write one row into `BENCH_<name>.json`: rows whose
/// `mode` extra matches `mode` are replaced, everything else is kept.
/// Creates a minimal record when the file is missing or unreadable.
/// This is how `pdfflow serve --bench` lands its serving-throughput row
/// next to the queries bench's scaling rows without clobbering them.
pub fn upsert_bench_row(name: &str, mode: &str, row: BenchRow) -> Result<PathBuf> {
    use crate::util::json::Json;
    let path = bench_json_path(name);
    let existing = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .filter(|doc| validate_bench_record(name, doc).is_ok());
    let mut rows: Vec<Json> = existing
        .as_ref()
        .and_then(|doc| doc.get("rows"))
        .and_then(|r| r.as_arr())
        .map(|r| {
            r.iter()
                .filter(|row| row.get("mode").and_then(|m| m.as_str()) != Some(mode))
                .cloned()
                .collect()
        })
        .unwrap_or_default();
    let mut pairs = vec![
        ("threads", Json::Num(row.threads as f64)),
        ("throughput", Json::Num(row.throughput)),
        ("mode", Json::Str(mode.to_string())),
    ];
    pairs.extend(row.extra);
    rows.push(Json::obj(pairs));
    // Start from the existing document so top-level extras the bench
    // wrote (region_summary_per_s, compacted_qps, …) survive the upsert.
    let mut map = match existing {
        Some(Json::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    map.insert("bench".to_string(), Json::Str(name.to_string()));
    let config = map
        .entry("config".to_string())
        .or_insert_with(|| Json::obj(Vec::new()));
    if let Json::Obj(c) = config {
        // Refresh provenance: the upserted row was measured by *this*
        // build, so the record's joinable keys must say so.
        c.insert(
            "git_rev".to_string(),
            Json::Str(crate::telemetry::export::git_rev()),
        );
        c.insert(
            "build_profile".to_string(),
            Json::Str(crate::telemetry::export::build_profile().to_string()),
        );
    }
    map.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(map);
    validate_bench_record(name, &doc)?;
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

/// One store build shared across every query-bench mode.
///
/// `benches/queries.rs` and the tier-1 smoke recorder
/// (`tests/bench_smoke.rs`) all drive point, serving and spatial passes
/// over the same fitted store; building it once per process — dataset
/// generation plus the pipeline's persist phase — instead of re-fitting
/// per mode is what keeps those harnesses smoke-fast. The fixture owns
/// its temp root and removes it on drop.
pub struct QueryStoreFixture {
    root: PathBuf,
    ds: SyntheticDataset,
    backend: Box<dyn Backend>,
    window_lines: usize,
    /// Slices persisted into the store, ascending.
    pub slices: Vec<usize>,
}

impl QueryStoreFixture {
    /// Generate the dataset under a process-unique temp root (`tag`
    /// keeps concurrent harnesses apart) and persist `slices`
    /// (Baseline, 4-types) into a store at `<root>/store`.
    pub fn build(
        tag: &str,
        dims: CubeDims,
        seed: u64,
        window_lines: usize,
        slices: &[usize],
    ) -> Result<QueryStoreFixture> {
        let root = std::env::temp_dir().join(format!("pdfflow-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut spec = DatasetSpec::tiny();
        spec.dims = dims;
        spec.seed = seed;
        let ds = SyntheticDataset::generate(&spec, root.join("data"))?;
        let backend = make_backend(
            BackendKind::Native,
            "artifacts",
            &BackendOptions {
                batch: 64,
                ..BackendOptions::default()
            },
        )?;
        let fixture = QueryStoreFixture {
            root,
            ds,
            backend,
            window_lines,
            slices: slices.to_vec(),
        };
        for &z in slices {
            fixture.persist_slice(z)?;
        }
        Ok(fixture)
    }

    /// Cube dims of the generated dataset.
    pub fn dims(&self) -> CubeDims {
        self.ds.spec.dims
    }

    /// On-disk store directory (open it with [`QueryEngine::open`] or
    /// point the `pdfflow query` CLI at it).
    pub fn store_dir(&self) -> PathBuf {
        self.root.join("store")
    }

    /// Run the persist phase for one slice. Calling it again for an
    /// already-persisted slice appends a generation — the compaction
    /// passes rely on this to create something to compact.
    pub fn persist_slice(&self, z: usize) -> Result<()> {
        let cfg = PipelineConfig {
            batch: 64,
            window_lines: self.window_lines,
            store_dir: Some(self.store_dir().to_string_lossy().into_owned()),
            ..PipelineConfig::default()
        };
        let mut pipe = Pipeline::new(
            &self.ds,
            self.backend.as_ref(),
            SimCluster::new(ClusterSpec::lncc()),
            cfg,
        );
        pipe.run_slice(Method::Baseline, z, TypeSet::Four)?;
        Ok(())
    }

    /// Fresh engine over the store with a `cache_bytes` sharded LRU.
    pub fn engine(&self, cache_bytes: u64) -> Result<QueryEngine> {
        QueryEngine::open(
            self.store_dir(),
            QueryOptions {
                cache_bytes,
                ..QueryOptions::default()
            },
        )
    }

    /// Deterministic random point workload spread across the persisted
    /// slices.
    pub fn point_ids(&self, n: usize, seed: u64) -> Vec<PointId> {
        let mut rng = Rng::new(seed);
        let slice_pts = self.dims().slice_points() as u64;
        (0..n)
            .map(|_| {
                let z = self.slices[rng.below(self.slices.len())] as u64;
                PointId(z * slice_pts + rng.below(slice_pts as usize) as u64)
            })
            .collect()
    }
}

impl Drop for QueryStoreFixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

/// Bench environment: compute backend + dataset root + scale.
pub struct BenchEnv {
    pub backend: Box<dyn Backend>,
    pub data_root: PathBuf,
    /// Quick scale (default for `cargo bench`): ~100x smaller datasets,
    /// reduced sweeps. Full scale via `--full` / PDFFLOW_BENCH_FULL=1.
    pub quick: bool,
}

impl BenchEnv {
    /// Build a bench environment on the given backend — the harness's
    /// apples-to-apples native-vs-XLA comparison point: run the same
    /// figure once per backend and diff the real-time columns.
    pub fn new(
        kind: BackendKind,
        artifacts_dir: &str,
        data_root: &str,
        quick: bool,
    ) -> Result<BenchEnv> {
        Ok(BenchEnv {
            backend: make_backend(kind, artifacts_dir, &BackendOptions::default())?,
            data_root: PathBuf::from(data_root),
            quick,
        })
    }

    /// Scaled experiment configs (DESIGN.md §3: every figure records the
    /// scale factor next to the paper's numbers).
    pub fn config(&self, name: &str) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::preset(match name {
            "set1" | "set2" | "set3" => name,
            other => return Err(PdfflowError::Config(format!("unknown set {other:?}"))),
        })?;
        if self.quick {
            // Lines keep the paper's 251-point length (256 here) so the
            // points-per-window : cluster-slot ratio — which drives every
            // Grouping/ML trade-off — stays in the paper's regime; only
            // slices, lines and observation counts shrink.
            match name {
                "set1" => {
                    cfg.dataset.dims = CubeDims::new(256, 64, 64);
                    cfg.dataset.n_sims = 100;
                    cfg.pipeline.batch = 64;
                }
                "set2" => {
                    cfg.dataset.dims = CubeDims::new(256, 80, 80);
                    cfg.dataset.n_sims = 100;
                    cfg.pipeline.batch = 64;
                }
                "set3" => {
                    // 10x set1's observations, like the paper's 10000 vs 1000.
                    cfg.dataset.dims = CubeDims::new(128, 64, 64);
                    cfg.dataset.n_sims = 1000;
                    cfg.pipeline.batch = 256;
                }
                _ => unreachable!(),
            }
            cfg.slice = cfg.dataset.dims.nz * 201 / 501;
        }
        cfg.data_dir = self
            .data_root
            .join(format!("{name}{}", if self.quick { "-quick" } else { "" }))
            .to_string_lossy()
            .into_owned();
        Ok(cfg)
    }

    fn dataset(&self, cfg: &ExperimentConfig) -> Result<SyntheticDataset> {
        eprintln!(
            "[bench] dataset {} at {} ({} sims, {}x{}x{})",
            cfg.name,
            cfg.data_dir,
            cfg.dataset.n_sims,
            cfg.dataset.dims.nx,
            cfg.dataset.dims.ny,
            cfg.dataset.dims.nz
        );
        SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)
    }

    /// Run one figure (or "all").
    pub fn run(&self, id: &str) -> Result<()> {
        match id {
            "fig06" | "fig07" => self.fig06_07(),
            "fig08" => self.fig08(),
            "fig09" => self.fig09(),
            "fig10" | "fig11" => self.fig10_11(),
            "fig12" => self.fig12(),
            "fig13" | "fig14" => self.fig13_14(),
            "fig15" => self.fig15_16_17(Sampler::Random),
            "fig16" => self.fig15_16_17(Sampler::KMeans),
            "fig17" => self.fig17(),
            "fig18" => self.fig18(),
            "fig19" => self.fig19(),
            "fig20" => self.fig20(),
            "treestats" => self.treestats(),
            "all" => {
                // Alias ids (fig07/fig11/fig14) share drivers with their
                // partner figures; run each driver once.
                for f in FIGURES {
                    if matches!(*f, "fig07" | "fig11" | "fig14") {
                        continue;
                    }
                    self.run(f)?;
                }
                Ok(())
            }
            other => Err(PdfflowError::InvalidArg(format!(
                "unknown figure {other:?}; known: {FIGURES:?} or 'all'"
            ))),
        }
    }

    fn header(&self, id: &str, title: &str) {
        println!();
        println!(
            "=== {} — {} [{} scale, {} backend] ===",
            id,
            title,
            if self.quick { "quick" } else { "full" },
            self.backend.name()
        );
    }

    /// The paper's small workload: 6 lines (3006 points at paper scale).
    fn small_workload_lines(&self) -> usize {
        6
    }

    // ---------------------------------------------------------------
    // Fig 6/7: small-workload execution time + error, LNCC, all methods
    // ---------------------------------------------------------------
    fn fig06_07(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = 3; // paper: 3 lines per window, 2 windows
        let mut pipe = Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
        pipe.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;

        self.header("fig06", "PDF computation time, small workload (6 lines), LNCC");
        println!(
            "{:<14} {:<8} {:>12} {:>12} {:>9} {:>8} {:>8}",
            "method", "types", "fit(real)", "fit(sim)", "E", "fits", "groups"
        );
        let mut rows = Vec::new();
        for types in [TypeSet::Four, TypeSet::Ten] {
            for method in Method::ALL {
                let r = pipe.run_lines(method, cfg.slice, types, self.small_workload_lines())?;
                println!(
                    "{:<14} {:<8} {:>12} {:>12} {:>9.4} {:>8} {:>8}",
                    method.name(),
                    types.name(),
                    fmt_secs(r.fit_real_s),
                    fmt_secs(r.fit_sim_s),
                    r.avg_error,
                    r.fits,
                    r.groups
                );
                rows.push(r);
            }
        }
        // Loading time (cold, same for all methods — paper: 67 s).
        println!(
            "loading (first run, cold): real {} sim {}",
            fmt_secs(rows[0].load_real_s),
            fmt_secs(rows[0].load_sim_s)
        );
        // Headline factors vs Baseline.
        for types in [TypeSet::Four, TypeSet::Ten] {
            let base = rows
                .iter()
                .find(|r| r.method == Method::Baseline && r.types == types)
                .unwrap();
            let best = rows
                .iter()
                .filter(|r| r.types == types)
                .min_by(|a, b| a.fit_sim_s.partial_cmp(&b.fit_sim_s).unwrap())
                .unwrap();
            println!(
                "{}: best {} = {:.1}x faster than baseline (sim)",
                types.name(),
                best.method.name(),
                base.fit_sim_s / best.fit_sim_s.max(1e-9)
            );
        }

        self.header("fig07", "average error E, NoML vs WithML");
        println!("{:<10} {:>12} {:>12}", "types", "NoML(E)", "WithML(E)");
        for types in [TypeSet::Four, TypeSet::Ten] {
            let noml = rows
                .iter()
                .filter(|r| r.types == types && !r.method.uses_ml())
                .map(|r| r.avg_error)
                .fold(0.0, f64::max);
            let withml = rows
                .iter()
                .filter(|r| r.types == types && r.method.uses_ml())
                .map(|r| r.avg_error)
                .fold(0.0, f64::max);
            println!("{:<10} {:>12.4} {:>12.4}", types.name(), noml, withml);
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 8: window-size sweep, Grouping 4-types, 2 windows
    // ---------------------------------------------------------------
    fn window_sizes(&self, ny: usize) -> Vec<usize> {
        let all = [2usize, 4, 8, 12, 16, 25, 32, 45];
        all.iter().copied().filter(|&w| 2 * w <= ny).collect()
    }

    fn fig08(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        self.header("fig08", "avg time per line vs window size (Grouping, 4-types, 2 windows)");
        println!(
            "{:<8} {:>14} {:>14} {:>14}",
            "window", "fit/line(sim)", "fit/line(real)", "load/line(sim)"
        );
        for w in self.window_sizes(ds.spec.dims.ny) {
            let mut pcfg = cfg.pipeline.clone();
            pcfg.window_lines = w;
            let mut pipe =
                Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
            let lines = 2 * w;
            let r = pipe.run_lines(Method::Grouping, cfg.slice, TypeSet::Four, lines)?;
            println!(
                "{:<8} {:>14} {:>14} {:>14}",
                w,
                fmt_secs(r.fit_sim_s / lines as f64),
                fmt_secs(r.fit_real_s / lines as f64),
                fmt_secs(r.load_sim_s / lines as f64),
            );
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 9: window-size sweep for the other methods
    // ---------------------------------------------------------------
    fn fig09(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let methods = [
            (Method::Baseline, TypeSet::Four),
            (Method::Baseline, TypeSet::Ten),
            (Method::GroupingMl, TypeSet::Four),
            (Method::GroupingMl, TypeSet::Ten),
            (Method::ReuseMl, TypeSet::Four),
            (Method::ReuseMl, TypeSet::Ten),
        ];
        self.header("fig09", "avg fit time per line vs window size, other methods (sim)");
        print!("{:<8}", "window");
        for (m, t) in &methods {
            print!(" {:>18}", format!("{}/{}", m.name(), t.n_types()));
        }
        println!();
        for w in self.window_sizes(ds.spec.dims.ny) {
            let mut pcfg = cfg.pipeline.clone();
            pcfg.window_lines = w;
            let mut pipe =
                Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
            pipe.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
            print!("{:<8}", w);
            let lines = 2 * w;
            for (m, t) in &methods {
                let r = pipe.run_lines(*m, cfg.slice, *t, lines)?;
                print!(" {:>18}", fmt_secs(r.fit_sim_s / lines as f64));
            }
            println!();
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 10/11: whole Slice-201-analog, LNCC, all methods
    // ---------------------------------------------------------------
    fn fig10_11(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = 25.min(ds.spec.dims.ny); // paper's tuned window
        let mut pipe = Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
        pipe.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
        self.header("fig10", "PDF computation time, whole slice, LNCC");
        println!(
            "{:<14} {:<8} {:>12} {:>12} {:>9} {:>8}",
            "method", "types", "fit(real)", "fit(sim)", "E", "fits"
        );
        let mut rows = Vec::new();
        for types in [TypeSet::Four, TypeSet::Ten] {
            for method in Method::ALL {
                let r = pipe.run_slice(method, cfg.slice, types)?;
                println!(
                    "{:<14} {:<8} {:>12} {:>12} {:>9.4} {:>8}",
                    method.name(),
                    types.name(),
                    fmt_secs(r.fit_real_s),
                    fmt_secs(r.fit_sim_s),
                    r.avg_error,
                    r.fits
                );
                rows.push(r);
            }
        }
        println!(
            "loading (first run, cold): real {} sim {}",
            fmt_secs(rows[0].load_real_s),
            fmt_secs(rows[0].load_sim_s)
        );
        self.header("fig11", "whole-slice error E");
        for r in &rows {
            println!("{:<14} {:<8} E={:.4}", r.method.name(), r.types.name(), r.avg_error);
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 12: data loading vs node count (G5k)
    // ---------------------------------------------------------------
    fn node_counts(&self) -> Vec<usize> {
        vec![10, 20, 30, 40, 50, 60]
    }

    fn fig12(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        self.header("fig12", "data loading time vs nodes (G5k, whole slice, cold cache)");
        println!("{:<8} {:>12} {:>12}", "nodes", "load(sim)", "load(real)");
        for n in self.node_counts() {
            let reader = DatasetReader::new(&ds);
            let cache = WindowCache::new(0); // cold: no caching
            let cluster = SimCluster::new(ClusterSpec::g5k(n));
            let mut real = 0.0;
            for w in ds.spec.dims.windows(cfg.slice, cfg.pipeline.window_lines) {
                let lw = crate::coordinator::loader::load_window(
                    &reader, &cache, self.backend.as_ref(), &cluster, w,
                )?;
                real += lw.real_s;
            }
            println!(
                "{:<8} {:>12} {:>12}",
                n,
                fmt_secs(cluster.total()),
                fmt_secs(real)
            );
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 13/14: PDF computation vs node count
    // ---------------------------------------------------------------
    fn fig13_14(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let methods = [
            Method::Baseline,
            Method::Grouping,
            Method::Ml,
            Method::GroupingMl,
        ];
        self.header("fig13", "PDF computation (sim) vs nodes, 10-types, G5k");
        print!("{:<8}", "nodes");
        for m in &methods {
            print!(" {:>14}", m.name());
        }
        println!();
        let mut crossover: Vec<(usize, f64, f64)> = Vec::new();
        for n in self.node_counts() {
            let mut pcfg = cfg.pipeline.clone();
            pcfg.window_lines = 25.min(ds.spec.dims.ny);
            let mut pipe =
                Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::g5k(n)), pcfg);
            pipe.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
            print!("{:<8}", n);
            let mut ml_t = 0.0;
            let mut gml_t = 0.0;
            for m in &methods {
                let r = pipe.run_slice(*m, cfg.slice, TypeSet::Ten)?;
                if *m == Method::Ml {
                    ml_t = r.fit_sim_s;
                }
                if *m == Method::GroupingMl {
                    gml_t = r.fit_sim_s;
                }
                print!(" {:>14}", fmt_secs(r.fit_sim_s));
            }
            println!();
            crossover.push((n, ml_t, gml_t));
        }
        self.header("fig14", "focus: ML vs Grouping+ML crossover");
        for (n, ml, gml) in crossover {
            println!(
                "nodes {:<4} ml {:>12} grouping+ml {:>12}  winner: {}",
                n,
                fmt_secs(ml),
                fmt_secs(gml),
                if ml < gml { "ml" } else { "grouping+ml" }
            );
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 15/16: sampling time vs rate
    // ---------------------------------------------------------------
    fn sampling_rates(&self, sampler: Sampler) -> Vec<f64> {
        match sampler {
            Sampler::Random => vec![0.001, 0.01, 0.1, 0.2, 0.5, 1.0],
            Sampler::KMeans => vec![0.2, 0.4, 0.6, 0.8, 1.0],
        }
    }

    fn fig15_16_17(&self, sampler: Sampler) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = 25.min(ds.spec.dims.ny);
        let mut pipe = Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
        pipe.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
        let tree = pipe.tree.clone().unwrap();
        let id = if sampler == Sampler::Random { "fig15" } else { "fig16" };
        self.header(id, &format!("sampling time vs rate ({})", sampler.name()));
        println!(
            "{:<8} {:>10} {:>12} {:>12} {:>14} {:>14}",
            "rate", "sampled", "load(sim)", "load(real)", "compute(sim)", "compute(real)"
        );
        let reader = DatasetReader::new(&ds);
        let cache = WindowCache::new(512 << 20);
        for rate in self.sampling_rates(sampler) {
            let cluster = SimCluster::new(ClusterSpec::lncc());
            let rep = run_sampling(
                &reader,
                &cache,
                self.backend.as_ref(),
                &cluster,
                &tree,
                cfg.slice,
                rate,
                sampler,
                42,
            )?;
            println!(
                "{:<8} {:>10} {:>12} {:>12} {:>14} {:>14}",
                rate,
                rep.n_sampled,
                fmt_secs(rep.load_sim_s),
                fmt_secs(rep.load_real_s),
                fmt_secs(rep.compute_sim_s),
                fmt_secs(rep.compute_real_s),
            );
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 17: type-percentage distance, sampled vs all points
    // ---------------------------------------------------------------
    fn fig17(&self) -> Result<()> {
        let cfg = self.config("set1")?;
        let ds = self.dataset(&cfg)?;
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = 25.min(ds.spec.dims.ny);
        let mut pipe = Pipeline::new(&ds, self.backend.as_ref(), SimCluster::new(ClusterSpec::lncc()), pcfg);
        pipe.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
        let tree = pipe.tree.clone().unwrap();
        let reader = DatasetReader::new(&ds);
        let cache = WindowCache::new(512 << 20);
        let cluster = SimCluster::new(ClusterSpec::lncc());
        let full = full_slice_features(&reader, &cache, self.backend.as_ref(), &cluster, &tree, cfg.slice)?;
        self.header("fig17", "Euclidean distance of type percentages vs all points");
        println!("{:<8} {:>12} {:>12}", "rate", "random", "kmeans");
        for rate in [0.05, 0.1, 0.2, 0.4, 0.6, 0.8] {
            let mut d = [0.0f64; 2];
            for (i, sampler) in [Sampler::Random, Sampler::KMeans].into_iter().enumerate() {
                let rep = run_sampling(
                    &reader, &cache, self.backend.as_ref(), &cluster, &tree, cfg.slice, rate, sampler, 42,
                )?;
                d[i] = rep.features.type_distance(&full);
            }
            println!("{:<8} {:>12.4} {:>12.4}", rate, d[0], d[1]);
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 18: Set2-analog, whole slice, 30/60 nodes
    // ---------------------------------------------------------------
    fn fig18(&self) -> Result<()> {
        let cfg = self.config("set2")?;
        let ds = self.dataset(&cfg)?;
        let methods = [
            Method::Baseline,
            Method::Grouping,
            Method::Ml,
            Method::GroupingMl,
        ];
        self.header("fig18", "Set2-analog whole slice (sim) vs methods, 30/60 nodes");
        println!(
            "{:<14} {:<8} {:>14} {:>14}",
            "method", "types", "30 nodes", "60 nodes"
        );
        for types in [TypeSet::Four, TypeSet::Ten] {
            for m in methods {
                let mut times = Vec::new();
                for n in [30, 60] {
                    let mut pcfg = cfg.pipeline.clone();
                    pcfg.window_lines = 25.min(ds.spec.dims.ny);
                    let mut pipe = Pipeline::new(
                        &ds,
                        self.backend.as_ref(),
                        SimCluster::new(ClusterSpec::g5k(n)),
                        pcfg,
                    );
                    pipe.ensure_tree(cfg.train_slice, types, 25_000)?;
                    let r = pipe.run_slice(m, cfg.slice, types)?;
                    times.push(r.fit_sim_s);
                }
                println!(
                    "{:<14} {:<8} {:>14} {:>14}",
                    m.name(),
                    types.name(),
                    fmt_secs(times[0]),
                    fmt_secs(times[1])
                );
            }
        }
        // Random sampling comparison (paper §6.3.1 text).
        let reader = DatasetReader::new(&ds);
        let cache = WindowCache::new(512 << 20);
        let mut pipe = Pipeline::new(
            &ds,
            self.backend.as_ref(),
            SimCluster::new(ClusterSpec::g5k(30)),
            cfg.pipeline.clone(),
        );
        pipe.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
        let tree = pipe.tree.clone().unwrap();
        for n in [30usize, 60] {
            let cluster = SimCluster::new(ClusterSpec::g5k(n));
            let mut total = 0.0;
            let rates = [0.001, 0.01, 0.1, 1.0];
            for r in rates {
                let rep = run_sampling(
                    &reader, &cache, self.backend.as_ref(), &cluster, &tree, cfg.slice, r,
                    Sampler::Random, 42,
                )?;
                total += rep.compute_sim_s;
            }
            println!(
                "sampling (random) avg PDF-computation time, {n} nodes: {}",
                fmt_secs(total / rates.len() as f64)
            );
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 19: Set3-analog small workload — Grouping collapses
    // ---------------------------------------------------------------
    fn fig19(&self) -> Result<()> {
        let cfg = self.config("set3")?;
        let ds = self.dataset(&cfg)?;
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = 1; // paper: 1 line per window, 2 windows
        let mut pipe = Pipeline::new(
            &ds,
            self.backend.as_ref(),
            SimCluster::new(ClusterSpec::g5k(30)),
            pcfg,
        );
        pipe.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
        self.header("fig19", "Set3-analog small workload (2 lines), 30 nodes");
        println!(
            "{:<14} {:<8} {:>12} {:>12} {:>9} {:>12}",
            "method", "types", "fit(real)", "fit(sim)", "E", "shuffleB"
        );
        for types in [TypeSet::Four, TypeSet::Ten] {
            for m in [Method::Baseline, Method::Grouping, Method::Ml] {
                let r = pipe.run_lines(m, cfg.slice, types, 2)?;
                println!(
                    "{:<14} {:<8} {:>12} {:>12} {:>9.4} {:>12}",
                    m.name(),
                    types.name(),
                    fmt_secs(r.fit_real_s),
                    fmt_secs(r.fit_sim_s),
                    r.avg_error,
                    r.shuffle_bytes
                );
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // Fig 20: Set3-analog whole slice, Baseline vs ML, 30/60 nodes
    // ---------------------------------------------------------------
    fn fig20(&self) -> Result<()> {
        let cfg = self.config("set3")?;
        let ds = self.dataset(&cfg)?;
        self.header("fig20", "Set3-analog whole slice, Baseline vs ML (sim)");
        println!(
            "{:<14} {:<8} {:>14} {:>14}",
            "method", "types", "30 nodes", "60 nodes"
        );
        for types in [TypeSet::Four, TypeSet::Ten] {
            for m in [Method::Baseline, Method::Ml] {
                let mut times = Vec::new();
                for n in [30, 60] {
                    let mut pcfg = cfg.pipeline.clone();
                    // paper: 126-line windows for parallelism; scale to ny
                    pcfg.window_lines = (ds.spec.dims.ny / 4).max(1);
                    let mut pipe = Pipeline::new(
                        &ds,
                        self.backend.as_ref(),
                        SimCluster::new(ClusterSpec::g5k(n)),
                        pcfg,
                    );
                    pipe.ensure_tree(cfg.train_slice, types, 25_000)?;
                    let r = pipe.run_slice(m, cfg.slice, types)?;
                    times.push(r.fit_sim_s);
                }
                println!(
                    "{:<14} {:<8} {:>14} {:>14}",
                    m.name(),
                    types.name(),
                    fmt_secs(times[0]),
                    fmt_secs(times[1])
                );
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // In-text: decision tree model errors and tuning (paper §6.2/§6.3)
    // ---------------------------------------------------------------
    fn treestats(&self) -> Result<()> {
        self.header("treestats", "decision-tree model errors + tuning (paper §6.2/§6.3 text)");
        for set in ["set1", "set2", "set3"] {
            let cfg = self.config(set)?;
            let ds = self.dataset(&cfg)?;
            let reader = DatasetReader::new(&ds);
            let cache = WindowCache::new(512 << 20);
            let cluster = SimCluster::new(ClusterSpec::lncc());
            for types in [TypeSet::Four, TypeSet::Ten] {
                let slices = mlmodel::training_slices(
                    &ds.spec.dims,
                    cfg.train_slice,
                    ds.spec.n_value_layers(),
                );
                let data = mlmodel::build_training_data(
                    &reader,
                    &cache,
                    self.backend.as_ref(),
                    &cluster,
                    &ds.spec.dims,
                    &slices,
                    types,
                    25_000,
                    cfg.pipeline.window_lines,
                    mlmodel::LabelSource::Refit,
                )?;
                let (params, tune_err, tune_s) = mlmodel::tune_hypers(&data, 42)?;
                let model = mlmodel::train_model(&data, params, 43)?;
                println!(
                    "{set} {:<8} samples {:>6}  tuned depth={} bins={} ({} tuning, val err {:.4})  model err {:.4}  train {}",
                    types.name(),
                    data.samples.len(),
                    params.max_depth,
                    params.max_bins,
                    fmt_secs(tune_s),
                    tune_err,
                    model.model_error,
                    fmt_secs(model.train_real_s)
                );
            }
        }
        Ok(())
    }
}
