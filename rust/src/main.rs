//! pdfflow CLI — the leader entrypoint.
//!
//! ```text
//! pdfflow generate  --preset set1 [--data-dir DIR]         generate a dataset
//! pdfflow run       --preset set1 --method grouping+ml --types 10
//!                   [--slice Z] [--lines N] [--window W] [--nodes N|--cluster lncc]
//!                   [--backend native|xla] [--executor-threads N] [--host-threads N]
//! pdfflow sample    --preset set1 --rate 0.1 [--sampler random|kmeans]
//! pdfflow features  --preset set1 [--slice Z]              full-slice features
//! pdfflow train-tree --preset set1 --types 4 [--tune] [--out tree.json]
//! pdfflow tune-window --preset set1 [--sizes 2,4,8,16,25]  window-size sweep
//! pdfflow qoi       --preset set1 [--lines N]             per-point QOI summary (paper §1)
//! pdfflow figure    <fig06..fig20|treestats|all> [--full]  paper figures
//! pdfflow artifacts-check                                   compile every artifact
//! pdfflow store     --preset set1 --store-dir DIR --method grouping --types 4
//!                   [--slice Z] [--lines N] [--run-id ID]  persist fitted PDFs to a pdfstore run
//! pdfflow store compact --store-dir DIR [--run ID]         collapse a run's generations
//! pdfflow store verify  --store-dir DIR [--run ID]         checksum every segment of a run
//! pdfflow store scrub   --store-dir DIR [--repair]         sweep every run; --repair rewrites
//!                                                          salvageable runs from survivors
//! pdfflow query     --store-dir DIR [--run ID] [--point x,y,z] [--region z[,y0,y1[,x0,x1]]]
//!                   [--box z0,z1[,y0,y1[,x0,x1]]] [--agg] [--radius x,y,z,r] [--knn x,y,z,k]
//!                   [--diff-run ID] [--cells sx,sy,sz]
//!                   [--quantile Q] [--threads N] [--host-threads N] [--cache-mb MB] [--verify]
//! pdfflow serve     --store-dir DIR [--run ID] [--clients N] [--queries N]
//!                   [--max-in-flight N] [--queue-depth N] [--bench]
//!                   [--read-path mmap|cached] [--result-cache-mb MB]
//!                   [--listen ADDR]                        serve over a TCP socket; --clients 0
//!                                                          serves until a wire shutdown frame
//! pdfflow serve     --connect ADDR [--clients N] [--queries N] [--shutdown]
//!                   drive a remote serve socket (client only, no local store)
//! pdfflow telemetry validate <snapshot.json>             check an exported metrics snapshot
//! ```
//!
//! `run` and `serve` take `--metrics-out PATH` to export the telemetry
//! registry (JSON snapshot at PATH, Prometheus text at PATH.prom).
//! `PDFFLOW_TRACE=0` disables span tracing and the flight recorder.
//! `PDFFLOW_FAULTS=<spec>` (or the `faults.spec` config key) arms the
//! deterministic fault-injection harness — see the `fault` module docs.
//!
//! `--config FILE` loads a TOML experiment config instead of `--preset`.
//! Every subcommand except `artifacts-check` (PJRT-only by nature)
//! accepts `--backend native|xla` (default native, or the
//! `PDFFLOW_BACKEND` environment variable).

use anyhow::{anyhow, Context, Result};

use pdfflow::bench::BenchEnv;
use pdfflow::cluster::{ClusterSpec, SimCluster};
use pdfflow::config::ExperimentConfig;
use pdfflow::coordinator::sampling::{full_slice_features, run_sampling};
use pdfflow::coordinator::{mlmodel, Method, Pipeline, Sampler, TypeSet};
use pdfflow::datagen::SyntheticDataset;
use pdfflow::pdfstore::{
    compact_run, validate_run_id, PdfStore, QueryEngine, QueryOptions, ReadPath, RegionQuery,
    RunSelector,
};
use pdfflow::runtime::BackendKind;
use pdfflow::serve::net::{closed_loop_net, Client, NetOptions, NetServer};
use pdfflow::serve::{closed_loop, ServeFront, ServeOptions};
use pdfflow::spatial::{BoxQuery, KnnQuery, RadiusQuery};
use pdfflow::storage::{DatasetReader, WindowCache};
use pdfflow::telemetry::flight;
use pdfflow::telemetry::text::{render_text, CacheLine, Section};
use pdfflow::util::cli::Args;
use pdfflow::util::timing::{fmt_bytes, fmt_secs};

fn main() {
    let args = match Args::parse(
        std::env::args().skip(1),
        &["tune", "full", "verbose", "verify", "bench", "agg", "repair", "shutdown"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    // A panic anywhere dumps the span flight recorder before unwinding.
    flight::install_crash_hook();
    // Register the robustness counter families eagerly so exported
    // snapshots list them even at zero.
    pdfflow::fault::register_metrics();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        if pdfflow::telemetry::enabled() {
            match flight::dump("error") {
                Ok(p) => eprintln!("flight recorder dumped to {}", p.display()),
                Err(de) => eprintln!("flight recorder dump failed: {de}"),
            }
        }
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.opt("config") {
        ExperimentConfig::from_file(path).context("loading --config")?
    } else {
        ExperimentConfig::preset(&args.opt_or("preset", "small"))?
    };
    if let Some(d) = args.opt("data-dir") {
        cfg.data_dir = d.to_string();
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    cfg.slice = args.usize_or("slice", cfg.slice).map_err(|e| anyhow!(e))?;
    cfg.pipeline.window_lines = args
        .usize_or("window", cfg.pipeline.window_lines)
        .map_err(|e| anyhow!(e))?;
    cfg.pipeline.executor_threads = args
        .usize_or("executor-threads", cfg.pipeline.executor_threads)
        .map_err(|e| anyhow!(e))?
        .max(1);
    if let Some(t) = args.opt("host-threads") {
        cfg.pipeline.host_threads = Some(t.parse::<usize>().context("--host-threads")?.max(1));
    }
    // The single thread-budget knob: size the shared host pool before
    // anything (backend construction, executor stages) first uses it.
    if let Some(n) = cfg.pipeline.host_threads {
        let got = pdfflow::runtime::hostpool::configure(n);
        if got != n {
            eprintln!("note: host pool already sized at {got} threads (requested {n})");
        }
    }
    match args.opt("cluster") {
        Some("lncc") => cfg.cluster = ClusterSpec::lncc(),
        Some("local") => cfg.cluster = ClusterSpec::local(4),
        Some("g5k") | None => {
            if let Some(n) = args.opt("nodes") {
                cfg.cluster = ClusterSpec::g5k(n.parse().context("--nodes")?);
            }
        }
        Some(other) => return Err(anyhow!("unknown --cluster {other:?}")),
    }
    if let Some(b) = args.opt("backend") {
        cfg.backend = BackendKind::resolve(Some(b))?;
    }
    if let Some(r) = args.opt("run-id") {
        validate_run_id(r)?;
        cfg.pipeline.run_id = Some(r.to_string());
    }
    // Arm configured fault injection (the PDFFLOW_FAULTS env, resolved
    // lazily by the fault module, takes precedence over the config key).
    if let Some(spec) = &cfg.faults {
        if std::env::var_os("PDFFLOW_FAULTS").is_none() {
            pdfflow::fault::install(spec).context("faults.spec")?;
        }
    }
    Ok(cfg)
}

/// Backend for subcommands that run outside an ExperimentConfig
/// (figures): --backend flag > PDFFLOW_BACKEND > native.
fn backend_kind_of(args: &Args) -> Result<BackendKind> {
    Ok(BackendKind::resolve(args.opt("backend"))?)
}

fn types_of(args: &Args) -> Result<TypeSet> {
    match args.opt_or("types", "4").as_str() {
        "4" => Ok(TypeSet::Four),
        "10" => Ok(TypeSet::Ten),
        other => Err(anyhow!("--types must be 4 or 10, got {other:?}")),
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(args),
        Some("run") => cmd_run(args),
        Some("sample") => cmd_sample(args),
        Some("features") => cmd_features(args),
        Some("train-tree") => cmd_train_tree(args),
        Some("tune-window") => cmd_tune_window(args),
        Some("qoi") => cmd_qoi(args),
        Some("figure") => cmd_figure(args),
        Some("artifacts-check") => cmd_artifacts_check(args),
        Some("store") => cmd_store(args),
        Some("query") => cmd_query(args),
        Some("serve") => cmd_serve(args),
        Some("telemetry") => cmd_telemetry(args),
        Some(other) => Err(anyhow!("unknown subcommand {other:?} (see --help in README)")),
        None => {
            println!("pdfflow — parallel computation of PDFs on big spatial data");
            println!("subcommands: generate run sample features train-tree tune-window qoi figure artifacts-check store query serve telemetry");
            Ok(())
        }
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let t0 = std::time::Instant::now();
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    println!(
        "dataset {} at {}: {} files, {} ({} points x {} observations) in {}",
        cfg.name,
        cfg.data_dir,
        ds.files.len(),
        fmt_bytes(ds.total_bytes()),
        ds.spec.dims.n_points(),
        ds.spec.n_sims,
        fmt_secs(t0.elapsed().as_secs_f64()),
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    if let Some(d) = &cfg.pipeline.store_dir {
        flight::set_dump_dir(d);
    }
    let method = Method::from_name(&args.opt_or("method", "baseline"))
        .ok_or_else(|| anyhow!("unknown --method (one of: baseline grouping reuse ml grouping+ml reuse+ml)"))?;
    let types = types_of(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), cfg.pipeline.clone());
    let lines = args.usize_or("lines", 0).map_err(|e| anyhow!(e))?;
    let r = if lines > 0 {
        if method.uses_ml() {
            let err = pipe.ensure_tree(cfg.train_slice, types, 25_000)?;
            println!("decision tree trained on slice {} (model error {err:.4})", cfg.train_slice);
        }
        pipe.run_lines(method, cfg.slice, types, lines)?
    } else {
        // Full-slice runs overlap any needed tree training with the
        // first-window cache warm-up on the shared host pool.
        let r = pipe.run_slice_overlapped(method, cfg.slice, types, cfg.train_slice, 25_000)?;
        if let Some(err) = pipe.model_error {
            println!(
                "decision tree trained on slice {} (model error {err:.4}, overlapped with first-window loads{})",
                cfg.train_slice,
                if pipe.tree_from_store { ", labels read from store" } else { "" }
            );
        }
        r
    };
    // Deterministic result witness: printed here and stamped into any
    // --metrics-out snapshot (provenance.report_fingerprint), so perf
    // before/after pairs can prove the results didn't change.
    let fp = r.fingerprint();
    pdfflow::telemetry::export::set_report_fingerprint(fp);
    println!("{}", r.row());
    println!("report fingerprint {fp:016x}");
    println!(
        "slice {} ({} points, {} windows) on {} ({} nodes x {} cores), {} backend",
        r.slice,
        r.n_points,
        r.windows.len(),
        cfg.cluster.name,
        cfg.cluster.nodes,
        cfg.cluster.cores_per_node,
        backend.name()
    );
    if args.flag("verbose") {
        for (k, v) in pipe.cluster.breakdown() {
            println!("  sim {k:<14} {}", fmt_secs(v));
        }
        let p = pdfflow::runtime::HostPool::global().metrics();
        print!(
            "{}",
            render_text(&[Section::Stage("window", &r.exec), Section::Pool(&p)])
        );
    }
    write_metrics_if_asked(args)?;
    Ok(())
}

/// Shared `--metrics-out PATH` handling: write the JSON snapshot at
/// PATH and the Prometheus text rendering at PATH.prom.
fn write_metrics_if_asked(args: &Args) -> Result<()> {
    if let Some(out) = args.opt("metrics-out") {
        let (json_path, prom_path) = pdfflow::telemetry::export::write_metrics(out)?;
        println!(
            "metrics written to {} and {}",
            json_path.display(),
            prom_path.display()
        );
    }
    Ok(())
}

fn cmd_sample(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rate = args.f64_or("rate", 0.1).map_err(|e| anyhow!(e))?;
    let sampler = match args.opt_or("sampler", "random").as_str() {
        "random" => Sampler::Random,
        "kmeans" => Sampler::KMeans,
        other => return Err(anyhow!("unknown --sampler {other:?}")),
    };
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), cfg.pipeline.clone());
    pipe.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
    let tree = pipe.tree.clone().unwrap();
    let reader = DatasetReader::new(&ds);
    let cache = WindowCache::new(cfg.pipeline.cache_bytes);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let rep = run_sampling(
        &reader, &cache, backend.as_ref(), &cluster, &tree, cfg.slice, rate, sampler, 42,
    )?;
    println!(
        "sampling {} rate {}: {} points, load {} (sim {}), compute {} (sim {})",
        sampler.name(),
        rate,
        rep.n_sampled,
        fmt_secs(rep.load_real_s),
        fmt_secs(rep.load_sim_s),
        fmt_secs(rep.compute_real_s),
        fmt_secs(rep.compute_sim_s),
    );
    print_features(&rep.features);
    Ok(())
}

fn print_features(f: &pdfflow::sampling::SliceFeatures) {
    println!("avg mean {:.3}  avg std {:.3}  ({} points)", f.avg_mean, f.avg_std, f.n_points);
    for (i, pct) in f.type_percentages.iter().enumerate() {
        if *pct > 0.0 {
            println!(
                "  {:<12} {:>6.2}%",
                pdfflow::stats::DistType::from_id(i).unwrap().name(),
                pct * 100.0
            );
        }
    }
}

fn cmd_features(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), cfg.pipeline.clone());
    pipe.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
    let tree = pipe.tree.clone().unwrap();
    let reader = DatasetReader::new(&ds);
    let cache = WindowCache::new(cfg.pipeline.cache_bytes);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let f = full_slice_features(&reader, &cache, backend.as_ref(), &cluster, &tree, cfg.slice)?;
    println!("slice {} features:", cfg.slice);
    print_features(&f);
    Ok(())
}

fn cmd_train_tree(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let types = types_of(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let reader = DatasetReader::new(&ds);
    let cache = WindowCache::new(cfg.pipeline.cache_bytes);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let slices = mlmodel::training_slices(&ds.spec.dims, cfg.train_slice, ds.spec.n_value_layers());
    // Store-backed training: when the configured store already holds a
    // matching full-fit run, read the "previous output" instead of
    // refitting it.
    let label_engine = mlmodel::store_label_engine(
        cfg.pipeline.store_dir.as_deref(),
        &ds.spec.dims,
        ds.spec.n_sims,
        &slices,
        types,
    );
    let labels = match &label_engine {
        Some(e) => mlmodel::LabelSource::Store(e),
        None => mlmodel::LabelSource::Refit,
    };
    let data = mlmodel::build_training_data(
        &reader,
        &cache,
        backend.as_ref(),
        &cluster,
        &ds.spec.dims,
        &slices,
        types,
        25_000,
        cfg.pipeline.window_lines,
        labels,
    )?;
    println!(
        "training data: {} samples from slice {} ({} {} the previous output)",
        data.samples.len(),
        cfg.train_slice,
        fmt_secs(data.generation_real_s),
        if data.from_store {
            "reading back"
        } else {
            "generating"
        },
    );
    let params = if args.flag("tune") {
        let (params, err, secs) = mlmodel::tune_hypers(&data, 42)?;
        println!(
            "tuned: depth={} maxBins={} (validation error {err:.4}, {})",
            params.max_depth,
            params.max_bins,
            fmt_secs(secs)
        );
        params
    } else {
        Default::default()
    };
    let model = mlmodel::train_model(&data, params, 43)?;
    println!(
        "model error {:.4} (train {} / test {}, {} nodes, depth {}, trained in {})",
        model.model_error,
        model.n_train,
        model.n_test,
        model.tree.n_nodes(),
        model.tree.depth(),
        fmt_secs(model.train_real_s)
    );
    if let Some(path) = args.opt("out") {
        std::fs::write(path, model.tree.to_json().to_string())?;
        println!("tree written to {path}");
    }
    Ok(())
}

fn cmd_tune_window(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let sizes: Vec<usize> = args
        .list_or("sizes", &["2", "4", "8", "16", "25"])
        .iter()
        .map(|s| s.parse().context("--sizes"))
        .collect::<Result<_>>()?;
    println!("{:<8} {:>16} {:>16}", "window", "fit/line(sim)", "fit/line(real)");
    let mut best = (0usize, f64::INFINITY);
    for w in sizes {
        if 2 * w > ds.spec.dims.ny {
            continue;
        }
        let mut pcfg = cfg.pipeline.clone();
        pcfg.window_lines = w;
        let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), pcfg);
        let r = pipe.run_lines(Method::Grouping, cfg.slice, TypeSet::Four, 2 * w)?;
        let per_line = r.fit_sim_s / (2 * w) as f64;
        println!(
            "{:<8} {:>16} {:>16}",
            w,
            fmt_secs(per_line),
            fmt_secs(r.fit_real_s / (2 * w) as f64)
        );
        if per_line < best.1 {
            best = (w, per_line);
        }
    }
    println!("optimal window: {} lines ({} per line)", best.0, fmt_secs(best.1));
    Ok(())
}

/// The paper's §1 deliverable: fit the best PDF per point, extract the
/// maximum-possibility QOI value and the uncertainty summary.
fn cmd_qoi(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let types = types_of(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), cfg.pipeline.clone());
    pipe.ensure_tree(cfg.train_slice, types, 25_000)?;
    let lines = args.usize_or("lines", 2).map_err(|e| anyhow!(e))?;
    let r = pipe.run_lines(pdfflow::coordinator::Method::GroupingMl, cfg.slice, types, lines)?;
    println!(
        "slice {} ({} points, E={:.4}) — QOI summary of the first points:",
        cfg.slice, r.n_points, r.avg_error
    );
    println!(
        "{:<8} {:<12} {:>12} {:>12} {:>10}",
        "point", "type", "qoi", "peak pdf", "fit err"
    );
    // Recompute the first window to pair outcomes with ids (run_lines
    // aggregates; here we show the per-point view the paper motivates).
    let w = r.windows[0].window;
    let reader = DatasetReader::new(&ds);
    let cache = WindowCache::new(cfg.pipeline.cache_bytes);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let lw = pdfflow::coordinator::loader::load_window(&reader, &cache, backend.as_ref(), &cluster, w)?;
    let show = lw.n_points().min(12);
    let out = backend.run_fit_all(
        &lw.obs.data[..show * lw.obs.n_obs],
        show,
        lw.obs.n_obs,
        types.n_types(),
    )?;
    for p in 0..out.n_rows {
        let row = out.row(p);
        let fit = pdfflow::stats::FitResult {
            dist: pdfflow::stats::DistType::from_id(row[0] as usize).unwrap(),
            params: [row[2] as f64, row[3] as f64, row[4] as f64],
            error: row[1] as f64,
        };
        let q = pdfflow::stats::density::qoi(&fit);
        println!(
            "{:<8} {:<12} {:>12.2} {:>12.5} {:>10.4}",
            lw.obs.point_ids[p].0, q.dist.name(), q.value, q.peak_density, q.fit_error
        );
    }
    Ok(())
}

/// `pdfflow store compact`: collapse a run's generations into one dense
/// segment per slice (query results bit-identical; old files retired).
fn cmd_store_compact(args: &Args) -> Result<()> {
    let store_dir = args
        .opt("store-dir")
        .ok_or_else(|| anyhow!("store compact needs --store-dir DIR"))?;
    let t0 = std::time::Instant::now();
    let rep = compact_run(store_dir, args.opt("run"))?;
    if rep.already_compact {
        println!(
            "run {} already compact: {} slice(s), {} segment(s), {} (generation {})",
            rep.run.label(),
            rep.slices,
            rep.segments_after,
            fmt_bytes(rep.bytes_after),
            rep.gen,
        );
        return Ok(());
    }
    println!(
        "compacted run {} to generation {} in {}: {} → {} segment(s), {} → {} on disk, \
         {} records, {} file(s) retired",
        rep.run.label(),
        rep.gen,
        fmt_secs(t0.elapsed().as_secs_f64()),
        rep.segments_before,
        rep.segments_after,
        fmt_bytes(rep.bytes_before),
        fmt_bytes(rep.bytes_after),
        rep.records,
        rep.retired_files,
    );
    Ok(())
}

/// `pdfflow store verify`: full-payload checksum verification of every
/// segment of one run, printed one line per segment; exit nonzero when
/// anything failed.
fn cmd_store_verify(args: &Args) -> Result<()> {
    let store_dir = args
        .opt("store-dir")
        .ok_or_else(|| anyhow!("store verify needs --store-dir DIR"))?;
    let store = PdfStore::open_run_tolerant(store_dir, RunSelector::from_opt(args.opt("run")))?;
    let report = store.verify_report();
    print!("{}", report.render());
    if report.all_ok() {
        println!(
            "run {}: all {} segment(s) verified",
            store.run_key().label(),
            report.segments.len()
        );
        Ok(())
    } else {
        Err(anyhow!(
            "run {}: {} of {} segment(s) failed verification",
            store.run_key().label(),
            report.n_bad(),
            report.segments.len()
        ))
    }
}

/// `pdfflow store scrub [--repair]`: sweep every run of the catalog,
/// quarantine corrupt segments, and (with --repair) rewrite salvageable
/// runs from the surviving generations via the compaction path. Exit
/// nonzero while damage remains.
fn cmd_store_scrub(args: &Args) -> Result<()> {
    let store_dir = args
        .opt("store-dir")
        .ok_or_else(|| anyhow!("store scrub needs --store-dir DIR"))?;
    flight::set_dump_dir(store_dir);
    let t0 = std::time::Instant::now();
    let report = pdfflow::pdfstore::scrub_store(store_dir, args.flag("repair"))?;
    print!("{}", report.render());
    println!(
        "scrubbed {} run(s) in {}: {} bad segment(s)",
        report.runs.len(),
        fmt_secs(t0.elapsed().as_secs_f64()),
        report.total_bad(),
    );
    if report.needs_attention() {
        Err(anyhow!(if args.flag("repair") {
            "store damage remains (coverage lost; re-persist the affected runs)"
        } else {
            "store has corrupt segments (rerun with --repair to rewrite salvageable runs)"
        }))
    } else {
        Ok(())
    }
}

/// Run the pipeline with the pdfstore persist sink and report the
/// resulting store (Algorithm 1's persist phase, made queryable).
fn cmd_store(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("compact") => return cmd_store_compact(args),
        Some("verify") => return cmd_store_verify(args),
        Some("scrub") => return cmd_store_scrub(args),
        _ => {}
    }
    let mut cfg = load_config(args)?;
    let store_dir = args
        .opt("store-dir")
        .map(|s| s.to_string())
        .or_else(|| cfg.pipeline.store_dir.clone())
        .ok_or_else(|| anyhow!("store needs --store-dir DIR (or pipeline.store_dir in --config)"))?;
    cfg.pipeline.store_dir = Some(store_dir.clone());
    flight::set_dump_dir(&store_dir);
    let method = Method::from_name(&args.opt_or("method", "baseline"))
        .ok_or_else(|| anyhow!("unknown --method (one of: baseline grouping reuse ml grouping+ml reuse+ml)"))?;
    let types = types_of(args)?;
    let ds = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipe = Pipeline::new(&ds, backend.as_ref(), SimCluster::new(cfg.cluster.clone()), cfg.pipeline.clone());
    if method.uses_ml() {
        let err = pipe.ensure_tree(cfg.train_slice, types, 25_000)?;
        println!("decision tree trained on slice {} (model error {err:.4})", cfg.train_slice);
    }
    let lines = args.usize_or("lines", 0).map_err(|e| anyhow!(e))?;
    let r = if lines > 0 {
        pipe.run_lines(method, cfg.slice, types, lines)?
    } else {
        pipe.run_slice(method, cfg.slice, types)?
    };
    println!("{}", r.row());
    println!(
        "persist: {} in {} windows, sim {}",
        fmt_bytes(r.persist_bytes),
        r.windows.len(),
        fmt_secs(r.persist_sim_s)
    );
    let store = PdfStore::open(&store_dir)?;
    println!(
        "store {} run {}: {} segment(s) in {} generation(s), {} records, {} on disk (catalog verified)",
        store_dir,
        store.run_key().label(),
        store.n_segments(),
        store.run().n_generations(),
        store.n_records(),
        fmt_bytes(store.total_bytes()),
    );
    Ok(())
}

/// Parse "x,y,z" into a coordinate triple.
fn parse_point(s: &str) -> Result<(usize, usize, usize)> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse().context("--point"))
        .collect::<Result<_>>()?;
    if parts.len() != 3 {
        return Err(anyhow!("--point expects x,y,z, got {s:?}"));
    }
    Ok((parts[0], parts[1], parts[2]))
}

/// Parse "z", "z,y0,y1" or "z,y0,y1,x0,x1" into a region (inclusive
/// bounds; omitted axes span the whole slice).
fn parse_region(s: &str, dims: &pdfflow::cube::CubeDims) -> Result<RegionQuery> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse().context("--region"))
        .collect::<Result<_>>()?;
    let mut q = match parts.len() {
        1 | 3 | 5 => RegionQuery::slice(dims, parts[0]),
        _ => return Err(anyhow!("--region expects z[,y0,y1[,x0,x1]], got {s:?}")),
    };
    if parts.len() >= 3 {
        q.y0 = parts[1];
        q.y1 = parts[2];
    }
    if parts.len() == 5 {
        q.x0 = parts[3];
        q.x1 = parts[4];
    }
    Ok(q)
}

/// Parse "z0,z1", "z0,z1,y0,y1" or "z0,z1,y0,y1,x0,x1" into a 3D box
/// (inclusive bounds; omitted axes span the whole cube).
fn parse_box(s: &str, dims: &pdfflow::cube::CubeDims) -> Result<BoxQuery> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse().context("--box"))
        .collect::<Result<_>>()?;
    let mut q = BoxQuery::whole(dims);
    match parts.len() {
        2 | 4 | 6 => {
            q.z0 = parts[0];
            q.z1 = parts[1];
        }
        _ => return Err(anyhow!("--box expects z0,z1[,y0,y1[,x0,x1]], got {s:?}")),
    }
    if parts.len() >= 4 {
        q.y0 = parts[2];
        q.y1 = parts[3];
    }
    if parts.len() == 6 {
        q.x0 = parts[4];
        q.x1 = parts[5];
    }
    Ok(q)
}

/// Parse "x,y,z,r" into a radius query (r may be fractional).
fn parse_radius(s: &str) -> Result<RadiusQuery> {
    let parts: Vec<&str> = s.split(',').map(|p| p.trim()).collect();
    if parts.len() != 4 {
        return Err(anyhow!("--radius expects x,y,z,r, got {s:?}"));
    }
    Ok(RadiusQuery {
        x: parts[0].parse().context("--radius x")?,
        y: parts[1].parse().context("--radius y")?,
        z: parts[2].parse().context("--radius z")?,
        radius: parts[3].parse().context("--radius r")?,
    })
}

/// Parse "x,y,z,k" into a k-nearest-neighbor query.
fn parse_knn(s: &str) -> Result<KnnQuery> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse().context("--knn"))
        .collect::<Result<_>>()?;
    if parts.len() != 4 {
        return Err(anyhow!("--knn expects x,y,z,k, got {s:?}"));
    }
    Ok(KnnQuery {
        x: parts[0],
        y: parts[1],
        z: parts[2],
        k: parts[3],
    })
}

/// Parse "sx,sy,sz" into spatial-grid cell sides.
fn parse_cells(s: &str) -> Result<[usize; 3]> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse().context("--cells"))
        .collect::<Result<_>>()?;
    if parts.len() != 3 || parts.contains(&0) {
        return Err(anyhow!("--cells expects positive sx,sy,sz, got {s:?}"));
    }
    Ok([parts[0], parts[1], parts[2]])
}

/// Serve point / region / analytical queries from an existing store.
fn cmd_query(args: &Args) -> Result<()> {
    let store_dir = args
        .opt("store-dir")
        .ok_or_else(|| anyhow!("query needs --store-dir DIR"))?;
    flight::set_dump_dir(store_dir);
    let file_cfg = match args.opt("config") {
        Some(path) => Some(ExperimentConfig::from_file(path).context("loading --config")?),
        None => None,
    };
    // The single budget knob applies to the query fan-out too:
    // --host-threads > pipeline.host_threads (--config) > env > cores.
    let host_threads = match args.opt("host-threads") {
        Some(t) => Some(t.parse::<usize>().context("--host-threads")?.max(1)),
        None => file_cfg.as_ref().and_then(|c| c.pipeline.host_threads),
    };
    if let Some(n) = host_threads {
        let got = pdfflow::runtime::hostpool::configure(n);
        if got != n {
            eprintln!("note: host pool already sized at {got} threads (requested {n})");
        }
    }
    // Cache budget precedence: --cache-mb flag > pipeline.query_cache_bytes
    // from --config > 64 MiB default.
    let cache_bytes = if let Some(mb) = args.opt("cache-mb") {
        mb.parse::<u64>().context("--cache-mb")? << 20
    } else if let Some(cfg) = &file_cfg {
        cfg.pipeline.query_cache_bytes
    } else {
        64 << 20
    };
    let threads = args
        .usize_or("threads", pdfflow::runtime::hostpool::default_budget())
        .map_err(|e| anyhow!(e))?;
    let quantile: Option<f64> = match args.opt("quantile") {
        Some(qs) => Some(qs.parse().context("--quantile")?),
        None => None,
    };
    let cell = match args.opt("cells") {
        Some(c) => Some(parse_cells(c)?),
        None => None,
    };
    let opts = QueryOptions {
        cache_bytes,
        workers: threads,
        cell,
        ..QueryOptions::default()
    };
    let engine = QueryEngine::open_run(store_dir, RunSelector::from_opt(args.opt("run")), opts)?;
    let dims = engine.dims();
    println!(
        "store {} run {}: {}x{}x{} cube, {} observations, {} segment(s) in {} generation(s), {} records, {}",
        store_dir,
        engine.store().run_key().label(),
        dims.nx,
        dims.ny,
        dims.nz,
        engine.store().n_obs(),
        engine.store().n_segments(),
        engine.store().run().n_generations(),
        engine.store().n_records(),
        fmt_bytes(engine.store().total_bytes()),
    );
    if args.flag("verify") {
        let report = engine.store().verify_report();
        print!("{}", report.render());
        if !report.all_ok() {
            return Err(anyhow!(
                "{} of {} segment(s) failed verification",
                report.n_bad(),
                report.segments.len()
            ));
        }
        println!("all {} segment checksum(s) verified", report.segments.len());
    }
    if let Some(p) = args.opt("point") {
        let (x, y, z) = parse_point(p)?;
        let rec = engine.point(x, y, z)?;
        let q = pdfflow::stats::density::qoi(&rec.fit());
        println!(
            "point ({x},{y},{z}) id {}: {} params [{:.5}, {:.5}, {:.5}]  fit err {:.4}",
            rec.point.0,
            rec.dist.name(),
            rec.params[0],
            rec.params[1],
            rec.params[2],
            rec.error,
        );
        println!(
            "  qoi {:.4} (peak density {:.5})  q25 {:.4}  q50 {:.4}  q75 {:.4}",
            q.value,
            q.peak_density,
            engine.quantile_of(&rec, 0.25),
            engine.quantile_of(&rec, 0.50),
            engine.quantile_of(&rec, 0.75),
        );
        if let Some(p) = quantile {
            println!("  P{:.0} {:.4}", p * 100.0, engine.quantile_of(&rec, p));
        }
    }
    if let Some(r) = args.opt("region") {
        let q = parse_region(r, &dims)?;
        let t0 = std::time::Instant::now();
        let s = engine.region_summary(&q)?;
        println!(
            "region z={} y[{},{}] x[{},{}]: {} points, avg E {:.4}, max E {:.4} ({})",
            q.z,
            q.y0,
            q.y1,
            q.x0,
            q.x1,
            s.n_points,
            s.avg_error,
            s.max_error,
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        for (i, &n) in s.type_counts.iter().enumerate() {
            if n > 0 {
                println!(
                    "  {:<12} {:>8} ({:>6.2}%)",
                    pdfflow::stats::DistType::from_id(i).unwrap().name(),
                    n,
                    100.0 * n as f64 / s.n_points.max(1) as f64
                );
            }
        }
        if let Some(p) = quantile {
            let mean_q = engine.region_quantile_mean(&q, p)?;
            println!("  mean P{:.0} over region: {:.4}", p * 100.0, mean_q);
        }
    }
    if let Some(b) = args.opt("box") {
        let q = parse_box(b, &dims)?;
        let t0 = std::time::Instant::now();
        let s = engine.box_summary(&q)?;
        println!(
            "box z[{},{}] y[{},{}] x[{},{}]: {} points, avg E {:.4}, max E {:.4} ({})",
            q.z0,
            q.z1,
            q.y0,
            q.y1,
            q.x0,
            q.x1,
            s.n_points,
            s.avg_error,
            s.max_error,
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        for (i, &n) in s.type_counts.iter().enumerate() {
            if n > 0 {
                println!(
                    "  {:<12} {:>8} ({:>6.2}%)",
                    pdfflow::stats::DistType::from_id(i).unwrap().name(),
                    n,
                    100.0 * n as f64 / s.n_points.max(1) as f64
                );
            }
        }
        if args.flag("agg") {
            let grid = engine.spatial_index().grid();
            let agg = engine.cell_aggregate(&q)?;
            println!(
                "cell aggregation ({}x{}x{} cells of {}x{}x{} points): {} non-empty, {} boundary",
                grid.ncx(),
                grid.ncy(),
                grid.ncz(),
                grid.sx,
                grid.sy,
                grid.sz,
                agg.cells.len(),
                agg.boundary.len(),
            );
            for c in &agg.cells {
                println!(
                    "  cell ({},{},{}): {} points, dominant {}, mean E {:.4}, max E {:.4}",
                    c.cell.0,
                    c.cell.1,
                    c.cell.2,
                    c.n_points,
                    c.dominant.name(),
                    c.mean_error(),
                    c.max_error,
                );
            }
        }
    }
    if let Some(r) = args.opt("radius") {
        let q = parse_radius(r)?;
        let t0 = std::time::Instant::now();
        let recs = engine.radius_records(&q)?;
        println!(
            "radius {} around ({},{},{}): {} records ({})",
            q.radius,
            q.x,
            q.y,
            q.z,
            recs.len(),
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        for rec in recs.iter().take(8) {
            let (x, y, z) = dims.coords(rec.point);
            println!(
                "  ({x},{y},{z}) id {}: {} fit err {:.4}",
                rec.point.0,
                rec.dist.name(),
                rec.error
            );
        }
        if recs.len() > 8 {
            println!("  ... {} more", recs.len() - 8);
        }
    }
    if let Some(kq) = args.opt("knn") {
        let q = parse_knn(kq)?;
        let t0 = std::time::Instant::now();
        let recs = engine.knn(&q)?;
        println!(
            "{} nearest records around ({},{},{}) ({}):",
            recs.len(),
            q.x,
            q.y,
            q.z,
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        for rec in &recs {
            let (x, y, z) = dims.coords(rec.point);
            let d2 = pdfflow::spatial::dist2((x, y, z), (q.x, q.y, q.z));
            println!(
                "  ({x},{y},{z}) id {} d {:.3}: {} fit err {:.4}",
                rec.point.0,
                (d2 as f64).sqrt(),
                rec.dist.name(),
                rec.error
            );
        }
    }
    if let Some(other_id) = args.opt("diff-run") {
        let other = QueryEngine::open_run(store_dir, RunSelector::Id(other_id), opts)?;
        let q = match args.opt("box") {
            Some(b) => parse_box(b, &dims)?,
            None => BoxQuery::whole(&dims),
        };
        let t0 = std::time::Instant::now();
        let d = engine.diff_run(&other, &q)?;
        println!(
            "diff run {} vs {} over z[{},{}] y[{},{}] x[{},{}] ({}):",
            engine.store().run_key().label(),
            other.store().run_key().label(),
            q.z0,
            q.z1,
            q.y0,
            q.y1,
            q.x0,
            q.x1,
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        println!(
            "  {} compared ({} only here, {} only there), {} type changes in {} cell(s), \
             mean |ΔE| {:.5}, max |ΔE| {:.5}",
            d.n_compared,
            d.only_a,
            d.only_b,
            d.type_changed,
            d.changed_cells.len(),
            d.mean_err_delta(),
            d.max_err_delta,
        );
        for &(cx, cy, cz) in d.changed_cells.iter().take(8) {
            println!("  changed cell ({cx},{cy},{cz})");
        }
    }
    let m = engine.meters();
    print!(
        "{}",
        render_text(&[Section::Cache(
            "cache",
            CacheLine {
                hits: m.hits,
                misses: m.misses,
                evictions: m.evictions,
                bytes: m.bytes,
                entries: m.entries,
            },
        )])
    );
    Ok(())
}

/// Closed-loop load through the admission-controlled serving tier.
///
/// Three modes:
/// * default — in-process: `--clients` synchronous clients drive the
///   request mix straight against one `ServeFront`;
/// * `--listen ADDR` — the same front behind the TCP socket endpoint;
///   `--clients 0` serves until a wire `shutdown` frame arrives, any
///   other count self-drives the closed loop over real loopback
///   connections (wire encode/decode in every measured latency);
/// * `--connect ADDR` — pure client: drive a remote server, no store
///   opened locally; `--shutdown` asks the server to stop afterwards.
///
/// `--bench` upserts the serving row into BENCH_queries.json next to
/// the raw engine rows (socket-driven when `--listen` is active).
fn cmd_serve(args: &Args) -> Result<()> {
    let defaults = ServeOptions::default();
    let max_in_flight = args
        .usize_or("max-in-flight", defaults.max_in_flight)
        .map_err(|e| anyhow!(e))?
        .max(1);
    let queue_depth = args
        .usize_or("queue-depth", 2 * max_in_flight)
        .map_err(|e| anyhow!(e))?;
    let clients_raw = args
        .usize_or("clients", 2 * (max_in_flight + queue_depth))
        .map_err(|e| anyhow!(e))?;
    let total = args.usize_or("queries", 20_000).map_err(|e| anyhow!(e))?;

    if let Some(addr) = args.opt("connect") {
        // Client mode: everything lives on the server side.
        let clients = clients_raw.max(1);
        let per_client = total.div_ceil(clients).max(1);
        let rep = closed_loop_net(addr, clients, per_client, 42)?;
        println!(
            "drove {} over {} connections: {} ok / {} shed / {} errors of {} in {} — {:.0} q/s",
            addr,
            rep.clients,
            rep.completed,
            rep.shed,
            rep.errors,
            rep.requests,
            fmt_secs(rep.secs),
            rep.throughput,
        );
        if args.flag("shutdown") {
            Client::connect(addr)?.shutdown_server()?;
            println!("server at {addr} acknowledged shutdown");
        }
        return Ok(());
    }

    let store_dir = args
        .opt("store-dir")
        .ok_or_else(|| anyhow!("serve needs --store-dir DIR (or --connect ADDR)"))?;
    flight::set_dump_dir(store_dir);
    if let Some(t) = args.opt("host-threads") {
        let n = t.parse::<usize>().context("--host-threads")?.max(1);
        let got = pdfflow::runtime::hostpool::configure(n);
        if got != n {
            eprintln!("note: host pool already sized at {got} threads (requested {n})");
        }
    }
    let cache_bytes = match args.opt("cache-mb") {
        Some(mb) => mb.parse::<u64>().context("--cache-mb")? << 20,
        None => 64 << 20,
    };
    // Serving defaults to the zero-copy mmap read path (PDFFLOW_READ_PATH
    // still wins when set); batch `query` keeps the block cache default.
    let read_path = match args.opt("read-path") {
        Some(s) => ReadPath::parse(s)
            .ok_or_else(|| anyhow!("--read-path must be `mmap` or `cached`, got {s:?}"))?,
        None => ReadPath::Mmap,
    };
    let result_cache_bytes = match args.opt("result-cache-mb") {
        Some(mb) => mb.parse::<u64>().context("--result-cache-mb")? << 20,
        None => pdfflow::serve::rescache::DEFAULT_RESULT_CACHE_BYTES,
    };

    let engine = QueryEngine::open_run(
        store_dir,
        RunSelector::from_opt(args.opt("run")),
        QueryOptions {
            cache_bytes,
            read_path,
            ..QueryOptions::default()
        },
    )?;
    println!(
        "serving store {} run {}: {} records, caps {} in-flight / {} queued, read path {:?}, result cache {} MiB",
        store_dir,
        engine.store().run_key().label(),
        engine.store().n_records(),
        max_in_flight,
        queue_depth,
        engine.read_path(),
        result_cache_bytes >> 20,
    );
    let front = ServeFront::new(
        engine,
        ServeOptions {
            max_in_flight,
            queue_depth,
        },
    )
    .with_result_cache(result_cache_bytes);
    // Publish the per-class latency/queue histograms so --metrics-out
    // snapshots carry the full serve distribution, not just the table.
    front.register_metrics();

    if let Some(listen) = args.opt("listen") {
        let front = std::sync::Arc::new(front);
        let server = NetServer::start(
            std::sync::Arc::clone(&front),
            listen,
            NetOptions {
                workers: max_in_flight,
                queue_depth,
            },
        )?;
        let addr = server.addr();
        println!("listening on {addr}");
        if clients_raw == 0 {
            // Serve until a client sends the wire `shutdown` frame.
            server.wait();
            println!("shutdown frame received, drained and stopped");
        } else {
            let per_client = total.div_ceil(clients_raw).max(1);
            let rep = closed_loop_net(&addr.to_string(), clients_raw, per_client, 42)?;
            server.join();
            println!(
                "served {} of {} socket requests in {} — {:.0} q/s, {} shed on wire",
                rep.completed,
                rep.requests,
                fmt_secs(rep.secs),
                rep.throughput,
                rep.shed,
            );
            if args.flag("bench") {
                let m = front.metrics();
                let path = pdfflow::bench::upsert_bench_row(
                    "queries",
                    "serve",
                    pdfflow::bench::BenchRow {
                        threads: rep.clients,
                        throughput: rep.throughput,
                        extra: vec![
                            ("transport", pdfflow::util::json::Json::Str("socket".into())),
                            ("shed", pdfflow::util::json::Json::Num(m.total_shed() as f64)),
                            (
                                "max_in_flight",
                                pdfflow::util::json::Json::Num(max_in_flight as f64),
                            ),
                            (
                                "queue_depth",
                                pdfflow::util::json::Json::Num(queue_depth as f64),
                            ),
                        ],
                    },
                )?;
                println!("serving row recorded in {}", path.display());
            }
        }
        print!("{}", render_text(&[Section::Serve(&front.metrics())]));
        if let Some(stats) = front.result_cache().map(|c| c.stats()) {
            println!(
                "result cache: {} hits / {} misses, {} entries, {} invalidations",
                stats.hits, stats.misses, stats.entries, stats.invalidations,
            );
        }
        write_metrics_if_asked(args)?;
        return Ok(());
    }

    let clients = clients_raw.max(1);
    let per_client = total.div_ceil(clients).max(1);
    let rep = closed_loop(&front, clients, per_client, 42);
    let m = &rep.metrics;
    println!(
        "served {} of {} requests in {} — {:.0} q/s, {} shed, peaks {} in-flight / {} queued",
        m.total_completed(),
        rep.requests,
        fmt_secs(rep.secs),
        rep.throughput,
        m.total_shed(),
        m.peak_in_flight,
        m.peak_queued,
    );
    print!("{}", render_text(&[Section::Serve(m)]));
    if let Some(stats) = front.result_cache().map(|c| c.stats()) {
        println!(
            "result cache: {} hits / {} misses, {} entries, {} invalidations",
            stats.hits, stats.misses, stats.entries, stats.invalidations,
        );
    }
    if args.flag("bench") {
        let path = pdfflow::bench::upsert_bench_row(
            "queries",
            "serve",
            pdfflow::bench::BenchRow {
                threads: clients,
                throughput: rep.throughput,
                extra: vec![
                    ("transport", pdfflow::util::json::Json::Str("inproc".into())),
                    ("shed", pdfflow::util::json::Json::Num(m.total_shed() as f64)),
                    (
                        "max_in_flight",
                        pdfflow::util::json::Json::Num(max_in_flight as f64),
                    ),
                    (
                        "queue_depth",
                        pdfflow::util::json::Json::Num(queue_depth as f64),
                    ),
                ],
            },
        )?;
        println!("serving row recorded in {}", path.display());
    }
    write_metrics_if_asked(args)?;
    Ok(())
}

/// `pdfflow telemetry validate <snapshot.json>`: re-parse an exported
/// metrics snapshot against the `pdfflow.telemetry.v1` schema — the CI
/// gate that keeps exporter and consumers honest.
fn cmd_telemetry(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("validate") => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: pdfflow telemetry validate <snapshot.json>"))?;
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let j = pdfflow::util::json::Json::parse(&text)
                .map_err(|e| anyhow!("parsing {path}: {e}"))?;
            let n = pdfflow::telemetry::export::validate_snapshot(&j)?;
            println!(
                "{path}: valid {} snapshot, {n} metrics",
                pdfflow::telemetry::export::SCHEMA
            );
            Ok(())
        }
        _ => Err(anyhow!("usage: pdfflow telemetry validate <snapshot.json>")),
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("usage: pdfflow figure <fig06..fig20|treestats|all> [--full]"))?;
    let full = args.flag("full") || std::env::var("PDFFLOW_BENCH_FULL").is_ok();
    let env = BenchEnv::new(
        backend_kind_of(args)?,
        &args.opt_or("artifacts", "artifacts"),
        &args.opt_or("data-dir", "data"),
        !full,
    )?;
    env.run(id)?;
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let engine = pdfflow::runtime::Engine::load_default(args.opt_or("artifacts", "artifacts"))?;
    println!("platform: {}", engine.platform());
    let mut n = 0;
    for info in engine.manifest.artifacts.clone() {
        let t0 = std::time::Instant::now();
        engine.warm(&info)?;
        println!("  {:<40} compiled in {}", info.name, fmt_secs(t0.elapsed().as_secs_f64()));
        n += 1;
    }
    println!("{n} artifacts compile cleanly");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "artifacts-check needs the PJRT engine; rebuild with `cargo build --features xla` \
         after `make artifacts` (see rust/README.md)"
    ))
}
