//! Dataset storage: the NFS-gather reader and the window cache.
//!
//! The paper keeps input data on an NFS server outside the Spark cluster
//! (§4.1) and loads, per point, its value from each of the K simulation
//! files (Algorithm 2, via an external Java program doing positioned
//! reads). We reproduce the same access pattern with `pread`-style
//! positioned reads: one contiguous range per (window, file), transposed
//! into per-point observation vectors. Bytes and read counts are metered
//! so the simulated cluster can charge NFS time (DESIGN.md §3).

pub mod cache;

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::cube::{PointId, Window};
use crate::datagen::{SyntheticDataset, HEADER_LEN, MAGIC};
use crate::{PdfflowError, Result};

pub use cache::{CacheStats, WindowCache};

/// Observation vectors for a set of points: row-major (point, simulation).
#[derive(Clone, Debug)]
pub struct ObsMatrix {
    pub point_ids: Vec<PointId>,
    pub n_obs: usize,
    /// `data[p * n_obs + k]` = value of point `p` in simulation `k`.
    pub data: Vec<f32>,
}

impl ObsMatrix {
    pub fn n_points(&self) -> usize {
        self.point_ids.len()
    }

    pub fn point_row(&self, p: usize) -> &[f32] {
        &self.data[p * self.n_obs..(p + 1) * self.n_obs]
    }

    /// Size of the observation payload in bytes (shuffle accounting).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

/// I/O meters accumulated by a reader (feed the NFS cost model).
#[derive(Debug, Default)]
pub struct IoMeter {
    pub bytes_read: AtomicU64,
    pub read_calls: AtomicU64,
    pub files_touched: AtomicU64,
}

impl IoMeter {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.bytes_read.load(Ordering::Relaxed),
            self.read_calls.load(Ordering::Relaxed),
            self.files_touched.load(Ordering::Relaxed),
        )
    }

    pub fn reset(&self) {
        self.bytes_read.store(0, Ordering::Relaxed);
        self.read_calls.store(0, Ordering::Relaxed);
        self.files_touched.store(0, Ordering::Relaxed);
    }
}

/// Reader over a dataset's simulation files.
pub struct DatasetReader<'a> {
    ds: &'a SyntheticDataset,
    pub meter: IoMeter,
}

impl<'a> DatasetReader<'a> {
    pub fn new(ds: &'a SyntheticDataset) -> Self {
        DatasetReader {
            ds,
            meter: IoMeter::default(),
        }
    }

    pub fn dataset(&self) -> &SyntheticDataset {
        self.ds
    }

    /// Validate one file's header (format guard; paper's loader would
    /// fail on mismatched cubes).
    pub fn check_header(&self, sim: usize) -> Result<()> {
        let mut f = File::open(&self.ds.files[sim])?;
        let mut hdr = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut hdr)?;
        if &hdr[0..4] != MAGIC {
            return Err(PdfflowError::Format(format!(
                "{}: bad magic",
                self.ds.files[sim].display()
            )));
        }
        let rd = |o: usize| u32::from_le_bytes(hdr[o..o + 4].try_into().unwrap()) as usize;
        let (nx, ny, nz) = (rd(8), rd(12), rd(16));
        let d = self.ds.spec.dims;
        if (nx, ny, nz) != (d.nx, d.ny, d.nz) {
            return Err(PdfflowError::Format(format!(
                "{}: dims {nx}x{ny}x{nz} != spec {}x{}x{}",
                self.ds.files[sim].display(),
                d.nx,
                d.ny,
                d.nz
            )));
        }
        Ok(())
    }

    /// Load the observation vectors of every point in a window: one
    /// contiguous positioned read per simulation file, transposed to
    /// point-major order (Algorithm 2's data loading).
    pub fn read_window(&self, w: &Window) -> Result<ObsMatrix> {
        let dims = self.ds.spec.dims;
        let n_obs = self.ds.spec.n_sims;
        let point_ids = dims.window_points(w);
        let n_pts = point_ids.len();
        let (off, len) = w.byte_range(&dims);
        let mut data = vec![0f32; n_pts * n_obs];
        let mut buf = vec![0u8; len];
        for (k, path) in self.ds.files.iter().enumerate() {
            let mut f = File::open(path)?;
            f.seek(SeekFrom::Start(HEADER_LEN + off))?;
            f.read_exact(&mut buf)?;
            self.meter.bytes_read.fetch_add(len as u64, Ordering::Relaxed);
            self.meter.read_calls.fetch_add(1, Ordering::Relaxed);
            self.meter.files_touched.fetch_add(1, Ordering::Relaxed);
            for p in 0..n_pts {
                let b = [buf[p * 4], buf[p * 4 + 1], buf[p * 4 + 2], buf[p * 4 + 3]];
                data[p * n_obs + k] = f32::from_le_bytes(b);
            }
        }
        Ok(ObsMatrix {
            point_ids,
            n_obs,
            data,
        })
    }

    /// Load observation vectors for an arbitrary point set (the Sampling
    /// method's access pattern: one positioned read per (point, file)).
    pub fn read_points(&self, ids: &[PointId]) -> Result<ObsMatrix> {
        let n_obs = self.ds.spec.n_sims;
        let n_pts = ids.len();
        let mut data = vec![0f32; n_pts * n_obs];
        let mut b4 = [0u8; 4];
        for (k, path) in self.ds.files.iter().enumerate() {
            let mut f = File::open(path)?;
            self.meter.files_touched.fetch_add(1, Ordering::Relaxed);
            for (p, id) in ids.iter().enumerate() {
                f.seek(SeekFrom::Start(HEADER_LEN + id.0 * 4))?;
                f.read_exact(&mut b4)?;
                self.meter.bytes_read.fetch_add(4, Ordering::Relaxed);
                self.meter.read_calls.fetch_add(1, Ordering::Relaxed);
                data[p * n_obs + k] = f32::from_le_bytes(b4);
            }
        }
        Ok(ObsMatrix {
            point_ids: ids.to_vec(),
            n_obs,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::CubeDims;
    use crate::datagen::DatasetSpec;

    fn dataset(tag: &str) -> (SyntheticDataset, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("pdfflow-storage-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ds = SyntheticDataset::generate(&DatasetSpec::tiny(), &dir).unwrap();
        (ds, dir)
    }

    #[test]
    fn header_check_passes() {
        let (ds, dir) = dataset("hdr");
        let r = DatasetReader::new(&ds);
        r.check_header(0).unwrap();
        r.check_header(ds.spec.n_sims - 1).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn header_check_rejects_corruption() {
        let (ds, dir) = dataset("corrupt");
        let path = &ds.files[0];
        let mut bytes = std::fs::read(path).unwrap();
        bytes[0] = b'X';
        std::fs::write(path, &bytes).unwrap();
        let r = DatasetReader::new(&ds);
        assert!(r.check_header(0).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn window_read_matches_point_read() {
        let (ds, dir) = dataset("match");
        let r = DatasetReader::new(&ds);
        let w = Window { z: 2, y0: 1, lines: 2 };
        let wm = r.read_window(&w).unwrap();
        let pm = r.read_points(&wm.point_ids).unwrap();
        assert_eq!(wm.data, pm.data);
        assert_eq!(wm.n_obs, ds.spec.n_sims);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn observation_vectors_group_as_designed() {
        // Pure points with the same gain level inside one slice must have
        // IDENTICAL observation vectors (the property Grouping exploits).
        let (ds, dir) = dataset("group");
        let r = DatasetReader::new(&ds);
        let dims = ds.spec.dims;
        let w = Window { z: 0, y0: 0, lines: dims.ny };
        let m = r.read_window(&w).unwrap();
        use std::collections::HashMap;
        let mut by_vec: HashMap<Vec<u32>, usize> = HashMap::new();
        for p in 0..m.n_points() {
            let key: Vec<u32> = m.point_row(p).iter().map(|v| v.to_bits()).collect();
            *by_vec.entry(key).or_default() += 1;
        }
        let n_groups = by_vec.len();
        let n_points = m.n_points();
        assert!(
            n_groups < n_points,
            "expected grouping: {n_groups} groups of {n_points} points"
        );
        // Unique-noise fraction (~25%) should keep groups well below 60%.
        assert!((n_groups as f64) < 0.6 * n_points as f64, "{n_groups}/{n_points}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn meter_counts_bytes() {
        let (ds, dir) = dataset("meter");
        let r = DatasetReader::new(&ds);
        let w = Window { z: 0, y0: 0, lines: 1 };
        let m = r.read_window(&w).unwrap();
        let (bytes, calls, files) = r.meter.snapshot();
        assert_eq!(bytes, (m.n_points() * 4 * ds.spec.n_sims) as u64);
        assert_eq!(calls, ds.spec.n_sims as u64);
        assert_eq!(files, ds.spec.n_sims as u64);
        r.meter.reset();
        assert_eq!(r.meter.snapshot(), (0, 0, 0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn values_are_finite_and_positive_scaled() {
        let (ds, dir) = dataset("vals");
        let r = DatasetReader::new(&ds);
        let w = Window { z: 4, y0: 0, lines: 3 };
        let m = r.read_window(&w).unwrap();
        assert!(m.data.iter().all(|v| v.is_finite()));
        // Seismic velocities are positive for these layer families.
        let frac_pos = m.data.iter().filter(|&&v| v > 0.0).count() as f64 / m.data.len() as f64;
        assert!(frac_pos > 0.95, "frac_pos={frac_pos}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_points_arbitrary_order() {
        let (ds, dir) = dataset("order");
        let r = DatasetReader::new(&ds);
        let dims = ds.spec.dims;
        let ids = vec![
            dims.point_id(5, 3, 1),
            dims.point_id(0, 0, 0),
            dims.point_id(dims.nx - 1, dims.ny - 1, dims.nz - 1),
        ];
        let m = r.read_points(&ids).unwrap();
        assert_eq!(m.n_points(), 3);
        assert_eq!(m.point_ids, ids);
        assert!(m.data.iter().all(|v| v.is_finite()));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
