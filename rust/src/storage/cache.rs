//! In-memory window cache (paper §4.3.1 data caching).
//!
//! The paper caches instruction data and intermediate data in memory (RDD
//! `Cache` + a tmpfs for external-program output) and never caches the
//! big input data. Our analog: loaded windows (the intermediate
//! observation matrices) are cached up to a byte budget with LRU
//! eviction; dataset files themselves are always streamed from "NFS".
//!
//! The cache is a single-shard front over the generic
//! [`crate::util::lru::ShardedStampLru`] core (shared with the
//! pdfstore's query block cache): one shard keeps exact global LRU
//! order, which the window access pattern (few, large, reused entries)
//! wants more than shard parallelism.

use std::sync::Arc;

use crate::cube::Window;
use crate::storage::ObsMatrix;
use crate::util::lru::ShardedStampLru;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    z: usize,
    y0: usize,
    lines: usize,
}

impl From<&Window> for Key {
    fn from(w: &Window) -> Key {
        Key {
            z: w.z,
            y0: w.y0,
            lines: w.lines,
        }
    }
}

/// Observability counters of a [`WindowCache`] — surfaced per run in
/// `SliceReport` rows so cache effectiveness is visible in every report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Resident bytes.
    pub bytes: u64,
    /// Resident entries.
    pub entries: usize,
}

/// LRU cache of loaded windows with a byte budget. All methods take
/// `&self`; one cache serves every parallel window task.
pub struct WindowCache {
    lru: ShardedStampLru<Key, Arc<ObsMatrix>>,
}

impl WindowCache {
    pub fn new(capacity_bytes: u64) -> Self {
        WindowCache {
            // Mirrored in the process registry as `cache.window.*` —
            // every pipeline window cache sums into one exported meter
            // while `stats()` stays instance-exact.
            lru: ShardedStampLru::with_label(
                capacity_bytes,
                1,
                |m: &Arc<ObsMatrix>| m.bytes(),
                "window",
            ),
        }
    }

    pub fn get(&self, w: &Window) -> Option<Arc<ObsMatrix>> {
        self.lru.get(&Key::from(w))
    }

    pub fn put(&self, w: &Window, m: Arc<ObsMatrix>) {
        self.lru.put(Key::from(w), m)
    }

    pub fn stats(&self) -> CacheStats {
        let s = self.lru.stats();
        CacheStats {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            bytes: s.bytes,
            entries: s.entries,
        }
    }

    pub fn clear(&self) {
        self.lru.clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::PointId;

    fn matrix(n_points: usize, n_obs: usize) -> Arc<ObsMatrix> {
        Arc::new(ObsMatrix {
            point_ids: (0..n_points as u64).map(PointId).collect(),
            n_obs,
            data: vec![1.0; n_points * n_obs],
        })
    }

    fn win(y0: usize) -> Window {
        Window { z: 0, y0, lines: 1 }
    }

    #[test]
    fn hit_after_put() {
        let c = WindowCache::new(1 << 20);
        assert!(c.get(&win(0)).is_none());
        c.put(&win(0), matrix(10, 10));
        assert!(c.get(&win(0)).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.bytes, 400);
    }

    #[test]
    fn lru_eviction_under_budget() {
        // Each matrix is 400 bytes; budget fits two.
        let c = WindowCache::new(900);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(1), matrix(10, 10));
        assert!(c.get(&win(0)).is_some()); // touch 0 so 1 is LRU
        c.put(&win(2), matrix(10, 10));    // evicts 1
        assert!(c.get(&win(1)).is_none());
        assert!(c.get(&win(0)).is_some());
        assert!(c.get(&win(2)).is_some());
    }

    #[test]
    fn eviction_counter_tracks_lru_evictions() {
        // Budget fits two 400-byte matrices; the third and fourth insert
        // must each evict exactly the least-recently-used entry.
        let c = WindowCache::new(900);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(1), matrix(10, 10));
        assert_eq!(c.stats().evictions, 0);
        c.put(&win(2), matrix(10, 10)); // evicts 0 (oldest stamp)
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(c.get(&win(0)).is_none());
        c.put(&win(3), matrix(10, 10)); // evicts 1
        assert_eq!(c.stats().evictions, 2);
        assert!(c.get(&win(1)).is_none());
        assert!(c.get(&win(2)).is_some() && c.get(&win(3)).is_some());
        // Re-inserting an existing key within budget evicts nothing.
        c.put(&win(3), matrix(10, 10));
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let c = WindowCache::new(100);
        c.put(&win(0), matrix(100, 100));
        assert!(c.get(&win(0)).is_none());
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
    }

    #[test]
    fn replace_same_key_updates_bytes() {
        let c = WindowCache::new(10_000);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(0), matrix(20, 10));
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 800);
    }

    #[test]
    fn clear_empties() {
        let c = WindowCache::new(10_000);
        c.put(&win(0), matrix(10, 10));
        c.clear();
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
    }
}
