//! In-memory window cache (paper §4.3.1 data caching).
//!
//! The paper caches instruction data and intermediate data in memory (RDD
//! `Cache` + a tmpfs for external-program output) and never caches the
//! big input data. Our analog: loaded windows (the intermediate
//! observation matrices) are cached up to a byte budget with LRU
//! eviction; dataset files themselves are always streamed from "NFS".

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cube::Window;
use crate::storage::ObsMatrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    z: usize,
    y0: usize,
    lines: usize,
}

impl From<&Window> for Key {
    fn from(w: &Window) -> Key {
        Key {
            z: w.z,
            y0: w.y0,
            lines: w.lines,
        }
    }
}

/// Observability counters of a [`WindowCache`] — surfaced per run in
/// `SliceReport` rows so cache effectiveness is visible in every report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Resident bytes.
    pub bytes: u64,
    /// Resident entries.
    pub entries: usize,
}

/// LRU cache of loaded windows with a byte budget.
pub struct WindowCache {
    inner: Mutex<Inner>,
    capacity_bytes: u64,
}

struct Inner {
    map: HashMap<Key, (u64, Arc<ObsMatrix>)>, // key -> (stamp, matrix)
    clock: u64,
    bytes: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl WindowCache {
    pub fn new(capacity_bytes: u64) -> Self {
        WindowCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            capacity_bytes,
        }
    }

    pub fn get(&self, w: &Window) -> Option<Arc<ObsMatrix>> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let found = g.map.get_mut(&Key::from(w)).map(|(stamp, m)| {
            *stamp = clock;
            Arc::clone(m)
        });
        match found {
            Some(m) => {
                g.hits += 1;
                Some(m)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, w: &Window, m: Arc<ObsMatrix>) {
        let bytes = m.bytes();
        if bytes > self.capacity_bytes {
            return; // too big to cache — streamed like input data
        }
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        if let Some((_, old)) = g.map.insert(Key::from(w), (clock, m)) {
            g.bytes -= old.bytes();
        }
        g.bytes += bytes;
        // Evict least-recently-used until under budget.
        while g.bytes > self.capacity_bytes {
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| *k)
                .expect("over budget implies non-empty");
            let (_, evicted) = g.map.remove(&victim).unwrap();
            g.bytes -= evicted.bytes();
            g.evictions += 1;
        }
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            bytes: g.bytes,
            entries: g.map.len(),
        }
    }

    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::PointId;

    fn matrix(n_points: usize, n_obs: usize) -> Arc<ObsMatrix> {
        Arc::new(ObsMatrix {
            point_ids: (0..n_points as u64).map(PointId).collect(),
            n_obs,
            data: vec![1.0; n_points * n_obs],
        })
    }

    fn win(y0: usize) -> Window {
        Window { z: 0, y0, lines: 1 }
    }

    #[test]
    fn hit_after_put() {
        let c = WindowCache::new(1 << 20);
        assert!(c.get(&win(0)).is_none());
        c.put(&win(0), matrix(10, 10));
        assert!(c.get(&win(0)).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.bytes, 400);
    }

    #[test]
    fn lru_eviction_under_budget() {
        // Each matrix is 400 bytes; budget fits two.
        let c = WindowCache::new(900);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(1), matrix(10, 10));
        assert!(c.get(&win(0)).is_some()); // touch 0 so 1 is LRU
        c.put(&win(2), matrix(10, 10));    // evicts 1
        assert!(c.get(&win(1)).is_none());
        assert!(c.get(&win(0)).is_some());
        assert!(c.get(&win(2)).is_some());
    }

    #[test]
    fn eviction_counter_tracks_lru_evictions() {
        // Budget fits two 400-byte matrices; the third and fourth insert
        // must each evict exactly the least-recently-used entry.
        let c = WindowCache::new(900);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(1), matrix(10, 10));
        assert_eq!(c.stats().evictions, 0);
        c.put(&win(2), matrix(10, 10)); // evicts 0 (oldest stamp)
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(c.get(&win(0)).is_none());
        c.put(&win(3), matrix(10, 10)); // evicts 1
        assert_eq!(c.stats().evictions, 2);
        assert!(c.get(&win(1)).is_none());
        assert!(c.get(&win(2)).is_some() && c.get(&win(3)).is_some());
        // Re-inserting an existing key within budget evicts nothing.
        c.put(&win(3), matrix(10, 10));
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let c = WindowCache::new(100);
        c.put(&win(0), matrix(100, 100));
        assert!(c.get(&win(0)).is_none());
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
    }

    #[test]
    fn replace_same_key_updates_bytes() {
        let c = WindowCache::new(10_000);
        c.put(&win(0), matrix(10, 10));
        c.put(&win(0), matrix(20, 10));
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 800);
    }

    #[test]
    fn clear_empties() {
        let c = WindowCache::new(10_000);
        c.put(&win(0), matrix(10, 10));
        c.clear();
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
    }
}
